"""Batched serving example: prefill + decode with KV-cache/SSM state across
the model zoo (deployment leg of the paper's create/train/deploy triad).

    PYTHONPATH=src python examples/serve_lm.py --arch smollm-135m
    PYTHONPATH=src python examples/serve_lm.py --arch xlstm-1.3b   # recurrent-state serving
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax

from repro.configs.registry import get_config
from repro.models.model import init_params
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()  # reduced weights: CPU-friendly demo
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    scfg = ServeConfig(
        max_batch=args.batch,
        max_len=args.prompt_len + args.new_tokens + 8,
        temperature=args.temperature,
    )
    eng = Engine(cfg, params, scfg)

    shape = (
        (args.batch, args.prompt_len, cfg.n_codebooks)
        if cfg.n_codebooks
        else (args.batch, args.prompt_len)
    )
    prompts = jax.random.randint(jax.random.PRNGKey(1), shape, 0, cfg.vocab_size)
    t0 = time.time()
    out, _ = eng.prefill_and_generate(prompts, n_new=args.new_tokens)
    dt = time.time() - t0
    total_new = args.batch * args.new_tokens
    print(f"arch={cfg.name} family={cfg.family}")
    print(f"generated {out.shape} tokens in {dt:.2f}s  ({total_new/dt:.1f} tok/s batched)")
    print("first sequence:", out[0].tolist()[:12], "...")


if __name__ == "__main__":
    main()
