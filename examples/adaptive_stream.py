"""Adaptive separation under a non-stationary mixing matrix — the paper's §I
motivation ("track changes in underlying distributions of input features").

    PYTHONPATH=src python examples/adaptive_stream.py

The mixing matrix rotates slowly while the separator streams mini-batches
through ``partial_fit``.  SMBGD's γ-momentum + β-recency weighting is exactly
the knob the paper describes: large γ for smooth drift, small γ for abrupt
change.  Prints the tracking error over time for SMBGD vs plain SGD.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax

from repro.core import AdaptiveICA, EASIConfig, SMBGDConfig, amari_index, global_system
from repro.data.pipeline import MixedSignals


def run(algorithm: str, gamma: float, n_steps: int = 4000) -> list:
    ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=gamma)
    ica = AdaptiveICA(ecfg, ocfg, algorithm=algorithm)
    state = ica.init(jax.random.PRNGKey(0))
    pipe = MixedSignals(m=4, n=2, batch=16, seed=0, drift_rate=3e-6)
    fit = jax.jit(lambda s, x: ica.partial_fit(s, x))
    errs = []
    for step in range(n_steps):
        state, _ = fit(state, pipe.batch_for_step(step))
        if step % 500 == 499:
            pi = float(amari_index(global_system(state.B, pipe.mixing_at(step))))
            errs.append((step, pi))
    return errs


def main():
    print("streaming 4000 mini-batches with a slowly rotating mixing matrix")
    print(f"{'step':>6} | {'SGD':>8} | {'SMBGD γ=0.5':>12}")
    sgd = dict(run("sgd", gamma=0.0))
    smb = dict(run("smbgd", gamma=0.5))
    for step in sorted(sgd):
        print(f"{step:6d} | {sgd[step]:8.4f} | {smb[step]:12.4f}")
    final_sgd, final_smb = list(sgd.values())[-1], list(smb.values())[-1]
    print(
        f"\nfinal tracking Amari index: SGD {final_sgd:.4f}  vs  SMBGD {final_smb:.4f}"
        f"  ({'SMBGD tracks better' if final_smb < final_sgd else 'comparable'})"
    )


if __name__ == "__main__":
    main()
