"""Adaptive separation under non-stationary mixing — single stream and bank.

    PYTHONPATH=src python examples/adaptive_stream.py

Part 1 (the paper's §I motivation): one mixing matrix rotates slowly while the
separator streams mini-batches through ``partial_fit``.  SMBGD's γ-momentum +
β-recency weighting is exactly the knob the paper describes: large γ for
smooth drift, small γ for abrupt change.  Prints tracking error over time for
SMBGD vs plain SGD.

Part 2 (the production shape): a ``SeparatorBank`` runs S such sessions at
once — every stream has its own sources, its own mixing matrix and its own
drift phase (``MixedSignals(streams=S)``), yet each tick is ONE fused array
program.  With ``use_pallas=True`` the gradient sums of all streams go through
a single (streams, P-tiles) kernel launch (interpreted on CPU; set
``REPRO_PALLAS_INTERPRET=0`` on real TPU hardware).

Part 3 (the serving shape): more sessions than slots.  A ``SeparationService``
with a ``ConvergencePolicy`` watches each session's in-bank convergence
statistic (relative update magnitude, computed inside the fused step) and
auto-evicts converged separators, backfilling their slots from the bounded
admission queue within the same tick — converged sessions stop wasting
hardware, exactly the utilization knob the paper's always-on datapath needs
at rack scale.

Part 4 (the drift-aware pipeline): a CORTEX-style ``ChannelBankSource``
session — a multi-channel ``.npy`` recording served through ``run_tick()``'s
pull loop — whose mixing rotates abruptly mid-recording.  The service has NO
ground truth (real recordings don't ship their mixing matrix): the
``DriftPolicy`` watchdog sees the in-kernel conv statistic rise on the
converged-hot session, fires a ``DriftEvent``, μ-boosts the stream through
the bank's per-stream hyperparameter rows, and the separator re-converges on
the new mixing — while the no-watchdog deployment would keep serving the
stale separator.

Part 5 (the containment shape): real feeds fail — sensors drop to NaN,
amplifiers rail to Inf, network reads stall.  The bank's megakernel folds a
per-stream *health word* into the same in-register reduction as the conv
statistic (non-finite B′/Ĥ′/Y bits + an update-magnitude blow-up bit) and
REFUSES an unhealthy commit in-kernel, so one poisoned mini-batch never
reaches persistent state.  A ``HealthPolicy`` turns the word into a
lifecycle: rollback to the last-known-good shadow snapshot + μ cut, then
quarantine under out-of-band health probes, then eviction with reason
``"diverged"`` — while ``ResilientSource`` retries transient source faults
before they ever become degraded ticks.  The drill injects faults with the
test suite's own ``FaultInjector`` chaos harness.

Part 6 (the real-time shape): serving is only as good as its worst tick.
Every tick the service stops a TIME-TO-READY clock (a ``block_until_ready``
on the bank's tiny conv telemetry leaf — honest on asynchronous backends,
where wall-clock around a jitted call times only the dispatch) and feeds a
streaming quantile sketch: ``svc.metrics()`` reports p50/p99/p999 live.  An
``SLOPolicy(deadline_budget_s=...)`` arms deadline accounting — over-budget
ticks count misses, per-session, and opt-in ``shed``/``gate_admissions``
levers turn sustained misses into load control.  The drill records a live
run's blocks through a ``RecordingSource`` tap, saves the ``.npz`` trace,
and replays it deterministically into a fresh service under a budget — the
same record→replay harness ``stream_throughput.py --slo`` gates in CI.

Part 7 (the adaptive-μ shape): the fixed drift boost of Part 4 is open-loop —
μ×4 for 40 ticks whether the separator needs 10 or 100.  With
``SeparatorBank(..., moments=True)`` the megakernel folds per-stream raw
moments [Σy², Σy⁴] into the same in-register reduction as the conv statistic
(8 bytes/stream/tick of extra HBM — the output leaf is the whole cost), and a
``MomentPolicy`` turns them into a closed-loop μ controller: per-session EMA
kurtosis, fast tracker vs slow reference; when drift re-mixes the output the
central limit theorem drags its kurtosis toward Gaussian, the fast EMA leaves
the reference, and μ scales with the deviation — then ANNEALS back to base as
re-convergence pulls the kurtosis home.  The drill serves the same abrupt
rotation twice, side by side: fixed boost vs moment-scaled.  Composition with
the other μ writers is pinned: a HealthPolicy μ-cut WINS while live, the
DriftPolicy boost and the controller MULTIPLY.

Part 8 (the elastic shape): a bank frozen at init either strands capacity or
turns every burst into queue wait.  ``AutoscalePolicy`` closes the loop: the
``run_tick`` autoscaler grows the bank (power-of-two ladder, pre-compilable
via ``svc.prewarm``) while sessions wait in the queue, and after the burst
drains it compacts the survivors to the low slots and shrinks the width back
— hysteresis bands plus cooldown ticks, so it never flaps.  Every resize is
a prefix copy and every compaction a verbatim row move: co-tenant
trajectories stay bit-identical to a fixed-width run (the tests/test_elastic
property sweep pins this on both execution paths).  The drill admits a burst
against a deliberately narrow bank and prints the width/utilization arc:
stranded-queue → grown → drained → compacted+shrunk.

Probe knobs (``DriftPolicy(mode="readmit")``, the parked alternative to the
hot watch used below): ``probe_every`` sets the out-of-band probe cadence in
run_ticks, and ``probe_batch`` sets how many parked sessions share one
no-commit probe-bank launch — at serving scale (thousands parked) the
watchdog costs O(parked / probe_batch) dispatches per probe tick instead of
O(parked); ``probe_batch=0`` falls back to the one-dispatch-per-session
loop.  See ``stream_throughput.py --probe`` for the measured gap at 256
parked sessions.

Memory-system knobs (the bank's bandwidth/capacity levers; all optional):

* ``SeparatorBank(..., dtype_policy="bf16")`` stores the persistent separator
  state (B, Ĥ) in bfloat16 while every gradient and commit still accumulates
  in f32 inside the kernel — casts happen only at the load/commit boundary.
  Capacity doubles per byte of HBM: ``bank.layout.persistent_bytes_per_session``
  drops 520 → 264 bytes for the paper's 4→2 shape.  Per-stream hyperparameter
  rows stay f32 regardless of policy.  The default (``None``) follows
  ``easi.dtype`` so existing configs keep their storage contract.
* ``SeparatorBank(..., prefetch=True)`` double-buffers the X mini-batch DMA in
  the fused megakernel: while stream-block t computes, t+1's tile is already
  in flight.  Bit-identical to the sync path (tested); it's a real-TPU
  latency-hiding win — on CPU interpret mode it just adds bookkeeping, so
  leave it off locally.
* Geometry (``block_p``, ``block_s``, prefetch) resolves from the checked-in
  ``AUTOTUNE.json`` when the bank's (S, P, m, n, backend) key was swept —
  run ``benchmarks/stream_throughput.py --autotune`` once per deployment
  shape to refresh it.  Explicitly-passed knobs always win, and
  ``dtype_policy`` is recorded but never auto-applied (a numerics contract
  stays an explicit opt-in).  ``autotune=False`` opts out entirely.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveICA, EASIConfig, SMBGDConfig, amari_index, global_system
from repro.data.pipeline import MixedSignals
from repro.serve.engine import ConvergencePolicy, SeparationService
from repro.stream import SeparatorBank


def run(algorithm: str, gamma: float, n_steps: int = 4000) -> list:
    ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=gamma)
    ica = AdaptiveICA(ecfg, ocfg, algorithm=algorithm)
    state = ica.init(jax.random.PRNGKey(0))
    pipe = MixedSignals(m=4, n=2, batch=16, seed=0, drift_rate=3e-6)
    fit = jax.jit(lambda s, x: ica.partial_fit(s, x))
    errs = []
    for step in range(n_steps):
        state, _ = fit(state, pipe.batch_for_step(step))
        if step % 500 == 499:
            pi = float(amari_index(global_system(state.B, pipe.mixing_at(step))))
            errs.append((step, pi))
    return errs


def run_bank(n_streams: int = 8, n_steps: int = 2000) -> jnp.ndarray:
    """S drifting sessions, one fused program; returns per-stream Amari index."""
    ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=0.5)
    bank = SeparatorBank(ecfg, ocfg, n_streams=n_streams)
    state = bank.init(jax.random.PRNGKey(0))
    pipe = MixedSignals(
        m=4, n=2, batch=16, seed=0, drift_rate=3e-6, streams=n_streams
    )
    step_fn = jax.jit(lambda s, x: bank.step(s, x))
    for step in range(n_steps):
        state, _ = step_fn(state, pipe.batch_for_step(step))
    # evaluate against the last-seen mixing (same convention as run())
    return bank.performance_index(state, pipe.mixing_at(n_steps - 1))


def run_service(n_slots: int = 4, n_sessions: int = 10, max_ticks: int = 1500):
    """Churning deployment: sessions queue for slots, converge, auto-evict.

    Returns (events, finished) — the lifecycle log and the eviction records
    (final separation matrix + serving stats per session).
    """
    P = 16
    ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=3e-3, beta=0.9, gamma=0.5)
    events = []
    svc = SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=n_slots, fused=True),
        seed=0,
        policy=ConvergencePolicy(threshold=0.02, patience=5, min_ticks=50, ema=0.9),
        max_queue=n_sessions,
        on_admit=lambda sid, slot: events.append((svc.metrics["n_ticks"], "admit", sid, slot)),
        on_evict=lambda sid, rec: events.append((svc.metrics["n_ticks"], "evict", sid, rec.reason)),
    )
    pipe = MixedSignals(m=4, n=2, batch=P, seed=0, streams=n_sessions)
    sids = [f"user-{i}" for i in range(n_sessions)]
    for sid in sids:
        svc.admit(sid)  # first n_slots activate, the rest queue
    stream_of = {sid: i for i, sid in enumerate(sids)}
    for tick in range(max_ticks):
        active = [sid for sid in sids if svc.status(sid) == "active"]
        if not active:
            break
        X = np.asarray(pipe.batch_for_step(tick))
        svc.step({sid: X[stream_of[sid]] for sid in active})
    return events, svc.pop_finished(), svc.metrics


def run_drift_recording(n_ticks: int = 700, jump_tick: int = 300):
    """Part 4: serve a channel-bank recording whose mixing jumps mid-run.

    Returns (events, trace, first_converged) — the lifecycle/drift log,
    (tick, amari) samples against the recording's true piecewise mixing,
    and the tick the session first converged (= when a policy-only service
    would have evicted it).
    """
    import os
    import tempfile

    from repro.data import signals
    from repro.data.sources import ChannelBankSource, _givens
    from repro.serve import DriftPolicy

    P, m, n = 16, 4, 2
    T = n_ticks * P
    # synthesize the "recording": sub-Gaussian sources through a mixing that
    # is stationary, rotates ~1.2 rad abruptly at jump_tick, then stationary
    key = jax.random.PRNGKey(0)
    S = signals.source_bank(jax.random.PRNGKey(1), n, T)
    A0 = signals.random_mixing_matrix(key, m, n)
    A1 = _givens(m, 1.2) @ A0  # the same rotation plane the watchdog drills use
    t_jump = jump_tick * P
    At = jnp.where(
        (jnp.arange(T) < t_jump)[:, None, None],
        jnp.broadcast_to(A0, (T, m, n)),
        jnp.broadcast_to(A1, (T, m, n)),
    )
    X = signals.mix_nonstationary(At, S)  # (T, m)
    rec_fd, rec_path = tempfile.mkstemp(suffix=".npy")
    os.close(rec_fd)
    np.save(rec_path, np.asarray(X).T.astype(np.float32))  # channel-major

    ecfg = EASIConfig(n_components=n, n_features=m, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=3e-3, beta=0.9, gamma=0.5)
    events = []
    svc = SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=2),
        seed=0,
        policy=ConvergencePolicy(threshold=0.025, patience=5, min_ticks=50, ema=0.9),
        # mode="boost" keeps the session hot in its slot; mode="readmit"
        # would park it instead and probe the frozen separator out-of-band
        # every `probe_every` ticks, `probe_batch` parked sessions per
        # batched probe launch (the rack-scale watchdog configuration)
        drift_policy=DriftPolicy(
            retrigger=0.03, patience=2, ema=0.8, cooldown=3,
            mode="boost", boost=4.0, boost_ticks=40,
        ),
        on_drift=lambda sid, ev: events.append(
            (int(svc.metrics["n_ticks"]), "drift", sid, f"μ×4 (stat {ev.stat:.3f})")
        ),
        on_evict=lambda sid, r: events.append(
            (int(svc.metrics["n_ticks"]), "evict", sid, r.reason)
        ),
    )
    # the session IS the recording: memory-mapped windowed reads, no ground
    # truth exposed — the blind conv statistic alone drives the lifecycle
    svc.admit("eeg-0", source=ChannelBankSource(rec_path, center=False))
    first_converged = None
    trace = []
    try:
        for tick in range(n_ticks - 1):
            svc.run_tick()
            st = svc.status("eeg-0")
            if st == "converged" and first_converged is None:
                first_converged = tick
                events.append((tick, "hot", "eeg-0", "converged, kept hot"))
            if tick % 50 == 49 and st in ("active", "converged"):
                B = svc.bank.slot_state(svc.state, svc.sessions["eeg-0"]).B
                A = A0 if tick < jump_tick else A1
                trace.append((tick, float(amari_index(global_system(B, A)))))
    finally:
        os.unlink(rec_path)
    return events, trace, first_converged


def run_containment(n_ticks: int = 30):
    """Part 5: fault containment — a poisoned feed, a flaky feed, a clean one.

    Returns (events, metrics, statuses) — the containment log (rollback →
    quarantine → release for the poisoned session; nothing at all for the
    retried flaky one), the service counters, and each session's final status.
    """
    from repro.data.resilience import FaultInjector, ResilientSource
    from repro.data.sources import ReplaySource
    from repro.kernels.easi_gradient.ops import describe_health
    from repro.serve import HealthPolicy

    P, m, n = 16, 4, 2
    ecfg = EASIConfig(n_components=n, n_features=m, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=3e-3, beta=0.9, gamma=0.5)
    rng = np.random.default_rng(0)

    def feed():
        return ReplaySource(
            rng.standard_normal(((n_ticks + 2) * P, m)).astype(np.float32),
            loop=True,
        )

    events = []
    svc = SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=3),  # health_checks=True default
        seed=0,
        # threshold unreachable: the drill watches containment, not convergence
        policy=ConvergencePolicy(threshold=1e-12, patience=10**6, min_ticks=10**6),
        health_policy=HealthPolicy(
            max_rollbacks=1, window=30, mu_cut=0.25, cut_ticks=5,
            max_quarantines=1, probation=2, probe_every=2, shadow_every=4,
        ),
        on_health=lambda sid, ev: events.append(
            (ev.tick, ev.action, sid, describe_health(ev.word))
        ),
    )
    # NaN bursts at blocks 2 and 4: the first costs a rollback, the second
    # exhausts the rollback budget → quarantine; the clean blocks after serve
    # the probation under out-of-band health probes → warm release.  (A feed
    # still poisoned IN quarantine keeps failing probes on the same ladder
    # and exits with reason "diverged" instead.)
    svc.admit("poisoned", source=FaultInjector(feed(), {2: "nan", 4: "nan"}))
    # two transient raises, retried clean inside the source wrapper — the
    # service never even sees a degraded tick
    svc.admit("flaky", source=ResilientSource(
        FaultInjector(feed(), {3: "raise", 5: "raise"}), max_retries=3,
    ))
    svc.admit("clean", source=feed())
    for _ in range(n_ticks):
        svc.run_tick()
    statuses = {sid: svc.status(sid) for sid in ("poisoned", "flaky", "clean")}
    return events, svc.metrics, statuses


def run_slo_replay(n_blocks: int = 40, budget_factor: float = 5.0):
    """Part 6: latency SLOs over a recorded load.

    Records a 2-session live run through ``RecordingSource`` taps, saves the
    trace, then replays it into a fresh service with a deadline budget set at
    ``budget_factor`` x the live run's median time-to-ready.  Returns (live
    metrics, replay metrics, miss rate, budget) — and the replay's separated
    outputs are bit-identical to the live run's (tested in test_slo.py), so
    the tail you measure is the tail you shipped.
    """
    import tempfile

    from repro.data.sources import RecordingSource, load_recording, save_recording
    from repro.serve import SLOPolicy
    from repro.serve.slo import replay

    P, m, n = 16, 4, 2
    ecfg = EASIConfig(n_components=n, n_features=m, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=3e-3, beta=0.9, gamma=0.5)

    def fresh(slo=None):
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=2), seed=0, slo=slo,
        )

    taps = {
        sid: RecordingSource(
            SyntheticSourceFactory(m=m, n=n, P=P, seed=seed)
        )
        for sid, seed in (("left", 7), ("right", 8))
    }
    live = fresh()
    for sid, tap in taps.items():
        live.admit(sid, source=tap)
    for _ in range(n_blocks):
        live.run_tick()
    live_m = live.metrics
    budget = budget_factor * live_m["p50_tick_s"]

    with tempfile.TemporaryDirectory() as d:
        path = Path(d) / "slo_demo.npz"
        save_recording(
            path, taps,
            events=[
                {"action": "admit", "sid": sid, "tick": 0, "order": i}
                for i, sid in enumerate(taps)
            ],
            meta={"P": P, "m": m, "n": n},
        )
        rec = load_recording(path)
        svc = fresh(slo=SLOPolicy(deadline_budget_s=budget))
        replay(svc, rec)
    rep_m = svc.metrics
    timed = rep_m["n_timed_ticks"] + rep_m["n_empty_ticks"]
    miss_rate = rep_m["n_deadline_misses"] / timed if timed else float("nan")
    return live_m, rep_m, miss_rate, budget


def run_moment_drill(n_ticks: int = 700, jump_tick: int = 300):
    """Part 7: fixed μ-boost vs the moment-scaled adaptive μ controller.

    The same abrupt ~1.2 rad mixing rotation (the Part-4 recipe) is served
    twice from identical seeds: once with the open-loop ``DriftPolicy``
    boost (μ×4 for 40 ticks on watchdog fire), once with a no-op boost plus
    a ``MomentPolicy`` controller reading the bank's in-kernel [Σy², Σy⁴]
    telemetry.  Returns (trace_fixed, trace_ctrl, scale_trace, reconv) —
    (tick, amari) samples for both services, the controller's (tick,
    μ-multiplier) trajectory, and the ticks-to-reconverge after the jump
    for each (None = never re-entered the pre-jump band).
    """
    from repro.data import signals
    from repro.data.sources import ReplaySource, _givens
    from repro.serve import DriftPolicy, MomentPolicy

    P, m, n = 16, 4, 2
    T = n_ticks * P
    src = signals.source_bank(jax.random.PRNGKey(1), n, T)
    A0 = signals.random_mixing_matrix(jax.random.PRNGKey(0), m, n)
    # a HARD jump (1.4 rad) at a conservative base μ: re-adaptation outlasts
    # the fixed 40-tick boost window, which is exactly where open-loop boost
    # mis-calibrates and the closed loop pays off
    A1 = _givens(m, 1.4) @ A0
    t_jump = jump_tick * P
    At = jnp.where(
        (jnp.arange(T) < t_jump)[:, None, None],
        jnp.broadcast_to(A0, (T, m, n)),
        jnp.broadcast_to(A1, (T, m, n)),
    )
    X = np.asarray(signals.mix_nonstationary(At, src)).astype(np.float32)

    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)

    def build(moment_policy=None, boost=4.0):
        svc = SeparationService(
            SeparatorBank(
                ecfg, ocfg, n_streams=2, moments=moment_policy is not None
            ),
            seed=0,
            policy=ConvergencePolicy(
                threshold=0.025, patience=5, min_ticks=50, ema=0.9
            ),
            # both services share the hot watchdog; the controller run makes
            # its boost a no-op (boost=1) so re-adaptation speed is the
            # moment controller's alone
            drift_policy=DriftPolicy(
                retrigger=0.03, patience=2, ema=0.8, cooldown=3,
                mode="boost", boost=boost, boost_ticks=40,
            ),
            moment_policy=moment_policy,
        )
        svc.admit("eeg-0", source=ReplaySource(X))
        return svc

    fixed = build()
    ctrl = build(
        moment_policy=MomentPolicy(
            ema_fast=0.3, ema_slow=0.005, warmup_ticks=20,
            deadband=0.05, gain=6.0, max_scale=8.0,
        ),
        boost=1.0,
    )
    traces = {"fixed": [], "ctrl": []}
    scale_trace = []
    for tick in range(n_ticks - 1):
        for name, svc in (("fixed", fixed), ("ctrl", ctrl)):
            svc.run_tick()
            if tick % 10 == 9 and svc.status("eeg-0") in ("active", "converged"):
                B = svc.bank.slot_state(svc.state, svc.sessions["eeg-0"]).B
                A = A0 if tick < jump_tick else A1
                traces[name].append(
                    (tick, float(amari_index(global_system(B, A))))
                )
        if tick % 10 == 9 and "eeg-0" in ctrl.sessions:
            scale_trace.append(
                (tick, ctrl.session_stats("eeg-0").get("mu_ctrl", 1.0))
            )

    def ticks_to_reconverge(trace):
        pre = [pi for t, pi in trace if t < jump_tick]
        band = 1.5 * pre[-1]  # "recovered" = back inside 1.5x pre-jump error
        for t, pi in trace:
            if t >= jump_tick + 10 and pi <= band:
                return t - jump_tick
        return None

    reconv = {k: ticks_to_reconverge(v) for k, v in traces.items()}
    return traces["fixed"], traces["ctrl"], scale_trace, reconv


class SyntheticSourceFactory:
    """A finite synthetic feed for the Part-6 drill: ``n_blocks`` of mixed
    signals, then ``SourceExhausted`` (so the replayed sessions drain and the
    replay loop terminates on its own)."""

    def __init__(self, m, n, P, seed, n_blocks: int = 40):
        from repro.data.sources import SyntheticSource

        self._src = SyntheticSource(MixedSignals(m=m, n=n, batch=P, seed=seed))
        self._left = n_blocks

    def next_block(self, n_samples):
        from repro.data.sources import SourceExhausted

        if self._left <= 0:
            raise SourceExhausted("demo feed drained")
        self._left -= 1
        return self._src.next_block(n_samples)


def run_elastic_drill(n_sessions: int = 8, n_blocks: int = 10):
    """Part 8: elastic capacity under a burst.

    A bank born at width 2 takes an ``n_sessions``-session burst of finite
    feeds: the autoscaler grows it while the queue holds work, the feeds
    drain and release their slots, and the autoscaler compacts + shrinks the
    width back down.  Returns the resize history, a per-tick width trace and
    the utilization arc (burst / peak / post-drain)."""
    from repro.serve import AutoscalePolicy

    m, n, P = 4, 2, 8
    ecfg = EASIConfig(n_components=n, n_features=m, mu=2e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)
    pol = AutoscalePolicy(max_streams=8, min_streams=2, cooldown_ticks=2)
    svc = SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=2),
        seed=0,
        autoscale=pol,
        max_queue=n_sessions,
    )
    # compile the whole power-of-two ladder up front: the first post-resize
    # tick then pays zero XLA compile (the bench's resize-overhead gate)
    svc.prewarm([2, 4, 8])
    for k in range(n_sessions):
        # the last session's feed outlives the burst: it ends up stranded in
        # a HIGH slot when the others drain, so the shrink has to compact —
        # the full grow → drain → compact → shrink arc in one drill
        blocks = n_blocks * 4 if k == n_sessions - 1 else n_blocks
        svc.admit(
            f"s{k}",
            source=SyntheticSourceFactory(m, n, P, seed=k, n_blocks=blocks),
        )
    util_burst = svc.metrics["bank_utilization"]
    widths, util_peak = [], 0.0
    for _ in range(80):
        svc.run_tick()
        widths.append(svc.bank.n_streams)
        util_peak = max(util_peak, svc.metrics["bank_utilization"])
        if svc.n_active == 0 and svc.bank.n_streams == pol.min_streams:
            break
    metrics = svc.metrics
    return {
        "history": svc.lifecycle["resize_history"],
        "widths": widths,
        "util_burst": util_burst,
        "util_peak": util_peak,
        "util_final": metrics["bank_utilization"],
        "n_grows": int(metrics["n_grows"]),
        "n_shrinks": int(metrics["n_shrinks"]),
        "n_compactions": int(metrics["n_compactions"]),
        "final_width": svc.bank.n_streams,
    }


def main():
    print("streaming 4000 mini-batches with a slowly rotating mixing matrix")
    print(f"{'step':>6} | {'SGD':>8} | {'SMBGD γ=0.5':>12}")
    sgd = dict(run("sgd", gamma=0.0))
    smb = dict(run("smbgd", gamma=0.5))
    for step in sorted(sgd):
        print(f"{step:6d} | {sgd[step]:8.4f} | {smb[step]:12.4f}")
    final_sgd, final_smb = list(sgd.values())[-1], list(smb.values())[-1]
    print(
        f"\nfinal tracking Amari index: SGD {final_sgd:.4f}  vs  SMBGD {final_smb:.4f}"
        f"  ({'SMBGD tracks better' if final_smb < final_sgd else 'comparable'})"
    )

    S = 8
    print(f"\nSeparatorBank: {S} drifting sessions, one fused step per tick")
    pis = run_bank(n_streams=S)
    per = "  ".join(f"{float(p):.3f}" for p in pis)
    print(f"per-stream tracking Amari index after 2000 ticks: {per}")
    print(f"worst stream: {float(jnp.max(pis)):.4f} (each stream has its own "
          "sources, mixing matrix and drift phase)")

    n_slots, n_sessions = 4, 10
    print(f"\nSeparationService: {n_sessions} sessions contending for "
          f"{n_slots} slots (convergence-aware lifecycle)")
    events, finished, metrics = run_service(n_slots, n_sessions)
    for tick, kind, sid, extra in events:
        print(f"  tick {int(tick):4d}  {kind:<5}  {sid:<8}  {extra}")
    ticks = {sid: int(rec.stats.ticks) for sid, rec in finished.items()}
    print(f"all {len(finished)} sessions served and auto-evicted in "
          f"{int(metrics['n_ticks'])} ticks "
          f"(per-session data ticks: min {min(ticks.values())}, "
          f"max {max(ticks.values())}); queue drained via same-tick backfill")

    print("\nDrift-aware pipeline: a channel-bank recording (memory-mapped "
          ".npy,\nno ground truth) whose mixing rotates ~1.2 rad mid-run")
    events, trace, first_converged = run_drift_recording()
    for tick, kind, sid, extra in events:
        print(f"  tick {tick:4d}  {kind:<5}  {sid:<8}  {extra}")
    pre = [pi for t, pi in trace if t < 300]
    post_jump = [pi for t, pi in trace if 300 <= t < 400]
    final = trace[-1][1]
    print(f"tracking Amari index: {pre[-1]:.3f} just before the jump → "
          f"{max(post_jump):.3f} at the jump → {final:.3f} after "
          f"watchdog-boosted re-adaptation")
    print("(a policy-only service would have evicted at tick "
          f"{first_converged} and served the stale separator forever — "
          "see `stream_throughput.py --drift` for the measured gap)")

    print("\nFault containment: three sessions, one poisoned feed (NaN "
          "bursts),\none flaky feed (transient raises), one clean")
    events, metrics, statuses = run_containment()
    for tick, action, sid, word in events:
        print(f"  tick {tick:4d}  {action:<10}  {sid:<8}  kernel saw: {word}")
    print("final status: " + "  ".join(f"{s}={st}" for s, st in statuses.items()))
    print(f"counters: {int(metrics['n_rollbacks'])} rollbacks, "
          f"{int(metrics['n_quarantined'])} still in quarantine, "
          f"{int(metrics['n_diverged'])} diverged, "
          f"{int(metrics['n_source_retries'])} source retries, "
          f"{int(metrics['n_degraded_ticks'])} degraded ticks")
    print("(the kernel refused every poisoned commit in-register — the "
          "rollback/quarantine\nladder and the retry wrapper kept all three "
          "sessions' state finite; see\n`stream_throughput.py --health` for "
          "the overhead gate and `pytest -m chaos`\nfor the full drill suite)")

    print("\nLatency SLOs: record a 2-session live run, replay the trace "
          "under a\ndeadline budget (time-to-ready clock, not dispatch time)")
    live_m, rep_m, miss_rate, budget = run_slo_replay()
    print(f"live   : p50 {live_m['p50_tick_s']*1e3:.2f}ms  "
          f"p99 {live_m['p99_tick_s']*1e3:.2f}ms  "
          f"p999 {live_m['p999_tick_s']*1e3:.2f}ms over "
          f"{int(live_m['n_timed_ticks'])} ticks")
    print(f"replay : p50 {rep_m['p50_tick_s']*1e3:.2f}ms  "
          f"p99 {rep_m['p99_tick_s']*1e3:.2f}ms  "
          f"budget {budget*1e3:.2f}ms (5x live p50) -> "
          f"{int(rep_m['n_deadline_misses'])} misses "
          f"(miss rate {miss_rate:.3f})")
    print("(same blocks, same eviction order, bit-identical outputs — the "
          "recorded\ntrace is the load test; the demo tails include "
          "first-tick XLA compiles,\nwhich `stream_throughput.py --slo` — "
          "the CI-gated version over the\nchecked-in trace — warms away)")

    print("\nAdaptive μ: the same abrupt rotation served twice — fixed "
          "μ-boost vs the\nmoment-scaled controller over in-kernel "
          "[Σy², Σy⁴] telemetry")
    tr_fixed, tr_ctrl, scales, reconv = run_moment_drill()
    peak = max(s for _, s in scales)
    peak_tick = max(scales, key=lambda ts: ts[1])[0]
    final_scale = scales[-1][1]
    print(f"controller μ multiplier: 1.0 before the jump → {peak:.2f} peak "
          f"at tick {peak_tick} → {final_scale:.2f} after annealing "
          "(closed loop: scales with the kurtosis deviation, returns to "
          "base on its own)")
    fmt = lambda v: f"{v} ticks" if v is not None else "never"
    print(f"ticks to re-converge after the jump: fixed boost "
          f"{fmt(reconv['fixed'])}  vs  moment-scaled {fmt(reconv['ctrl'])}")
    print("(the fixed boost is open-loop — μ×4 for exactly 40 ticks, "
          "need it or not;\nsee `stream_throughput.py --adapt` for the "
          "CI-gated re-convergence ratio\nand the ≤5% telemetry HBM bar)")

    print("\nElastic capacity: an 8-session burst against a width-2 bank, "
          "autoscaler on\n(grow under queue pressure, compact+shrink after "
          "the drain)")
    drill = run_elastic_drill()
    for ev in drill["history"]:
        print(f"  tick {ev['tick']:4d}  {ev['action']:<8} "
              f"{ev['from']:>2} -> {ev['to']:<2}  ({ev['reason']})")
    arc = " ".join(str(w) for w in drill["widths"][:12])
    print(f"width per tick: {arc} ...")
    print(f"utilization: {drill['util_burst']:.2f} at the burst (queue "
          f"stranded) -> {drill['util_peak']:.2f} peak after growth -> "
          f"{drill['util_final']:.2f} after the drain at width "
          f"{drill['final_width']}")
    print(f"counters: {drill['n_grows']} grows, {drill['n_shrinks']} "
          f"shrinks, {drill['n_compactions']} compactions — every resize a "
          "prefix copy, every\ncompaction a verbatim row move; co-tenant "
          "trajectories bit-identical to a\nfixed-width run (see "
          "tests/test_elastic.py and `stream_throughput.py --elastic`)")


if __name__ == "__main__":
    main()
