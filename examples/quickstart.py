"""Quickstart: blind source separation with EASI + SMBGD (the paper's system).

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's benchmark setting (m=4 observed mixtures of n=2
independent sources, fp32, cubic nonlinearity), trains the adaptive separator
with the SMBGD update rule (Eq. 1), and reports the Amari separation index and
the SGD-vs-SMBGD comparison on the same problem.

``AdaptiveICA`` is the single-stream front-end (``algorithm`` selects
``sgd | smbgd_sequential | smbgd_batched``; ``use_pallas=True`` routes the
gradient sum through the fused Pallas kernel — interpreted on CPU by default,
set ``REPRO_PALLAS_INTERPRET=0`` on real TPU).  To run many separation
sessions at once as one fused program, see ``repro.stream.SeparatorBank``
(examples/adaptive_stream.py) and ``serve.engine.SeparationService``.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.core import (
    AdaptiveICA,
    EASIConfig,
    SMBGDConfig,
    amari_index,
    global_system,
)
from repro.data import signals


def main():
    key = jax.random.PRNGKey(0)
    # The paper's problem: 2 independent sub-Gaussian sources, 4 mixtures.
    A, S, X = signals.make_problem(key, m=4, n=2, T=40_000)
    print(f"mixing matrix A (hidden from the separator):\n{A}")

    easi_cfg = EASIConfig(n_components=2, n_features=4, mu=2e-3, nonlinearity="cubic")
    smbgd_cfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)

    for algo in ("sgd", "smbgd"):
        ica = AdaptiveICA(easi_cfg, smbgd_cfg, algorithm=algo)
        state = ica.init(jax.random.PRNGKey(42))
        pi0 = float(ica.performance_index(state, A))
        state, Y = ica.fit(state, X)
        pi = float(ica.performance_index(state, A))
        # deployment: separate fresh data with the frozen separator
        _, S2, X2 = signals.make_problem(jax.random.PRNGKey(1), m=4, n=2, T=1_000)
        Y2 = ica.transform(state, X2)
        print(
            f"[{algo:5s}] amari index: {pi0:.3f} -> {pi:.4f}   "
            f"(0 = perfect separation); deployed on {Y2.shape[0]} fresh samples"
        )

    # correlation of recovered vs true sources (up to permutation/sign)
    ica = AdaptiveICA(easi_cfg, smbgd_cfg)
    state = ica.init(jax.random.PRNGKey(42))
    state, _ = ica.fit(state, X)
    Y = ica.transform(state, X[-5000:])
    St = S[-5000:]
    C = jnp.corrcoef(Y.T, St.T)[:2, 2:]
    print(f"|corr(recovered, true)| (rows should each have one ~1 entry):\n{jnp.abs(C)}")


if __name__ == "__main__":
    main()
