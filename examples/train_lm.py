"""End-to-end LM training driver with the SMBGD optimizer — the paper's
"SMBGD is not limited to EASI" claim, exercised on a real model.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300   # the full ~100M run
    PYTHONPATH=src python examples/train_lm.py --arch smollm-135m ...      # any zoo arch

Features on display: fault-tolerant Trainer (async checkpoints, auto-resume —
re-run the same command after killing it and it continues), SMBGD vs AdamW
(--optimizer), microbatched SMBGD accumulation (--microbatches).
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax

from repro.configs.registry import get_config
from repro.data.pipeline import make_lm_pipeline
from repro.optim.optimizers import adamw
from repro.optim.smbgd import smbgd
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~1M params: CI-speed sanity run
    "tiny": dict(arch="smollm-135m", d_model=128, n_layers=4, seq=128, batch=8),
    # ~100M params: the deliverable's end-to-end run (hours on 1 CPU core;
    # the intended host is a TPU slice via launch/train.py)
    "100m": dict(arch="smollm-135m", d_model=None, n_layers=None, seq=512, batch=16),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--arch", default=None)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--optimizer", default="smbgd", choices=["smbgd", "adamw"])
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = get_config(args.arch or p["arch"])
    if p["d_model"]:
        cfg = dataclasses.replace(
            cfg, d_model=p["d_model"], n_layers=p["n_layers"], n_heads=4,
            n_kv_heads=1, head_dim=32, d_ff=4 * p["d_model"], vocab_size=4096,
            dtype="float32", remat=False,
        )
    else:
        cfg = dataclasses.replace(cfg, dtype="float32", remat=False)

    from repro.models.model import count_params, init_params

    n_params = count_params(jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg)))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M optimizer={args.optimizer}")

    pipe = make_lm_pipeline(cfg, seq_len=p["seq"], global_batch=p["batch"], seed=0)
    tx = (
        smbgd(args.lr, gamma=0.9, beta=0.98, microbatches=args.microbatches)
        if args.optimizer == "smbgd"
        else adamw(args.lr / 10)
    )
    tcfg = TrainerConfig(
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        log_every=20,
        microbatches=args.microbatches,
        smbgd_beta=0.98 if args.optimizer == "smbgd" else 1.0,
        metrics_path=str(Path(args.ckpt_dir) / "metrics.jsonl"),
    )
    trainer = Trainer(cfg, tx, tcfg)

    t0 = time.time()

    def on_step(step, loss):
        if step % 20 == 0:
            print(f"step {step:5d}  loss {loss:.4f}  ({time.time()-t0:.0f}s)")

    _, _, losses = trainer.fit(jax.random.PRNGKey(0), pipe, args.steps, on_step)
    if losses:
        print(
            f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps "
            f"({(time.time()-t0):.0f}s); checkpoints in {args.ckpt_dir}"
        )
    else:
        print("nothing to do (already trained to --steps; delete ckpt dir to restart)")


if __name__ == "__main__":
    main()
