"""Config schema for every assigned architecture (``--arch <id>``).

One ``ModelConfig`` describes any member of the zoo; family-specific fields are
zero/empty when unused.  ``reduced()`` derives the CPU smoke-test variant of the
same family (small widths, few layers/experts) used by tests; the full config is
only ever lowered via ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | gemma2 | moe | xlstm | zamba2
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    rmsnorm_eps: float = 1e-6

    # gemma2-style
    sliding_window: int = 0  # window for "local" layers (0 = none)
    alt_local_global: bool = False  # alternate local/global attention
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    sandwich_norm: bool = False  # extra post-attn / post-mlp norms
    query_scale: Optional[float] = None  # override 1/sqrt(head_dim)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    expert_d_ff: int = 0
    moe_dense_residual: bool = False  # arctic: parallel dense MLP branch
    n_shared_experts: int = 0  # kimi: always-on shared expert(s)
    first_dense_layers: int = 0  # kimi: leading dense layers
    router_jitter: float = 0.0
    load_balance_coef: float = 0.0
    capacity_factor: float = 1.25  # expert-buffer slack (drops above capacity)

    # SSM / xLSTM
    ssm_state: int = 0  # Mamba2 N (state per head)
    ssm_heads: int = 0  # Mamba2 / mLSTM heads (defaults to n_heads)
    ssm_expand: int = 2  # input expansion factor
    conv_width: int = 4
    slstm_every: int = 0  # xlstm: every k-th block is sLSTM (0 = none)
    ssm_chunk: int = 256  # SSD chunk length
    mlstm_chunk: int = 0  # xlstm: chunkwise mLSTM (0 = quadratic parallel form)

    # zamba2 hybrid
    shared_attn_period: int = 0  # apply shared attn block after every k mamba blocks
    lora_rank: int = 0  # per-invocation LoRA on the shared block

    # modality frontends (stubs — see DESIGN.md)
    n_codebooks: int = 0  # musicgen: EnCodec codebooks (inputs (B,T,K))
    vision_tokens: int = 0  # internvl: prepended precomputed patch embeddings

    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    use_pallas_attn: bool = False

    # sharding policy (see repro/sharding/rules.py)
    fsdp: bool = False  # shard params over the data axis too (zero-3)
    sequence_parallel: bool = False  # shard long KV caches over 'model'
    dp_only: bool = False  # replicate params, batch over ALL mesh axes
    attn_softmax_dtype: str = "float32"  # "bfloat16" halves the T² score traffic

    def __post_init__(self):
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be divisible by n_kv_heads")

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def ssm_heads_(self) -> int:
        return self.ssm_heads or self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "xlstm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM/hybrid state, no dense KV)."""
        return self.family in ("xlstm", "zamba2")

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/topology, tiny dims."""
        return dataclasses.replace(
            self,
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=1 if self.n_heads // self.n_kv_heads > 1 else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=8 if self.n_experts else 0,
            experts_per_token=min(2, self.experts_per_token) if self.n_experts else 0,
            expert_d_ff=64 if self.expert_d_ff else 0,
            first_dense_layers=min(1, self.first_dense_layers),
            ssm_state=16 if self.ssm_state else 0,
            ssm_heads=2 if self.ssm_heads else 0,
            ssm_chunk=16,
            sliding_window=32 if self.sliding_window else 0,
            shared_attn_period=2 if self.shared_attn_period else 0,
            lora_rank=min(8, self.lora_rank),
            slstm_every=4 if self.slstm_every else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            dtype="float32",
            remat=False,
            fsdp=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
