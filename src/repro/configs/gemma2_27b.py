"""Gemma2-27B [arXiv:2408.00118] — local(4k sliding)/global alternating
attention, logit softcapping (attn 50, final 30), sandwich RMSNorms, GeGLU.

46L, d_model 4608, 32 heads (GQA kv=16), head_dim 128, d_ff 36864, vocab 256000.
Query scale: gemma2-27b uses 1/sqrt(d_model/n_heads) = 1/12 (not head_dim)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="gemma2",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    tie_embeddings=True,
    rope_theta=10_000.0,
    sliding_window=4096,
    alt_local_global=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sandwich_norm=True,
    query_scale=(4608 / 32) ** -0.5,
    fsdp=True,
)
