"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention
block (tied weights, per-invocation LoRA) applied every 6 mamba blocks.

54 mamba2 layers (d_state 64, headdim 64), shared attn block: 32 heads MHA,
d_model 2560.  Runs long_500k: SSM state is O(1); the shared attention block
uses a 4096-token sliding-window ring cache in the long-context cell (the
sub-quadratic adaptation recorded in DESIGN.md §5)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="zamba2",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_heads=80,  # d_inner 5120 / headdim 64
    ssm_expand=2,
    conv_width=4,
    ssm_chunk=256,
    shared_attn_period=6,
    lora_rank=128,
    sliding_window=4096,  # ring-cache window for the shared block (long ctx)
)
