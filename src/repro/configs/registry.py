"""Registry: ``--arch <id>`` → ModelConfig.  One module per assigned arch."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

ARCH_IDS: List[str] = [
    "minitron-8b",
    "smollm-135m",
    "mistral-nemo-12b",
    "gemma2-27b",
    "xlstm-1.3b",
    "musicgen-large",
    "zamba2-2.7b",
    "kimi-k2-1t-a32b",
    "arctic-480b",
    "internvl2-76b",
    # the paper's own workload, as a selectable "arch" for benches/examples
    "easi-ica",
]

_MODULES: Dict[str, str] = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_lm_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS if a != "easi-ica"}
