"""MusicGen-large [arXiv:2306.05284; hf] — decoder-only LM over EnCodec tokens.

48L, d_model 2048, 32 heads (MHA kv=32), d_ff 8192, vocab 2048 per codebook,
4 codebooks (delay pattern handled as data layout).  The EnCodec frontend is a
STUB: inputs are the 4-codebook token grid (B, T, 4); embeddings are summed."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="dense",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    rope_theta=10_000.0,
)
