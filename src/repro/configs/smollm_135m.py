"""SmolLM-135M — llama-arch small model [hf:HuggingFaceTB/SmolLM-135M].

30L, d_model 576, 9 heads (GQA kv=3), d_ff 1536, vocab 49152.  Also the ~100M
end-to-end training example (examples/train_lm.py)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49_152,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
