"""Snowflake Arctic (480B) [hf:Snowflake/snowflake-arctic-base] — dense-MoE
hybrid: every layer has a top-2-of-128 MoE *plus* a parallel dense residual MLP.

35L, d_model 7168, 56 heads (GQA kv=8), dense d_ff 4864 (residual branch),
per-expert d_ff 4864, vocab 32000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    n_experts=128,
    experts_per_token=2,
    expert_d_ff=4864,
    moe_dense_residual=True,
    load_balance_coef=0.01,
    rope_theta=10_000.0,
    fsdp=True,
)
