"""InternVL2-Llama3-76B [arXiv:2404.16821; unverified] — InternViT-6B frontend
+ 76B LM backbone (Llama3-70B-arch: 80L, d_model 8192, 64H GQA kv=8,
d_ff 28672, vocab 128256).

The vision tower is a STUB per the assignment: ``input_specs()`` feeds 256
precomputed patch embeddings per image, prepended to the text sequence; loss is
computed on text positions only."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    vision_tokens=256,
    rope_theta=500_000.0,
    fsdp=True,
)
