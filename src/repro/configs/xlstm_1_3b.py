"""xLSTM-1.3B [arXiv:2405.04517; unverified] — 48 blocks, d_model 2048,
4 heads, xLSTM[7:1] (every 8th block sLSTM), no separate FFN (d_ff=0).

Block internals follow the official v1 layers (proj factor 2, qk factor 0.5).
Runs the long_500k cell: O(1) recurrent state per block."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    slstm_every=8,
    ssm_expand=2,
    conv_width=4,
    tie_embeddings=True,
)
