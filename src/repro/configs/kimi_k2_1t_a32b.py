"""Kimi K2 (1T total / 32B active) [arXiv:2501.kimi2; unverified, paper-table].

61L, d_model 7168, 64 heads (GQA kv=8), vocab 163840; MoE: 384 experts top-8,
per-expert d_ff 2048, 1 shared expert, first layer dense (DeepSeek-V3-style —
dense d_ff = 8×2048 matching active expert width).  SMBGD's one-slot optimizer
state is what lets this cell fit 512 chips (see EXPERIMENTS.md §Dry-run)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163_840,
    n_experts=384,
    experts_per_token=8,
    expert_d_ff=2048,
    n_shared_experts=1,
    first_dense_layers=1,
    load_balance_coef=0.01,
    rope_theta=50_000.0,
    fsdp=True,
)
