"""The paper's own workload (m=4 mixtures → n=2 components, fp32, cubic
nonlinearity) as a selectable config for benches/examples.  Not an LM arch —
dry-run cells use the 10 assigned LM configs."""
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig

EASI = EASIConfig(n_components=2, n_features=4, mu=2e-3, nonlinearity="cubic")
SMBGD = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
CONFIG = (EASI, SMBGD)
