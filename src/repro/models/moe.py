"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

Covers both assigned MoE archs:
  * kimi-k2-1t-a32b — 384 experts, top-8, + always-on shared expert, first
    layer(s) dense;
  * arctic-480b — 128 experts, top-2, + *parallel dense residual* MLP branch.

Dispatch is the canonical TPU formulation: tokens are grouped, each group
builds a one-hot ``(S, E, C)`` dispatch tensor (C = per-group expert capacity)
and dispatch/combine are einsums — under pjit with tokens sharded over "data"
and experts over "model" this lowers to the expected all-to-all pair.  Dropped
tokens (over capacity) fall through the residual connection, standard for
capacity-factor routing.  The dispatch-einsum FLOPs are bookkept separately in
the roofline notes (they are mask matmuls, not model math).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common

GROUP_SIZE = 512  # tokens per dispatch group (keeps the one-hot tensor small)


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    std = 1.0 / d**0.5
    p = {
        "router": common.dense_init(kr, d, E, jnp.float32),  # router in fp32
        "w_gate": (jax.random.normal(kg, (E, d, f), jnp.float32) * std).astype(dtype),
        "w_up": (jax.random.normal(ku, (E, d, f), jnp.float32) * std).astype(dtype),
        "w_down": (
            jax.random.normal(kd, (E, f, d), jnp.float32)
            * std
            / (2 * cfg.n_layers) ** 0.5
        ).astype(dtype),
    }
    if cfg.n_shared_experts:
        from repro.models.blocks import init_mlp

        p["shared"] = init_mlp(
            ks, cfg, dtype, d_ff=cfg.expert_d_ff * cfg.n_shared_experts
        )
    return p


def _capacity(group_size: int, k: int, n_experts: int, factor: float) -> int:
    c = int(group_size * k * factor / n_experts)
    return max(8, (c + 7) // 8 * 8)  # sublane-align


def moe_fwd(
    params: dict, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, T, d) → (out (B, T, d), aux load-balance loss scalar)."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    S = min(GROUP_SIZE, B * T)
    tokens = x.reshape(-1, d)
    N = tokens.shape[0]
    assert N % S == 0, (N, S)
    G = N // S
    xg = tokens.reshape(G, S, d)

    logits = (xg.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, S, E)
    top_p, top_e = jax.lax.top_k(probs, k)  # (G, S, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize over top-k

    C = _capacity(S, k, E, cfg.capacity_factor)
    # position of each (token, choice) in its expert's buffer
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)  # (G, S, k, E)
    # priority: iterate choices in order, tokens in order (GShard policy)
    flat = onehot.reshape(G, S * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # (G, S*k, E) slot index per assignment
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, S, k)  # (G, S, k)
    keep = (pos < C) & (top_p > 0)
    gate = top_p * keep  # (G, S, k)

    # dispatch tensor (G, S, E, C) — one-hot in expert and slot
    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=xg.dtype)[..., :C]
    disp = jnp.einsum("gske,gskc->gsec", onehot.astype(xg.dtype), slot_oh)
    comb = jnp.einsum("gsk,gske,gskc->gsec", gate.astype(xg.dtype),
                      onehot.astype(xg.dtype), slot_oh)

    xe = jnp.einsum("gsd,gsec->gecd", xg, disp)  # (G, E, C, d)  [all-to-all]
    h = common.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xe, params["w_up"]
    )
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])  # (G, E, C, d)
    y = jnp.einsum("gecd,gsec->gsd", ye, comb)  # [all-to-all back]

    out = y.reshape(B, T, d)
    if cfg.n_shared_experts:
        from repro.models.blocks import mlp_fwd

        out = out + mlp_fwd(params["shared"], x, cfg)

    # Switch-style load-balance aux: E * Σ_e f_e · p̄_e
    me = jnp.mean(jnp.sum(onehot, axis=2), axis=1)  # (G, E) fraction routed
    pe = jnp.mean(probs, axis=1)  # (G, E) mean prob
    aux = E * jnp.mean(jnp.sum(me * pe, axis=-1))
    return out, aux


def init_moe_block(key, cfg: ModelConfig, dtype, dense: bool = False) -> dict:
    """Full layer: attention + (dense | MoE [+ dense residual]) FFN."""
    from repro.models.attention import init_attn
    from repro.models.blocks import init_mlp

    ka, kf, kr = jax.random.split(key, 3)
    p = {
        "attn_norm": common.init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attn(ka, cfg, dtype),
        "mlp_norm": common.init_rmsnorm(cfg.d_model, dtype),
    }
    if dense:
        p["mlp"] = init_mlp(kf, cfg, dtype, d_ff=cfg.expert_d_ff * cfg.experts_per_token)
    else:
        p["moe"] = init_moe(kf, cfg, dtype)
        if cfg.moe_dense_residual:
            p["residual_mlp"] = init_mlp(kr, cfg, dtype, d_ff=cfg.d_ff)
    return p


def moe_block_fwd(
    params: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    cache=None,
):
    """Returns (x, cache, aux)."""
    from repro.models.attention import attn_fwd
    from repro.models.blocks import mlp_fwd

    h = common.rmsnorm(params["attn_norm"], x, cfg.rmsnorm_eps)
    a, new_cache = attn_fwd(params["attn"], h, positions, cfg, cache=cache)
    x = x + a
    h = common.rmsnorm(params["mlp_norm"], x, cfg.rmsnorm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "mlp" in params:  # dense leading layer (kimi)
        m = mlp_fwd(params["mlp"], h, cfg)
    else:
        m, aux = moe_fwd(params["moe"], h, cfg)
        if cfg.moe_dense_residual:
            m = m + mlp_fwd(params["residual_mlp"], h, cfg)  # arctic parallel branch
    return x + m, new_cache, aux
