"""Mamba2 (SSD — state-space duality) block: chunked-parallel training form and
O(1)-state recurrent decode.  Used by zamba2 (hybrid backbone).

Scalar-per-head decay: h_t = exp(A·dt_t)·h_{t-1} + dt_t·(B_t ⊗ x_t), y_t = C_tᵀh_t + D·x_t
with x (…, H, P), B/C shared across heads (n_groups=1), state N per head.

Training uses the standard chunked algorithm: intra-chunk quadratic term +
inter-chunk state scan (T/chunk steps of lax.scan) — sub-quadratic overall and
the reason the zamba/xlstm cells are the ones that run long_500k.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common


class SSMState(NamedTuple):
    conv: jnp.ndarray  # (B, conv_width-1, conv_dim) — conv1d tail
    h: jnp.ndarray  # (B, H, P, N) — SSM state
    length: jnp.ndarray  # () int32


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads_
    P = d_inner // H
    N = cfg.ssm_state
    return d_inner, H, P, N


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N  # x, B, C all convolved
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "norm": common.init_rmsnorm(d, dtype),
        # in_proj → [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
        "in_proj": common.dense_init(k1, d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_width, conv_dim), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": common.init_rmsnorm(d_inner, dtype),
        "out_proj": common.dense_init(
            k3, d_inner, d, dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d over time. x (B, T, C), w (W, C).  ``tail``
    (B, W-1, C) prepends streaming context (decode); else zero-pad."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, T+W-1, C)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    return out + b[None, None, :]


def _ssd_chunked(
    xh: jnp.ndarray,  # (B, T, H, P)
    dt: jnp.ndarray,  # (B, T, H) — softplus'd
    A: jnp.ndarray,  # (H,) — negative decay rates
    Bm: jnp.ndarray,  # (B, T, N)
    Cm: jnp.ndarray,  # (B, T, N)
    chunk: int,
    h0: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. Returns (y (B,T,H,P), h_final (B,H,P,N))."""
    Bsz, T, H, P = xh.shape
    N = Bm.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    # reshape into chunks
    xc = xh.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    a = dtc * (-jnp.exp(A))[None, None, None, :]  # (B,nc,Q,H) log-decay ≤ 0
    a_cum = jnp.cumsum(a, axis=2)  # within-chunk cumulative

    # intra-chunk: L[i,j] = exp(a_cum_i − a_cum_j) for i ≥ j (else 0).
    # Mask BEFORE exp: the i<j region has positive exponents that overflow,
    # and a post-exp where() would still leak inf into the backward pass.
    diff = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Li = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -1e30))
    # scores[i,j] = C_i·B_j — shared across heads (n_groups=1)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    M = scores[..., None] * Li  # (B,nc,Q,Q,H)
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # dt-weighted input
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xdt)

    # chunk-final states: S_c = Σ_j exp(a_end − a_cum_j)·B_j ⊗ (dt_j x_j)
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B,nc,Q,H)
    S = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_to_end, Bc.astype(jnp.float32), xdt)

    # inter-chunk recurrence (lax.scan over chunks)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def body(h, inp):
        S_c, dec = inp  # (B,H,P,N), (B,H)
        h_out = h  # state *entering* the chunk
        h = h * dec[:, :, None, None] + S_c
        return h, h_out

    Ss = jnp.moveaxis(S, 1, 0)  # (nc,B,H,P,N)
    decs = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,B,H)
    h_final, h_in = jax.lax.scan(body, h0, (Ss, decs))
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,nc,H,P,N) state entering each chunk

    # inter-chunk output: y_off_i = exp(a_cum_i)·C_i · h_in
    inner_decay = jnp.exp(a_cum)  # (B,nc,Q,H)
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc.astype(jnp.float32), h_in, inner_decay)

    y = (y_intra + y_off).reshape(Bsz, T, H, P)
    return y, h_final


def mamba2_fwd(
    params: dict,
    x: jnp.ndarray,  # (B, T, d)
    cfg: ModelConfig,
    state: Optional[SSMState] = None,
) -> Tuple[jnp.ndarray, Optional[SSMState]]:
    """Full block: norm → in_proj → conv → SSD → gate → out_proj (+residual)."""
    Bsz, T, d = x.shape
    d_inner, H, P, N = _dims(cfg)
    h = common.rmsnorm(params["norm"], x, cfg.rmsnorm_eps)
    zxbcdt = h @ params["in_proj"]
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    tail = state.conv if state is not None else None
    conv_out = common.silu(_causal_conv(conv_in, params["conv_w"], params["conv_b"], tail))
    xin, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,T,H)
    xh = xin.reshape(Bsz, T, H, P)
    A = params["A_log"]

    if state is None:
        y, _ = _ssd_chunked(xh, dt, A, Bm, Cm, min(cfg.ssm_chunk, T))
        new_state = None
    else:
        if T != 1:
            raise NotImplementedError("streaming mamba2 is decode-only (T=1)")
        decay = jnp.exp(dt[:, 0, :] * (-jnp.exp(A))[None, :])  # (B,H)
        dBx = jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, 0, :], Bm[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
        )
        h_new = state.h * decay[:, :, None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h_new)
        y = y[:, None].reshape(Bsz, 1, H, P)
        new_conv = jnp.concatenate([state.conv[:, 1:], conv_in], axis=1)
        new_state = SSMState(conv=new_conv, h=h_new, length=state.length + 1)

    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(Bsz, T, d_inner).astype(x.dtype)
    y = common.rmsnorm(params["out_norm"], y * common.silu(z), cfg.rmsnorm_eps)
    return x + y @ params["out_proj"], new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    return SSMState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        h=jnp.zeros((batch, H, P, N), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )
