"""Shared model building blocks: norms, rotary embeddings, initializers.

All modules are functional: ``init_*`` returns a params pytree (nested dicts of
arrays), ``*_fwd`` consumes it.  Layer-stacked parameters carry a leading ``L``
axis and are consumed by ``lax.scan`` (compile-time O(1) in depth).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# -- initializers -------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (LM standard)."""
    std = scale / (in_dim**0.5)
    return (
        jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim), jnp.float32)
        * std
    ).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    # std = dim^-1/2: unit-scale logits under tied heads, and the gemma-style
    # sqrt(d) input rescale restores O(1) embedding outputs.
    return (
        jax.random.truncated_normal(key, -2.0, 2.0, (vocab, dim), jnp.float32)
        / dim**0.5
    ).astype(dtype)


# -- norms --------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype) -> PyTree:
    return {"scale": jnp.zeros((dim,), dtype)}


def rmsnorm(params: PyTree, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with (1 + scale) parameterization (gemma/llama convention)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# -- rotary position embeddings ----------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x (B, H, T, d), positions (B, T) or (T,) — rotate pairs (even, odd)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,T,d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# -- misc ---------------------------------------------------------------------


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x, approximate=True)


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(x)


def cross_entropy_loss(
    logits: jnp.ndarray,  # (..., V) — any leading dims
    labels: jnp.ndarray,  # (...) int32
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Mean token CE in fp32; `mask` zeroes padded / non-text positions."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def stacked_init(init_fn: Callable[[jax.Array], PyTree], key: jax.Array, n: int) -> PyTree:
    """vmap an init over a leading layer axis → scan-ready stacked params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)
