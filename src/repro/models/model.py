"""Model assembly: config → init / loss / prefill / decode_step.

Layer stacks are scan-compatible: per-layer params are stacked on a leading
axis and executed with ``lax.scan`` (compile time O(1) in depth; remat policy
applied to the scan body).  Families with repeating patterns scan over
*groups*:

  dense                  scan over L identical blocks
  gemma2                 scan over L/2 (local, global) pairs
  moe                    unstacked leading dense layers (kimi) + scan over rest
  xlstm                  scan over L/period groups of (period-1 mLSTM + 1 sLSTM)
  zamba2                 scan over L/period groups of `period` mamba2 blocks,
                         shared attention block (tied weights + per-invocation
                         LoRA) applied between groups

Serving state (KV caches / SSM states) is stacked along the same axis and
threaded through the scan as xs/ys.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common
from repro.models import blocks as blocks_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.attention import KVCache, make_cache

PyTree = Any

REMAT_POLICY = jax.checkpoint_policies.dots_with_no_batch_dims_saveable


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn, policy=REMAT_POLICY) if cfg.remat else fn


def _apply_stack(body, carry, xs, cfg: ModelConfig):
    """lax.scan over the stacked layer-group axis, or an unrolled python loop
    when cfg.scan_layers=False (used by the dry-run's body-cost reconstruction —
    cost_analysis counts while bodies once, unrolled HLO counts every group)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
    params: Dict[str, Any] = {}

    # embeddings ------------------------------------------------------------
    if cfg.n_codebooks:  # musicgen: one table per codebook
        keys = jax.random.split(k_emb, cfg.n_codebooks)
        params["embed"] = jnp.stack(
            [common.embed_init(k, cfg.vocab_size, cfg.d_model, dt) for k in keys]
        )  # (K, V, d)
    else:
        params["embed"] = common.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt)

    # layer stacks ------------------------------------------------------------
    fam = cfg.family
    if fam in ("dense", "gemma2"):
        period = 2 if cfg.alt_local_global else 1
        assert cfg.n_layers % period == 0

        def group_init(k):
            ks = jax.random.split(k, period)
            return {f"b{i}": blocks_lib.init_block(ks[i], cfg, dt) for i in range(period)}

        params["layers"] = common.stacked_init(group_init, k_layers, cfg.n_layers // period)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            kd, k_layers = jax.random.split(k_layers)
            ks = jax.random.split(kd, nd)
            params["dense_layers"] = [
                moe_lib.init_moe_block(ks[i], cfg, dt, dense=True) for i in range(nd)
            ]
        params["layers"] = common.stacked_init(
            lambda k: moe_lib.init_moe_block(k, cfg, dt, dense=False),
            k_layers,
            cfg.n_layers - nd,
        )
    elif fam == "xlstm":
        period = cfg.slstm_every or cfg.n_layers
        assert cfg.n_layers % period == 0

        def group_init(k):
            ks = jax.random.split(k, period)
            g = {
                f"m{i}": xlstm_lib.init_mlstm(ks[i], cfg, dt)
                for i in range(period - 1)
            }
            g["s"] = xlstm_lib.init_slstm(ks[-1], cfg, dt)
            return g

        params["layers"] = common.stacked_init(group_init, k_layers, cfg.n_layers // period)
    elif fam == "zamba2":
        period = cfg.shared_attn_period
        assert cfg.n_layers % period == 0
        n_groups = cfg.n_layers // period

        def group_init(k):
            ks = jax.random.split(k, period)
            return {f"m{i}": mamba_lib.init_mamba2(ks[i], cfg, dt) for i in range(period)}

        params["layers"] = common.stacked_init(group_init, k_layers, n_groups)
        ks1, ks2 = jax.random.split(k_extra)
        params["shared_block"] = blocks_lib.init_block(ks1, cfg, dt)
        if cfg.lora_rank:
            d, r = cfg.d_model, cfg.lora_rank
            qkv_dim = cfg.n_heads * cfg.head_dim_ + 2 * cfg.n_kv_heads * cfg.head_dim_

            def lora_init(k):
                ka, kb = jax.random.split(k)
                return {
                    "A": common.dense_init(ka, d, r, dt),
                    "B": jnp.zeros((r, qkv_dim), dt),
                }

            params["shared_lora"] = common.stacked_init(lora_init, ks2, n_groups)
    else:  # pragma: no cover
        raise ValueError(f"unknown family {fam}")

    # output ------------------------------------------------------------------
    params["final_norm"] = common.init_rmsnorm(cfg.d_model, dt)
    if cfg.n_codebooks:
        keys = jax.random.split(k_head, cfg.n_codebooks)
        params["lm_head"] = jnp.stack(
            [common.dense_init(k, cfg.d_model, cfg.vocab_size, dt) for k in keys]
        )  # (K, d, V)
    elif not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(k_head, cfg.d_model, cfg.vocab_size, dt)
    return params


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def embed_inputs(params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig) -> jnp.ndarray:
    if cfg.n_codebooks:
        toks = batch["tokens"]  # (B, T, K)
        x = sum(
            jnp.take(params["embed"][k], toks[..., k], axis=0)
            for k in range(cfg.n_codebooks)
        )
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)  # (B, T, d)
    if cfg.family == "gemma2":
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.vision_tokens and "vision_embeds" in batch:
        x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x], axis=1)
    return x


def lm_logits(params: PyTree, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = common.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    if cfg.n_codebooks:
        logits = jnp.einsum("btd,kdv->btkv", x, params["lm_head"])
    elif cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return common.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


# ---------------------------------------------------------------------------
# forward (training / prefill — full sequence, optional cache build)
# ---------------------------------------------------------------------------


def _zamba_shared(params, lora, x, positions, cfg):
    """Shared attention block with per-invocation LoRA folded into wq."""
    p = params["shared_block"]
    if lora is not None:
        # LoRA on the fused qkv input projection: x·(A·B) added to q projection
        delta = (x @ lora["A"]) @ lora["B"]
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        dq, dk, _ = hq * dh, hkv * dh, hkv * dh
        h = common.rmsnorm(p["attn_norm"], x, cfg.rmsnorm_eps)
        # emulate fused-qkv LoRA by splitting delta
        d_q, d_k, d_v = jnp.split(delta, [dq, dq + dk], axis=-1)
        patched = dict(p["attn"])
        out, _ = _attn_with_delta(patched, h, (d_q, d_k, d_v), positions, cfg)
        x = x + out
        hm = common.rmsnorm(p["mlp_norm"], x, cfg.rmsnorm_eps)
        return x + blocks_lib.mlp_fwd(p["mlp"], hm, cfg)
    out, _ = blocks_lib.block_fwd(p, x, positions, cfg)
    return out


def _attn_with_delta(params, h, deltas, positions, cfg):
    from repro.models.attention import _merge_heads, _split_heads, attention_op

    d_q, d_k, d_v = deltas
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = _split_heads(h @ params["wq"] + d_q, hq)
    k = _split_heads(h @ params["wk"] + d_k, hkv)
    v = _split_heads(h @ params["wv"] + d_v, hkv)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.query_scale if cfg.query_scale is not None else dh**-0.5
    o = attention_op(
        q, k, v, scale=scale, causal=True, window=0,
        softcap=cfg.attn_logit_softcap, use_pallas=cfg.use_pallas_attn,
    )
    return _merge_heads(o) @ params["wo"], None


def forward(
    params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    x = embed_inputs(params, batch, cfg)
    B, T, _ = x.shape
    positions = jnp.arange(T)
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "gemma2"):
        period = 2 if cfg.alt_local_global else 1

        def body(x, layer_params):
            for i in range(period):
                window = blocks_lib.layer_window(cfg, i)
                x, _ = blocks_lib.block_fwd(
                    layer_params[f"b{i}"], x, positions, cfg, window=window
                )
            return x, None

        x, _ = _apply_stack(_maybe_remat(body, cfg), x, params["layers"], cfg)
    elif fam == "moe":
        for lp in params.get("dense_layers", []):
            x, _, _ = moe_lib.moe_block_fwd(lp, x, positions, cfg)

        def body(carry, layer_params):
            x, aux = carry
            x, _, a = moe_lib.moe_block_fwd(layer_params, x, positions, cfg)
            return (x, aux + a), None

        (x, aux), _ = _apply_stack(_maybe_remat(body, cfg), (x, aux), params["layers"], cfg)
    elif fam == "xlstm":
        period = cfg.slstm_every or cfg.n_layers

        def body(x, gp):
            for i in range(period - 1):
                x, _ = xlstm_lib.mlstm_fwd(gp[f"m{i}"], x, cfg)
            x, _ = xlstm_lib.slstm_fwd(gp["s"], x, cfg)
            return x, None

        x, _ = _apply_stack(_maybe_remat(body, cfg), x, params["layers"], cfg)
    elif fam == "zamba2":
        period = cfg.shared_attn_period
        lora = params.get("shared_lora")

        def body(x, xs):
            gp, lora_g = xs
            for i in range(period):
                x, _ = mamba_lib.mamba2_fwd(gp[f"m{i}"], x, cfg)
            x = _zamba_shared(params, lora_g, x, positions, cfg)
            return x, None

        xs = (params["layers"], lora)
        x, _ = _apply_stack(_maybe_remat(body, cfg), x, xs, cfg)
    else:  # pragma: no cover
        raise ValueError(fam)

    return lm_logits(params, x, cfg), aux


def loss_fn(
    params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token LM loss (text positions only for VLM; mean over codebooks
    for audio).  Returns (loss, metrics)."""
    logits, aux = forward(params, batch, cfg)
    toks = batch["tokens"]
    if cfg.n_codebooks:
        lg = logits[:, :-1]  # (B, T-1, K, V)
        lbl = toks[:, 1:]  # (B, T-1, K)
        ce = common.cross_entropy_loss(lg, lbl)
    elif cfg.vision_tokens:
        lg = logits[:, cfg.vision_tokens : -1]  # text positions
        lbl = toks[:, 1:]
        ce = common.cross_entropy_loss(lg, lbl)
    else:
        ce = common.cross_entropy_loss(logits[:, :-1], toks[:, 1:])
    total = ce + cfg.load_balance_coef * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with per-layer state
# ---------------------------------------------------------------------------


class ServeState(NamedTuple):
    """Stacked per-layer serving state; exact pytree structure is family-
    dependent (documented in serve/engine.py)."""

    layers: PyTree
    extra: PyTree  # e.g. zamba shared-block caches (n_groups-stacked)
    length: jnp.ndarray


def init_serve_state(cfg: ModelConfig, batch: int, t_max: int) -> ServeState:
    dt = _dtype(cfg)
    fam = cfg.family
    zero = jnp.zeros((), jnp.int32)
    if fam in ("dense", "gemma2"):
        period = 2 if cfg.alt_local_global else 1
        n_groups = cfg.n_layers // period

        def one(i):
            window = blocks_lib.layer_window(cfg, i)
            return make_cache(cfg, batch, t_max, dt, window=window)

        group = {f"b{i}": one(i) for i in range(period)}
        layers = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), group
        )
        return ServeState(layers=layers, extra=None, length=zero)
    if fam == "moe":
        nd = cfg.first_dense_layers
        dense = [make_cache(cfg, batch, t_max, dt) for _ in range(nd)]
        one = make_cache(cfg, batch, t_max, dt)
        layers = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers - nd,) + x.shape), one
        )
        return ServeState(layers=layers, extra=dense, length=zero)
    if fam == "xlstm":
        period = cfg.slstm_every or cfg.n_layers
        n_groups = cfg.n_layers // period
        group = {
            f"m{i}": xlstm_lib.init_mlstm_state(cfg, batch, dt)
            for i in range(period - 1)
        }
        group["s"] = xlstm_lib.init_slstm_state(cfg, batch, dt)
        layers = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), group)
        return ServeState(layers=layers, extra=None, length=zero)
    if fam == "zamba2":
        period = cfg.shared_attn_period
        n_groups = cfg.n_layers // period
        group = {f"m{i}": mamba_lib.init_ssm_state(cfg, batch, dt) for i in range(period)}
        layers = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), group)
        # shared attention block: one cache per invocation; windowed (ring) for
        # the long-context cells — the sub-quadratic adaptation (DESIGN.md §5)
        window = cfg.sliding_window if cfg.sliding_window else 0
        cache = make_cache(cfg, batch, t_max, dt, window=window)
        extra = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), cache)
        return ServeState(layers=layers, extra=extra, length=zero)
    raise ValueError(fam)


def decode_step(
    params: PyTree,
    state: ServeState,
    batch: Dict[str, jnp.ndarray],  # tokens (B, 1) [+ modality extras]
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, ServeState]:
    """One-token decode against per-layer caches/states.  Returns (logits,
    new state)."""
    x = embed_inputs(params, batch, cfg)  # (B, 1, d)
    positions = state.length + jnp.arange(x.shape[1])
    fam = cfg.family
    extra = state.extra

    if fam in ("dense", "gemma2"):
        period = 2 if cfg.alt_local_global else 1

        def body(x, xs):
            lp, caches = xs
            new_caches = {}
            for i in range(period):
                window = blocks_lib.layer_window(cfg, i)
                x, nc = blocks_lib.block_fwd(
                    lp[f"b{i}"], x, positions, cfg, window=window, cache=caches[f"b{i}"]
                )
                new_caches[f"b{i}"] = nc
            return x, new_caches

        x, new_layers = _apply_stack(body, x, (params["layers"], state.layers), cfg)
    elif fam == "moe":
        new_extra = []
        for lp, c in zip(params.get("dense_layers", []), extra or []):
            x, nc, _ = moe_lib.moe_block_fwd(lp, x, positions, cfg, cache=c)
            new_extra.append(nc)
        extra = new_extra

        def body(x, xs):
            lp, cache = xs
            x, nc, _ = moe_lib.moe_block_fwd(lp, x, positions, cfg, cache=cache)
            return x, nc

        x, new_layers = _apply_stack(body, x, (params["layers"], state.layers), cfg)
    elif fam == "xlstm":
        period = cfg.slstm_every or cfg.n_layers

        def body(x, xs):
            gp, st = xs
            new = {}
            for i in range(period - 1):
                x, ns = xlstm_lib.mlstm_fwd(gp[f"m{i}"], x, cfg, state=st[f"m{i}"])
                new[f"m{i}"] = ns
            x, ns = xlstm_lib.slstm_fwd(gp["s"], x, cfg, state=st["s"])
            new["s"] = ns
            return x, new

        x, new_layers = _apply_stack(body, x, (params["layers"], state.layers), cfg)
    elif fam == "zamba2":
        period = cfg.shared_attn_period
        lora = params.get("shared_lora")

        def body(x, xs):
            gp, st, cache, lora_g = xs
            new = {}
            for i in range(period):
                x, ns = mamba_lib.mamba2_fwd(gp[f"m{i}"], x, cfg, state=st[f"m{i}"])
                new[f"m{i}"] = ns
            x, nc = _zamba_shared_decode(params, lora_g, x, positions, cfg, cache)
            return x, (new, nc)

        xs = (params["layers"], state.layers, state.extra, lora)
        x, (new_layers, new_extra) = _apply_stack(body, x, xs, cfg)
        extra = new_extra
    else:  # pragma: no cover
        raise ValueError(fam)

    logits = lm_logits(params, x, cfg)
    return logits, ServeState(layers=new_layers, extra=extra, length=state.length + x.shape[1])


def _zamba_shared_decode(params, lora, x, positions, cfg, cache):
    p = params["shared_block"]
    h = common.rmsnorm(p["attn_norm"], x, cfg.rmsnorm_eps)
    from repro.models.attention import attn_fwd

    if lora is not None:
        delta = (x @ lora["A"]) @ lora["B"]
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        dq, dk = hq * dh, hkv * dh
        d_q, d_k, d_v = jnp.split(delta, [dq, dq + dk], axis=-1)
        out, nc = _attn_with_delta_cache(p["attn"], h, (d_q, d_k, d_v), positions, cfg, cache)
    else:
        window = cfg.sliding_window or 0
        out, nc = attn_fwd(p["attn"], h, positions, cfg, window=window, cache=cache)
    x = x + out
    hm = common.rmsnorm(p["mlp_norm"], x, cfg.rmsnorm_eps)
    return x + blocks_lib.mlp_fwd(p["mlp"], hm, cfg), nc


def _attn_with_delta_cache(params, h, deltas, positions, cfg, cache):
    from repro.models import attention as attn_mod

    d_q, d_k, d_v = deltas
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = attn_mod._split_heads(h @ params["wq"] + d_q, hq)
    k = attn_mod._split_heads(h @ params["wk"] + d_k, hkv)
    v = attn_mod._split_heads(h @ params["wv"] + d_v, hkv)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.query_scale if cfg.query_scale is not None else dh**-0.5
    slots = cache.k.shape[2]
    window = cfg.sliding_window or 0
    ring = window > 0 and slots == window
    T = q.shape[2]
    if ring:
        idx = cache.length % slots
        k_all = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, idx, 0))
        v_all = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, idx, 0))
        valid = jnp.minimum(cache.length + 1, slots)
        mask = (jnp.arange(slots) < valid)[None, :]
    else:
        start = cache.length
        k_all = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, start, 0))
        v_all = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, start, 0))
        cols = jnp.arange(slots)[None, :]
        rows = (cache.length + jnp.arange(T))[:, None]
        mask = cols <= rows
        if window > 0:
            mask = mask & (cols > rows - window)
    new_cache = attn_mod.KVCache(k=k_all, v=v_all, length=cache.length + T)
    o = attn_mod._cache_attention(q, k_all, v_all, mask, scale, cfg.attn_logit_softcap)
    return attn_mod._merge_heads(o) @ params["wo"], new_cache


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — never allocated)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    B = shape.global_batch
    T = shape.seq_len if shape.kind != "decode" else 1
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.n_codebooks:
        specs["tokens"] = jax.ShapeDtypeStruct((B, T, cfg.n_codebooks), jnp.int32)
    elif cfg.vision_tokens and shape.kind != "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((B, T - cfg.vision_tokens), jnp.int32)
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), _dtype(cfg)
        )
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    return specs


def count_params(params: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
