"""Transformer blocks: pre-norm GQA attention + (Ge/Swi)GLU MLP, with the
gemma2 variants (sandwich norms, local/global alternation, logit soft-caps).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.attention import KVCache, attn_fwd, init_attn


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": common.dense_init(k1, d, f, dtype),
        "w_up": common.dense_init(k2, d, f, dtype),
        "w_down": common.dense_init(
            k3, f, d, dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def mlp_fwd(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    act = common.gelu if cfg.family == "gemma2" else common.silu
    h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


def init_block(key, cfg: ModelConfig, dtype) -> dict:
    ka, km = jax.random.split(key)
    p = {
        "attn_norm": common.init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attn(ka, cfg, dtype),
        "mlp_norm": common.init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(km, cfg, dtype),
    }
    if cfg.sandwich_norm:
        p["post_attn_norm"] = common.init_rmsnorm(cfg.d_model, dtype)
        p["post_mlp_norm"] = common.init_rmsnorm(cfg.d_model, dtype)
    return p


def block_fwd(
    params: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    window: int = 0,
    cache: Optional[KVCache] = None,
) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    h = common.rmsnorm(params["attn_norm"], x, cfg.rmsnorm_eps)
    a, new_cache = attn_fwd(
        params["attn"], h, positions, cfg, window=window, cache=cache
    )
    if cfg.sandwich_norm:
        a = common.rmsnorm(params["post_attn_norm"], a, cfg.rmsnorm_eps)
    x = x + a
    h = common.rmsnorm(params["mlp_norm"], x, cfg.rmsnorm_eps)
    m = mlp_fwd(params["mlp"], h, cfg)
    if cfg.sandwich_norm:
        m = common.rmsnorm(params["post_mlp_norm"], m, cfg.rmsnorm_eps)
    return x + m, new_cache


def layer_window(cfg: ModelConfig, layer_idx: int) -> int:
    """gemma2 alternation: even layers local (sliding window), odd global."""
    if cfg.alt_local_global and cfg.sliding_window > 0:
        return cfg.sliding_window if layer_idx % 2 == 0 else 0
    return cfg.sliding_window if cfg.sliding_window > 0 and not cfg.alt_local_global else 0
