"""GQA attention for the model zoo: training/prefill (flash-able) and
single-token decode against a KV cache.

Cache layout: ``k/v (B, Hkv, slots, d)`` plus an int32 ``length`` (tokens seen).
``slots == t_max`` is a plain linear cache; ``slots < t_max`` is a ring buffer
(used by sliding-window layers in the long-context cells — it holds exactly the
last ``window`` tokens, so the window mask is the ring itself).  RoPE is applied
to K *before* caching with absolute positions, so ring overwrites are exact.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ops import attention as attention_op
from repro.models import common


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, Hkv, slots, d)
    v: jnp.ndarray  # (B, Hkv, slots, d)
    length: jnp.ndarray  # () int32 — total tokens seen (may exceed slots)


def init_attn(key, cfg: ModelConfig, dtype) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": common.dense_init(kq, d, hq * dh, dtype),
        "wk": common.dense_init(kk, d, hkv * dh, dtype),
        "wv": common.dense_init(kv, d, hkv * dh, dtype),
        "wo": common.dense_init(
            ko, hq * dh, d, dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    B, T, _ = x.shape
    return x.reshape(B, T, n_heads, -1).transpose(0, 2, 1, 3)  # (B,H,T,d)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    B, H, T, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, T, H * d)


def attn_fwd(
    params: dict,
    x: jnp.ndarray,  # (B, T, d_model)
    positions: jnp.ndarray,  # (T,) or (B, T) absolute positions
    cfg: ModelConfig,
    *,
    window: int = 0,
    cache: Optional[KVCache] = None,
) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Returns (output (B,T,d_model), updated cache or None)."""
    B, T, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = _split_heads(x @ params["wq"], hq)
    k = _split_heads(x @ params["wk"], hkv)
    v = _split_heads(x @ params["wv"], hkv)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.query_scale if cfg.query_scale is not None else dh**-0.5

    if cache is None:
        o = attention_op(
            q, k, v,
            scale=scale, causal=True, window=window,
            softcap=cfg.attn_logit_softcap,
            use_pallas=cfg.use_pallas_attn,
            softmax_dtype=cfg.attn_softmax_dtype,
        )
        return _merge_heads(o) @ params["wo"], None

    slots = cache.k.shape[2]
    ring = window > 0 and slots == window
    if ring:
        if T != 1:
            raise NotImplementedError("ring-buffer cache is decode-only (T=1)")
        idx = cache.length % slots
        k_all = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, idx, 0))
        v_all = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, idx, 0))
        valid = jnp.minimum(cache.length + 1, slots)
        mask = (jnp.arange(slots) < valid)[None, :]  # (1, slots)
    else:
        start = cache.length
        k_all = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, start, 0))
        v_all = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, start, 0))
        cols = jnp.arange(slots)[None, :]
        rows = (cache.length + jnp.arange(T))[:, None]  # absolute q positions
        mask = cols <= rows
        if window > 0:
            mask = mask & (cols > rows - window)
    new_cache = KVCache(k=k_all, v=v_all, length=cache.length + T)
    o = _cache_attention(q, k_all, v_all, mask, scale, cfg.attn_logit_softcap)
    return _merge_heads(o) @ params["wo"], new_cache


def _cache_attention(
    q: jnp.ndarray,  # (B, Hq, Tq, d)
    k: jnp.ndarray,  # (B, Hkv, S, d)
    v: jnp.ndarray,
    mask: jnp.ndarray,  # (Tq or 1, S) bool
    scale: float,
    softcap: float,
) -> jnp.ndarray:
    """Decode/chunk attention streaming the cache once (memory-bound).

    Grouped einsum keeps GQA K/V unreplicated in HBM — at 500k context the
    cache read IS the roofline term, so no repeats and no dtype upcasts of
    the cache: ``preferred_element_type`` gives fp32 accumulation without
    materializing an fp32 copy of K/V (2× traffic saved on the decode cells).
    """
    B, Hq, Tq, d = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, Tq, d)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) * scale
    s = common.softcap(s, softcap)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return o.reshape(B, Hq, Tq, d).astype(q.dtype)


def make_cache(
    cfg: ModelConfig, batch: int, t_max: int, dtype, window: int = 0
) -> KVCache:
    """Allocate an empty cache; sliding-window layers get a ring of ``window``
    slots when that is smaller than ``t_max`` (long-context decode)."""
    slots = min(t_max, window) if window > 0 else t_max
    shape = (batch, cfg.n_kv_heads, slots, cfg.head_dim_)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )
