"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, parallel train form +
O(1) recurrent decode) and sLSTM (scalar memory, true hidden-to-hidden
recurrence → lax.scan over time).

Block structure follows the official v1 layers: up-projection (factor
``ssm_expand``), causal conv feeding q/k, exponential gating with
log-stabilizer, per-head norm, z-gated down-projection.  sLSTM blocks carry the
official 4/3-GLU FFN (the assigned config's d_ff=0 means "no separate FFN
sublayer"; the projections here are part of the block).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.mamba2 import _causal_conv


class MLSTMState(NamedTuple):
    conv: jnp.ndarray  # (B, W-1, d_in)
    C: jnp.ndarray  # (B, H, dqk, dv) matrix memory
    n: jnp.ndarray  # (B, H, dqk) normalizer
    m: jnp.ndarray  # (B, H) log stabilizer


class SLSTMState(NamedTuple):
    conv: jnp.ndarray  # (B, W-1, d)
    c: jnp.ndarray  # (B, H, dh)
    n: jnp.ndarray  # (B, H, dh)
    h: jnp.ndarray  # (B, H, dh)
    m: jnp.ndarray  # (B, H, dh)


def _mlstm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.n_heads
    dv = d_in // H
    dqk = dv // 2  # qk_dim_factor = 0.5 (official)
    return d_in, H, dqk, dv


def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in, H, dqk, dv = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": common.init_rmsnorm(d, dtype),
        "up": common.dense_init(ks[0], d, 2 * d_in, dtype),  # [x_in, z]
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, d_in), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": common.dense_init(ks[2], d_in, H * dqk, dtype),
        "wk": common.dense_init(ks[3], d_in, H * dqk, dtype),
        "wv": common.dense_init(ks[4], d_in, H * dv, dtype),
        "w_if": common.dense_init(ks[5], d_in, 2 * H, dtype),  # input/forget gates
        "if_bias": jnp.concatenate(
            [jnp.zeros((H,), jnp.float32), 3.0 + jnp.arange(H, dtype=jnp.float32)]
        ),  # positive forget-gate bias init (official)
        "head_norm": common.init_rmsnorm(d_in, dtype),
        "down": common.dense_init(
            ks[6], d_in, d, dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def _mlstm_parallel(q, k, v, log_i, log_f, compute_dtype=jnp.float32):
    """Stabilized parallel mLSTM.  q/k (B,H,T,dqk), v (B,H,T,dv),
    gates (B,H,T).  Returns h (B,H,T,dv).

    ``compute_dtype=bfloat16`` runs the three (B,H,T,T) tensors (decay matrix
    W, score matrix S, their product A) in bf16 — the gate cumsums and the
    row stabilizer stay fp32, and the normalizer is accumulated in fp32 by
    folding a ones-column into the A·V contraction (no fp32 T² tensors).
    """
    T = q.shape[2]
    dqk = q.shape[-1]
    cd = jnp.dtype(compute_dtype)
    F = jnp.cumsum(log_f, axis=-1)  # (B,H,T) fp32
    D = F[..., :, None] - F[..., None, :] + log_i[..., None, :]  # (B,H,T,T)
    tri = jnp.tril(jnp.ones((T, T), bool))
    D = jnp.where(tri[None, None], D, -jnp.inf)
    m = jnp.max(D, axis=-1)  # (B,H,T) fp32
    m = jnp.maximum(m, -1e30)  # guard all -inf rows
    W = jnp.exp((D - m[..., None]).astype(cd) if cd != jnp.float32 else D - m[..., None])
    if cd != jnp.float32:
        W = jnp.where(tri[None, None], W, jnp.zeros((), cd))  # exp(bf16(-inf))=0 safe anyway
    S = jnp.einsum("bhtd,bhsd->bhts", q, k, preferred_element_type=cd) / jnp.asarray(
        dqk**0.5, cd
    )
    A = W.astype(cd) * S
    v_ext = jnp.concatenate(
        [v.astype(cd), jnp.ones(v.shape[:-1] + (1,), cd)], axis=-1
    )
    o_ext = jnp.einsum(
        "bhts,bhsv->bhtv", A, v_ext, preferred_element_type=jnp.float32
    )
    num, l = o_ext[..., :-1], o_ext[..., -1]
    den = jnp.maximum(jnp.abs(l), jnp.exp(-m))  # (B,H,T) fp32
    return num / den[..., None]


def _mlstm_chunkwise(q, k, v, log_i, log_f, chunk: int, unroll: bool = False):
    """Chunkwise-parallel mLSTM (the official xLSTM kernel formulation):
    O(T·L) intra-chunk quadratic + O(T/L) inter-chunk state recurrence instead
    of the O(T²) dense form.  Exact (stabilizers cancel in exact arithmetic);
    equality with `_mlstm_parallel` asserted in tests.

    q/k (B,H,T,dqk) pre-scaled by caller? NO — raw; 1/sqrt(dqk) applied here.
    gates (B,H,T) fp32 log-space.  Returns h (B,H,T,dv).
    ``unroll`` mirrors cfg.scan_layers=False for honest dry-run cost counting.
    """
    B, H, T, dqk = q.shape
    dv = v.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    nc = T // L
    inv = 1.0 / (dqk**0.5)

    def c(x):  # (B,H,T,…) → (B,H,nc,L,…)
        return x.reshape(*x.shape[:2], nc, L, *x.shape[3:])

    qc, kc, vc = c(q), c(k), c(v)
    li, lf = c(log_i), c(log_f)  # (B,H,nc,L)
    b = jnp.cumsum(lf, axis=-1)  # within-chunk cumulative log-forget
    F = b[..., -1]  # (B,H,nc) total chunk log-decay

    # intra-chunk decay matrix (same structure as the dense form, L×L)
    D = b[..., :, None] - b[..., None, :] + li[..., None, :]  # (B,H,nc,L,L)
    tri = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(tri, D, -jnp.inf)
    m_intra = jnp.maximum(jnp.max(D, axis=-1), -1e30)  # (B,H,nc,L)
    S = jnp.einsum("bhcld,bhcmd->bhclm", qc, kc, preferred_element_type=jnp.float32) * inv

    # chunk-boundary state ingredients: decay-to-end weights per source pos
    w_end = F[..., None] - b + li  # (B,H,nc,L): log-weight of k_j v_jᵀ into C_c
    m_loc = jnp.max(w_end, axis=-1)  # (B,H,nc)

    carry0 = (
        jnp.zeros((B, H, dqk, dv), jnp.float32),
        jnp.zeros((B, H, dqk), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    )

    def chunk_step(carry, idx):
        C_prev, n_prev, m_prev = carry
        Dc = D[:, :, idx]  # (B,H,L,L)
        Sc = S[:, :, idx]
        bc = b[:, :, idx]  # (B,H,L)
        m_i = m_intra[:, :, idx]
        # combined stabilizer per target position
        m_inter = bc + m_prev[..., None]  # (B,H,L)
        m_comb = jnp.maximum(m_i, m_inter)
        W = jnp.exp(Dc - m_comb[..., None])  # (B,H,L,L)
        A = W * Sc
        num = jnp.einsum("bhlm,bhmv->bhlv", A, vc[:, :, idx].astype(jnp.float32))
        den = jnp.sum(A, axis=-1)  # (B,H,L)
        inter_scale = jnp.exp(m_inter - m_comb)  # (B,H,L)
        qf = qc[:, :, idx].astype(jnp.float32) * inv
        num = num + inter_scale[..., None] * jnp.einsum("bhld,bhdv->bhlv", qf, C_prev)
        den = den + inter_scale * jnp.einsum("bhld,bhd->bhl", qf, n_prev)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_comb))[..., None]

        # state update with its own running max
        m_new = jnp.maximum(F[:, :, idx] + m_prev, m_loc[:, :, idx])
        w = jnp.exp(w_end[:, :, idx] - m_new[..., None])  # (B,H,L)
        kf = kc[:, :, idx].astype(jnp.float32)
        # two explicit steps — a 3-operand einsum may pick an outer-product
        # contraction order materializing a (B,H,L,dqk,dv) 5-D intermediate
        wk = w[..., None] * kf  # (B,H,L,dqk)
        C_new = jnp.exp(F[:, :, idx] + m_prev - m_new)[..., None, None] * C_prev + jnp.einsum(
            "bhld,bhlv->bhdv", wk, vc[:, :, idx].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        n_new = jnp.exp(F[:, :, idx] + m_prev - m_new)[..., None] * n_prev + jnp.sum(
            wk, axis=2
        )
        return (C_new, n_new, m_new), h

    if unroll:
        carry, hs = carry0, []
        for i in range(nc):
            carry, h = chunk_step(carry, i)
            hs.append(h)
        h_all = jnp.stack(hs, axis=2)  # (B,H,nc,L,dv)
    else:
        carry, h_all = jax.lax.scan(
            lambda cr, i: chunk_step(cr, i), carry0, jnp.arange(nc)
        )
        h_all = jnp.moveaxis(h_all, 0, 2)  # (nc,B,H,L,dv) → (B,H,nc,L,dv)

    return h_all.reshape(B, H, T, dv)


def mlstm_fwd(
    params: dict,
    x: jnp.ndarray,  # (B, T, d)
    cfg: ModelConfig,
    state: Optional[MLSTMState] = None,
) -> Tuple[jnp.ndarray, Optional[MLSTMState]]:
    Bsz, T, d = x.shape
    d_in, H, dqk, dv = _mlstm_dims(cfg)
    hN = common.rmsnorm(params["norm"], x, cfg.rmsnorm_eps)
    up = hN @ params["up"]
    x_in, z = jnp.split(up, 2, axis=-1)
    tail = state.conv if state is not None else None
    x_conv = common.silu(_causal_conv(x_in, params["conv_w"], params["conv_b"], tail))
    q = (x_conv @ params["wq"]).reshape(Bsz, T, H, dqk).transpose(0, 2, 1, 3)
    k = (x_conv @ params["wk"]).reshape(Bsz, T, H, dqk).transpose(0, 2, 1, 3)
    v = (x_in @ params["wv"]).reshape(Bsz, T, H, dv).transpose(0, 2, 1, 3)
    gates = (x_conv @ params["w_if"]).astype(jnp.float32) + params["if_bias"]
    log_i, log_f = jnp.split(gates, 2, axis=-1)  # (B,T,H)
    log_i = log_i.transpose(0, 2, 1)  # treated as log ĩ (pre-stabilizer)
    log_f = jax.nn.log_sigmoid(log_f.transpose(0, 2, 1))

    if state is None:
        if cfg.mlstm_chunk:
            h = _mlstm_chunkwise(
                q, k, v, log_i, log_f, cfg.mlstm_chunk,
                unroll=not cfg.scan_layers,
            )
        else:
            h = _mlstm_parallel(
                q, k, v, log_i, log_f,
                compute_dtype=jnp.dtype(cfg.attn_softmax_dtype),
            )  # (B,H,T,dv)
        new_state = None
    else:
        if T != 1:
            raise NotImplementedError("recurrent mLSTM is decode-only (T=1)")
        li, lf = log_i[:, :, 0], log_f[:, :, 0]  # (B,H)
        m_new = jnp.maximum(lf + state.m, li)
        i_s = jnp.exp(li - m_new)[..., None]
        f_s = jnp.exp(lf + state.m - m_new)[..., None]
        k0 = k[:, :, 0].astype(jnp.float32) / (dqk**0.5)
        C = f_s[..., None] * state.C + i_s[..., None] * (
            k0[..., :, None] * v[:, :, 0].astype(jnp.float32)[..., None, :]
        )
        n = f_s * state.n + i_s * k0
        q0 = q[:, :, 0].astype(jnp.float32)
        num = jnp.einsum("bhd,bhdv->bhv", q0, C)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", q0, n)), jnp.exp(-m_new)
        )
        h = (num / den[..., None])[:, :, None, :]  # (B,H,1,dv)
        new_state = MLSTMState(
            conv=jnp.concatenate([state.conv[:, 1:], x_in], axis=1),
            C=C, n=n, m=m_new,
        )

    h = h.transpose(0, 2, 1, 3).reshape(Bsz, T, d_in).astype(x.dtype)
    h = common.rmsnorm(params["head_norm"], h, cfg.rmsnorm_eps)
    out = (h * common.silu(z)) @ params["down"]
    return x + out, new_state


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype) -> MLSTMState:
    d_in, H, dqk, dv = _mlstm_dims(cfg)
    return MLSTMState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, d_in), dtype),
        C=jnp.zeros((batch, H, dqk, dv), jnp.float32),
        n=jnp.zeros((batch, H, dqk), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
    )


# -- sLSTM --------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 8)
    f_ff = int(d * 4 / 3) // 8 * 8
    return {
        "norm": common.init_rmsnorm(d, dtype),
        "conv_w": (jax.random.normal(ks[0], (cfg.conv_width, d), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d,), dtype),
        "w_gates": common.dense_init(ks[1], d, 4 * d, dtype),  # z,i,f,o from conv(x)
        "r_gates": (jax.random.normal(ks[2], (4, H, dh, dh), jnp.float32) / dh**0.5).astype(dtype),
        "gate_bias": jnp.concatenate(
            [
                jnp.zeros((2 * d,), jnp.float32),  # z, i
                jnp.full((d,), 3.0, jnp.float32),  # f (positive bias)
                jnp.zeros((d,), jnp.float32),  # o
            ]
        ),
        "head_norm": common.init_rmsnorm(d, dtype),
        "ffn_norm": common.init_rmsnorm(d, dtype),
        "ffn_gate": common.dense_init(ks[3], d, f_ff, dtype),
        "ffn_up": common.dense_init(ks[4], d, f_ff, dtype),
        "ffn_down": common.dense_init(
            ks[5], f_ff, d, dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def _slstm_cell(carry, wx, r_gates):
    """One sLSTM time step.  wx (B, 4, H, dh) pre-activations from input path."""
    c, n, h, m = carry  # each (B,H,dh)
    rec = jnp.einsum("bhd,ghde->bghe", h, r_gates.astype(jnp.float32))  # (B,4,H,dh)
    z_pre, i_pre, f_pre, o_pre = [wx[:, g] + rec[:, g] for g in range(4)]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new), h_new


def slstm_fwd(
    params: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    state: Optional[SLSTMState] = None,
) -> Tuple[jnp.ndarray, Optional[SLSTMState]]:
    Bsz, T, d = x.shape
    H = cfg.n_heads
    dh = d // H
    x_norm = common.rmsnorm(params["norm"], x, cfg.rmsnorm_eps)
    tail = state.conv if state is not None else None
    x_conv = common.silu(_causal_conv(x_norm, params["conv_w"], params["conv_b"], tail))
    wx = (x_conv @ params["w_gates"]).astype(jnp.float32) + params["gate_bias"]
    wx = wx.reshape(Bsz, T, 4, H, dh)

    if state is None:
        c0 = jnp.zeros((Bsz, H, dh), jnp.float32)
        m0 = jnp.full((Bsz, H, dh), -1e30, jnp.float32)
        carry0 = (c0, c0, c0, m0)
    else:
        carry0 = (state.c, state.n, state.h, state.m)

    def body(carry, wx_t):
        return _slstm_cell(carry, wx_t, params["r_gates"])

    carry, hs = jax.lax.scan(body, carry0, jnp.moveaxis(wx, 1, 0))
    h_seq = jnp.moveaxis(hs, 0, 1).reshape(Bsz, T, d).astype(x.dtype)
    h_seq = common.rmsnorm(params["head_norm"], h_seq, cfg.rmsnorm_eps)
    x = x + h_seq
    # block-internal 4/3 GLU FFN (official sLSTM block)
    fN = common.rmsnorm(params["ffn_norm"], x, cfg.rmsnorm_eps)
    ff = common.gelu(fN @ params["ffn_gate"]) * (fN @ params["ffn_up"])
    x = x + ff @ params["ffn_down"]

    new_state = None
    if state is not None:
        new_state = SLSTMState(
            conv=jnp.concatenate([state.conv[:, 1:], x_norm[:, -1:]], axis=1),
            c=carry[0], n=carry[1], h=carry[2], m=carry[3],
        )
    return x, new_state


def init_slstm_state(cfg: ModelConfig, batch: int, dtype) -> SLSTMState:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return SLSTMState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, d), dtype),
        c=z, n=z, h=z, m=jnp.full((batch, H, dh), -1e30, jnp.float32),
    )
