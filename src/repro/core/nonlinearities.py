"""Element-wise nonlinearities g(.) used by the EASI relative gradient.

The paper replaces the traditional ``tanh`` with a *cubic* nonlinearity because it
only needs multiplies/adds (cheap on FPGA DSP slices, and equally cheap on the TPU
VPU).  The choice of g changes the stability region of the EASI stationary points
(it must satisfy Cardoso's nonlinear-moment condition for the source distribution)
but not the datapath structure, so it is a config knob here.

All functions are pure, shape-preserving and jit/vmap-safe.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

Nonlinearity = Callable[[jnp.ndarray], jnp.ndarray]


def cubic(y: jnp.ndarray) -> jnp.ndarray:
    """g(y) = y^3 — the paper's hardware-efficient choice (mul/add only).

    Suitable for sub-Gaussian sources (negative kurtosis), e.g. sinusoids,
    uniform noise, communication constellations.
    """
    return y * y * y


def tanh(y: jnp.ndarray) -> jnp.ndarray:
    """g(y) = tanh(y) — the classic choice the paper compares against."""
    return jnp.tanh(y)


def relu_signed(y: jnp.ndarray) -> jnp.ndarray:
    """Signed rectifier g(y) = relu(y) - relu(-y-1) style cheap odd-ish function.

    The paper suggests ReLU-family functions as an even cheaper alternative.  EASI
    needs an (approximately) odd function, so we use the odd extension
    g(y) = sign(y) * relu(|y| - 1): zero in the unit box, linear outside.  This
    keeps the skew-symmetric HOS term meaningful while costing only compares/adds.
    """
    return jnp.sign(y) * jnp.maximum(jnp.abs(y) - 1.0, 0.0)


def scaled_tanh(y: jnp.ndarray) -> jnp.ndarray:
    """g(y) = tanh(3y): steeper tanh, sometimes used for super-Gaussian sources."""
    return jnp.tanh(3.0 * y)


NONLINEARITIES: Dict[str, Nonlinearity] = {
    "cubic": cubic,
    "tanh": tanh,
    "relu": relu_signed,
    "scaled_tanh": scaled_tanh,
}


def get(name: str) -> Nonlinearity:
    try:
        return NONLINEARITIES[name]
    except KeyError as e:  # pragma: no cover - trivial
        raise ValueError(
            f"unknown nonlinearity {name!r}; available: {sorted(NONLINEARITIES)}"
        ) from e
