"""SMBGD — Sequential Mini-Batch Gradient Descent (the paper's Eq. 1).

Within a mini-batch of ``P`` samples the separation matrix ``B_k`` is *frozen*
(this is what breaks the loop-carried dependency and enabled the paper's FPGA
pipeline); per-sample relative gradients are folded with an exponential
within-batch decay ``β`` and a cross-batch momentum ``γ``:

    Ĥ_k^0 = γ Ĥ_{k-1}^{P-1} + μ H_k^0
    Ĥ_k^p = β Ĥ_k^{p-1}     + μ H_k^p        0 < p < P
    B_{k+1} = B_k + Ĥ_k^{P-1} B_k

Unrolling the affine recurrence gives the exact closed form used on TPU:

    Ĥ_k = (γ β^{P-1}) Ĥ_{k-1} + Σ_{p<P} (μ β^{P-1-p}) H_k^p
        =  γ̂ Ĥ_{k-1} + S_k

where ``S_k`` collapses into two weighted matmuls (see
``core.easi.batched_relative_gradient``).  ``smbgd_sequential_step`` implements the
recurrence literally (the FPGA datapath, for validation), ``smbgd_batched_step``
implements the MXU form; tests assert bit-level-tight agreement.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import easi as easi_lib
from repro.core.easi import EASIConfig


@dataclasses.dataclass(frozen=True)
class SMBGDConfig:
    """Hyper-parameters of the paper's Eq. 1."""

    batch_size: int = 8  # P — the paper's pipeline depth analogue
    mu: float = 1e-3  # learning rate μ
    beta: float = 0.9  # within-batch decay β (0 < β ≤ 1)
    gamma: float = 0.5  # cross-batch momentum γ (γ=0 disables momentum)

    def __post_init__(self) -> None:
        if self.batch_size < 1:
            raise ValueError("batch_size (P) must be >= 1")
        if not (0.0 <= self.beta <= 1.0):
            raise ValueError("beta must be in [0, 1]")
        if not (0.0 <= self.gamma < 1.0):
            raise ValueError("gamma must be in [0, 1)")

    @property
    def effective_momentum(self) -> float:
        """γ̂ = γ β^{P-1} — momentum coefficient of the closed form."""
        return self.gamma * self.beta ** (self.batch_size - 1)

    def within_batch_weights(self, dtype=jnp.float32) -> jnp.ndarray:
        """w_p = μ β^{P-1-p}, p = 0..P-1 (most recent sample weighted highest)."""
        p = jnp.arange(self.batch_size, dtype=dtype)
        return self.mu * jnp.power(jnp.asarray(self.beta, dtype), (self.batch_size - 1) - p)


class SMBGDState(NamedTuple):
    """Carry between mini-batches: separation matrix + momentum accumulator."""

    B: jnp.ndarray  # (n, m)
    H_hat: jnp.ndarray  # (n, n) — Ĥ_{k-1}^{P-1}
    step: jnp.ndarray  # scalar int32 mini-batch counter k


class BankHyperparams(NamedTuple):
    """Per-stream SMBGD hyper-parameters for a heterogeneous separator bank.

    The scaling-limit analysis (arXiv:1710.05384) motivates sweeping step
    sizes across otherwise identical problems; carrying ``(μ, β, γ)`` as
    ``(S,)`` arrays lets one bank launch run the whole sweep.  A plain pytree
    of arrays so it threads through jit/vmap/shard_map (sharded over the
    stream axis like the bank state itself).
    """

    mu: jnp.ndarray  # (S,) learning rates
    beta: jnp.ndarray  # (S,) within-batch decays
    gamma: jnp.ndarray  # (S,) cross-batch momenta

    @classmethod
    def broadcast(cls, cfg: "SMBGDConfig", n_streams: int) -> "BankHyperparams":
        """Homogeneous bank: every stream carries ``cfg``'s scalars."""
        full = lambda v: jnp.full((n_streams,), v, dtype=jnp.float32)
        return cls(mu=full(cfg.mu), beta=full(cfg.beta), gamma=full(cfg.gamma))

    def within_batch_weights(self, P: int, dtype=jnp.float32) -> jnp.ndarray:
        """Per-stream weight rows ``w[s, p] = μ_s β_s^{P-1-p}`` — shape (S, P)."""
        p = jnp.arange(P, dtype=dtype)
        beta = jnp.asarray(self.beta, dtype)[:, None]
        return jnp.asarray(self.mu, dtype)[:, None] * beta ** ((P - 1) - p)[None, :]

    def effective_momentum(self, P: int, dtype=jnp.float32) -> jnp.ndarray:
        """Per-stream closed-form momentum ``γ̂_s = γ_s β_s^{P-1}`` — shape (S,)."""
        beta = jnp.asarray(self.beta, dtype)
        return jnp.asarray(self.gamma, dtype) * beta ** (P - 1)


def init_state(cfg: EASIConfig, key: jax.Array) -> SMBGDState:
    B0 = easi_lib.init_separation_matrix(cfg, key)
    n = cfg.n_components
    return SMBGDState(
        B=B0,
        H_hat=jnp.zeros((n, n), dtype=cfg.dtype),
        step=jnp.zeros((), dtype=jnp.int32),
    )


def smbgd_sequential_step(
    state: SMBGDState, X_batch: jnp.ndarray, easi_cfg: EASIConfig, cfg: SMBGDConfig
) -> Tuple[SMBGDState, jnp.ndarray]:
    """Literal Eq. 1: scan sample-by-sample inside the mini-batch.

    This mirrors the FPGA pipeline semantics exactly (one sample per "clock",
    ``B`` frozen for the whole batch).  Used as the oracle for the batched form
    and for the throughput baseline benchmark.
    """
    B, H_prev = state.B, state.H_hat
    g = easi_cfg.g
    # γ is gated off for the very first mini-batch (paper: "for the first
    # mini-batch, γ is set to zero") — H_hat starts at exact zeros so the gate
    # is a no-op numerically, but we keep it for faithfulness under restarts.
    gamma = jnp.where(state.step == 0, 0.0, cfg.gamma).astype(B.dtype)

    def body(H_hat, xp):
        p, x = xp
        y = B @ x
        H = easi_lib.relative_gradient(y, g, easi_cfg.normalized, cfg.mu)
        decay = jnp.where(p == 0, gamma, cfg.beta).astype(B.dtype)
        H_hat = decay * H_hat + cfg.mu * H
        return H_hat, y

    P = X_batch.shape[0]
    H_hat, Y = jax.lax.scan(body, H_prev, (jnp.arange(P), X_batch))
    B_next = B + H_hat @ B
    return SMBGDState(B=B_next, H_hat=H_hat, step=state.step + 1), Y


def smbgd_commit(
    step: jnp.ndarray,
    H_prev: jnp.ndarray,
    S: jnp.ndarray,
    B: jnp.ndarray,
    cfg: SMBGDConfig,
    *,
    gamma_hat: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The closed-form commit shared by every batched driver:

        Ĥ = γ̂·Ĥ_prev + S,   B' = B + Ĥ B,   γ̂ gated off where step == 0.

    Shape-polymorphic: scalar ``step`` with ``(n, n)``/``(n, m)`` operands
    (single stream), or ``step (S,)`` with a leading stream axis on all mats
    (``SeparatorBank``).  ``gamma_hat`` overrides ``cfg.effective_momentum``
    for heterogeneous banks — a ``(S,)`` array of per-stream γ̂ (see
    ``BankHyperparams.effective_momentum``).  Keeping this in ONE place means
    a change to the update rule cannot silently skip the sharded or
    Pallas-bank paths.
    """
    if gamma_hat is None:
        gamma_hat = cfg.effective_momentum
    gamma_hat = jnp.where(step == 0, 0.0, gamma_hat).astype(B.dtype)
    if gamma_hat.ndim:
        gamma_hat = gamma_hat[:, None, None]
    H_hat = gamma_hat * H_prev + S.astype(B.dtype)
    B_next = B + H_hat @ B  # matmul broadcasts over a leading stream axis
    return H_hat, B_next


def smbgd_batched_step(
    state: SMBGDState, X_batch: jnp.ndarray, easi_cfg: EASIConfig, cfg: SMBGDConfig,
    *,
    use_pallas: bool = False,
) -> Tuple[SMBGDState, jnp.ndarray]:
    """Closed-form Eq. 1: the TPU-native (MXU) step.

    ``Y = X Bᵀ`` is one matmul; the weighted gradient sum is two matmuls; the
    commit is two more small matmuls.  No per-sample recurrence anywhere.
    """
    B, H_prev = state.B, state.H_hat
    Y = X_batch @ B.T
    w = cfg.within_batch_weights(dtype=B.dtype)
    if use_pallas:
        from repro.kernels.easi_gradient import ops as easi_ops

        S = easi_ops.easi_gradient(Y, w, nonlinearity=easi_cfg.nonlinearity)
    else:
        S = easi_lib.batched_relative_gradient(Y, w, easi_cfg.g)
    H_hat, B_next = smbgd_commit(state.step, H_prev, S, B, cfg)
    return SMBGDState(B=B_next, H_hat=H_hat, step=state.step + 1), Y


@partial(jax.jit, static_argnames=("easi_cfg", "cfg", "use_pallas"))
def smbgd_epoch(
    state: SMBGDState,
    X: jnp.ndarray,
    easi_cfg: EASIConfig,
    cfg: SMBGDConfig,
    use_pallas: bool = False,
) -> Tuple[SMBGDState, jnp.ndarray]:
    """Run SMBGD over a stream ``X (K*P, m)`` reshaped into K mini-batches.

    The cross-batch recurrence is a ``lax.scan`` over k; within a batch there is
    no recurrence at all (the paper's point).  Returns final state and
    ``Y (K*P, n)``.
    """
    T, m = X.shape
    P = cfg.batch_size
    K = T // P
    Xb = X[: K * P].reshape(K, P, m)

    def body(st, xb):
        st, Y = smbgd_batched_step(st, xb, easi_cfg, cfg, use_pallas=use_pallas)
        return st, Y

    state, Yb = jax.lax.scan(body, state, Xb)
    return state, Yb.reshape(K * P, -1)


@partial(jax.jit, static_argnames=("easi_cfg", "cfg"))
def smbgd_epoch_sequential(
    state: SMBGDState, X: jnp.ndarray, easi_cfg: EASIConfig, cfg: SMBGDConfig
) -> Tuple[SMBGDState, jnp.ndarray]:
    """Same as ``smbgd_epoch`` but with the literal per-sample Eq. 1 inside each
    mini-batch (validation / FPGA-semantics oracle)."""
    T, m = X.shape
    P = cfg.batch_size
    K = T // P
    Xb = X[: K * P].reshape(K, P, m)

    def body(st, xb):
        st, Y = smbgd_sequential_step(st, xb, easi_cfg, cfg)
        return st, Y

    state, Yb = jax.lax.scan(body, state, Xb)
    return state, Yb.reshape(K * P, -1)
