"""EASI — Equivariant Adaptive Separation via Independence (Cardoso & Laheld 1996).

Linear model: ``x = A s`` with mixing matrix ``A (m, n)``, sources ``s (n,)``.
EASI adapts a separation matrix ``B (n, m)`` such that ``y = B x`` recovers the
sources (up to permutation/scale), using the *relative* (natural) gradient

    H(y) = (I - y yᵀ) + (y g(y)ᵀ - g(y) yᵀ)
    B   ←  B + μ H(y) B

The first (symmetric) term whitens, the second (skew-symmetric) term removes
higher-order dependence — whitening is merged with separation, which is one of the
paper's stated reasons EASI parallelizes well.

This module provides the *vanilla per-sample SGD* form (a serial ``lax.scan`` — the
loop-carried dependency the paper's SMBGD removes), the batched relative gradient
used by SMBGD, and a normalized variant for large step sizes.

Shape conventions (framework-wide):
  * sample vectors are rows: ``X (P, m)``, ``Y (P, n)``
  * ``B`` is ``(n, m)``; ``Y = X @ B.T``
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import nonlinearities


@dataclasses.dataclass(frozen=True)
class EASIConfig:
    """Static configuration of an EASI separator."""

    n_components: int
    n_features: int
    mu: float = 1e-3  # learning rate
    nonlinearity: str = "cubic"  # the paper's hardware-efficient choice
    normalized: bool = False  # Cardoso's normalized update (stable at large mu)
    dtype: jnp.dtype = jnp.float32

    def __post_init__(self) -> None:
        if self.n_components > self.n_features:
            raise ValueError(
                f"n_components ({self.n_components}) must be <= n_features "
                f"({self.n_features}) — ICA cannot extract more components than "
                "observed mixtures."
            )

    @property
    def g(self) -> nonlinearities.Nonlinearity:
        return nonlinearities.get(self.nonlinearity)


def init_separation_matrix(
    cfg: EASIConfig, key: jax.Array, scale: float = 0.5
) -> jnp.ndarray:
    """Random init of ``B (n, m)``.

    A small random matrix plus identity block keeps early iterates well
    conditioned; the paper initializes "with random values".
    """
    n, m = cfg.n_components, cfg.n_features
    eye = jnp.eye(n, m, dtype=cfg.dtype)
    noise = scale * jax.random.normal(key, (n, m), dtype=cfg.dtype)
    return eye + noise


def relative_gradient(
    y: jnp.ndarray, g: nonlinearities.Nonlinearity, normalized: bool = False,
    mu: float = 1.0,
) -> jnp.ndarray:
    """Per-sample relative gradient ``H(y)`` for a single sample ``y (n,)``.

    With ``normalized=True`` uses Cardoso's normalized form which bounds the
    update for any sample magnitude:
        H = (I - y yᵀ) / (1 + μ yᵀy)  +  (y gᵀ - g yᵀ) / (1 + μ |yᵀ g|)
    """
    n = y.shape[-1]
    gy = g(y)
    eye = jnp.eye(n, dtype=y.dtype)
    sym = eye - jnp.outer(y, y)
    skew = jnp.outer(y, gy) - jnp.outer(gy, y)
    if normalized:
        sym = sym / (1.0 + mu * jnp.dot(y, y))
        skew = skew / (1.0 + mu * jnp.abs(jnp.dot(y, gy)))
    return sym + skew


def batched_relative_gradient(
    Y: jnp.ndarray,
    weights: jnp.ndarray,
    g: nonlinearities.Nonlinearity,
    *,
    precision: jax.lax.Precision = jax.lax.Precision.HIGHEST,
) -> jnp.ndarray:
    """Weighted sum of per-sample relative gradients, in closed matmul form.

    Computes ``S = Σ_p w_p H(y_p)`` for ``Y (P, n)``, ``weights (P,)`` **without**
    materializing P outer products:

        S = (Σ w) I − Yᵀ W Y − (Gᵀ W Y − (Gᵀ W Y)ᵀ)          W = diag(w)

    i.e. two rank-P weighted matmuls — this is the TPU-native ("MXU") form of the
    paper's FPGA sample-per-clock pipeline.  Exactly equal (associativity of the
    weighted sum) to scanning ``relative_gradient`` over p; asserted in tests.
    """
    n = Y.shape[-1]
    G = g(Y)
    Yw = Y * weights[:, None]
    gram = jnp.matmul(Y.T, Yw, precision=precision)  # Σ w y yᵀ
    cross = jnp.matmul(G.T, Yw, precision=precision)  # Σ w g yᵀ
    eye = jnp.eye(n, dtype=Y.dtype) * jnp.sum(weights).astype(Y.dtype)
    return eye - gram - cross + cross.T


def easi_sgd_step(
    B: jnp.ndarray, x: jnp.ndarray, cfg: EASIConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One vanilla EASI SGD step (the paper's Fig. 1 datapath).

    Returns ``(B_next, y)``.  Note the loop-carried dependency: ``B_next`` is
    needed before the next sample can be processed — the serial bottleneck the
    paper's SMBGD (and our batched form) removes.
    """
    y = B @ x
    H = relative_gradient(y, cfg.g, cfg.normalized, cfg.mu)
    B_next = B + cfg.mu * (H @ B)
    return B_next, y


@partial(jax.jit, static_argnames=("cfg",))
def easi_sgd_scan(
    B0: jnp.ndarray, X: jnp.ndarray, cfg: EASIConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run vanilla per-sample EASI over ``X (T, m)`` serially.

    This is the faithful reproduction of the *baseline* (``EASI with SGD`` column
    of Table I).  Returns ``(B_final, Y (T, n))``.
    """

    def body(B, x):
        B_next, y = easi_sgd_step(B, x, cfg)
        return B_next, y

    return jax.lax.scan(body, B0, X)


def transform(B: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """Apply a (fixed) separation matrix: ``Y = X Bᵀ`` for ``X (..., m)``."""
    return X @ B.T
