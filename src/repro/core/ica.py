"""High-level adaptive-ICA estimator built on EASI + SMBGD.

This is the deployable API of the paper's system: model creation, training and
deployment in one object, supporting the *adaptive* (streaming / non-stationary)
regime the paper targets.

    ica = AdaptiveICA(EASIConfig(n_components=2, n_features=4),
                      SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5))
    state = ica.init(key)
    state, Y = ica.fit(state, X)            # offline: one pass over X
    state, y = ica.partial_fit(state, x_batch)   # online: track drift
    Y = ica.transform(state, X_new)         # deployment: separate only

``AdaptiveICA`` is the back-compat name for ``repro.stream.separator.Separator``
— the unified front-end over the three epoch drivers (``sgd``,
``smbgd_sequential``, ``smbgd_batched``; ``"smbgd"`` is an accepted alias of
the batched form).  For many concurrent sessions use
``repro.stream.SeparatorBank``, which is this estimator vmapped over a leading
stream axis with a fused multi-stream Pallas kernel.

Everything is pure-functional (state in/state out) so it drops into pjit/scan.
Data-parallel fitting over a device mesh is provided by ``make_sharded_step``
which psums the weighted gradient across the batch axis — the gradient sum in
``batched_relative_gradient`` is linear in samples, so DP is exact.  (Stream
parallelism — sharding *sessions* rather than samples — lives in
``repro.stream.sharding``.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import easi as easi_lib
from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig, SMBGDState
from repro.stream.separator import Separator


class AdaptiveICA(Separator):
    """Back-compat subclass; all behavior lives on ``Separator``."""


# ---------------------------------------------------------------------------
# Data-parallel fitting: exact DP because the weighted gradient sum is linear.
# Each device computes the weighted relative gradient over its local shard of
# the mini-batch; a psum makes the update identical to the single-device one
# (up to the within-batch β ordering, which DP reinterprets as interleaved
# sample order — recorded in DESIGN.md §6).
# ---------------------------------------------------------------------------


def make_sharded_step(mesh, easi_cfg: EASIConfig, cfg: SMBGDConfig, axis: str = "data"):
    """Build a pjit-able SMBGD step where the mini-batch is sharded over
    ``axis``.  Returns ``step(state, X_batch) -> (state, Y)``.

    Within-batch weights are computed over the *global* sample index so the
    sequential semantics match the single-device run when samples are
    contiguously sharded.
    """
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[axis]
    P_global = cfg.batch_size
    if P_global % n_shards:
        raise ValueError(f"batch_size {P_global} not divisible by {n_shards} shards")

    def local_gradient(B, X_local, w_local):
        Y = X_local @ B.T
        S_local = easi_lib.batched_relative_gradient(Y, w_local, easi_cfg.g)
        # Σw·I was added per-shard; the psum then over-counts the identity —
        # no: batched_relative_gradient adds sum(w_local)·I locally, and
        # psum(Σ_shard sum(w_local)) = sum(w_global): exact.
        return jax.lax.psum(S_local, axis), Y

    def step(state: SMBGDState, X_batch: jnp.ndarray):
        w = cfg.within_batch_weights(dtype=state.B.dtype)

        sharded = shard_map(
            local_gradient,
            mesh=mesh,
            in_specs=(P(None, None), P(axis, None), P(axis)),
            out_specs=(P(None, None), P(axis, None)),
            check_rep=False,
        )
        S, Y = sharded(state.B, X_batch, w)
        H_hat, B_next = smbgd_lib.smbgd_commit(
            state.step, state.H_hat, S, state.B, cfg
        )
        return SMBGDState(B=B_next, H_hat=H_hat, step=state.step + 1), Y

    return step
