"""Separation-quality metrics and convergence detection for ICA.

The paper reports "iterations required for convergence" (§V.A: SGD 4166 vs
SMBGD 3166 → 24 % improvement).  Convergence of a blind separator is measured on
the *global* system ``C = B A``: perfect separation means C is a scaled
permutation.  We use the standard Amari performance index, which is 0 iff C is a
scaled permutation and is invariant to the scale/permutation ambiguity of ICA.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def amari_index(C: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """Amari performance index of a square global matrix ``C (n, n)``.

    PI(C) = 1/(2n(n-1)) * [ Σ_i (Σ_j |c_ij| / max_j |c_ij| − 1)
                          + Σ_j (Σ_i |c_ij| / max_i |c_ij| − 1) ]

    Normalized to [0, 1]; 0 ⇔ scaled permutation (perfect separation).
    """
    A = jnp.abs(C) + eps
    n = A.shape[0]
    row = jnp.sum(A / jnp.max(A, axis=1, keepdims=True), axis=1) - 1.0
    col = jnp.sum(A / jnp.max(A, axis=0, keepdims=True), axis=0) - 1.0
    return (jnp.sum(row) + jnp.sum(col)) / (2.0 * n * (n - 1))


def global_system(B: jnp.ndarray, A: jnp.ndarray) -> jnp.ndarray:
    """C = B A (n×n): the mixing-then-separating chain EASI equivariance is about."""
    return B @ A


def interference_to_signal(C: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """Total interference-to-signal ratio (ISR) in dB — the BSS community's
    alternative to the Amari index.  Lower is better."""
    P = C * C
    sig = jnp.max(P, axis=1)
    isr = (jnp.sum(P, axis=1) - sig) / (sig + eps)
    return 10.0 * jnp.log10(jnp.mean(isr) + eps)


def iterations_to_converge(
    pi_trace: jnp.ndarray, threshold: float = 0.05, sustain: int = 1
) -> jnp.ndarray:
    """First iteration index where the Amari index drops (and stays, for
    ``sustain`` consecutive checks) below ``threshold``.

    Returns the trace length if never converged (callers treat == len as
    "did not converge").  jit-safe (no data-dependent python control flow).
    """
    T = pi_trace.shape[0]
    below = pi_trace < threshold
    if sustain > 1:
        # sustained convergence: all of the next `sustain` checks below threshold
        windows = jnp.stack(
            [jnp.roll(below, -i) for i in range(sustain)], axis=0
        )
        # roll wraps; mask out the wrapped tail
        valid = jnp.arange(T) < (T - sustain + 1)
        below = jnp.all(windows, axis=0) & valid
    idx = jnp.argmax(below)  # first True (0 if none True)
    return jnp.where(jnp.any(below), idx, T)


def update_magnitude(
    B_new: jnp.ndarray, B_old: jnp.ndarray, eps: float = 1e-12
) -> jnp.ndarray:
    """Relative Frobenius update magnitude ``‖B_new − B_old‖_F / ‖B_old‖_F``.

    The *blind* convergence statistic of an SMBGD separator: at a stationary
    point the relative gradient sum vanishes, so ``ΔB = Ĥ′B → 0`` while ``B``
    stays O(1).  Unlike the Amari index it needs no ground-truth mixing
    matrix, and it is exactly what the whole-step megakernel computes
    in-register at commit time (``ΔB = Ĥ′B`` — padding-exact, because padded
    rows/columns of ``B`` are zero).  Shape-polymorphic: reduces the trailing
    two axes, so ``(n, m)`` → scalar and ``(S, n, m)`` → ``(S,)``.
    """
    d = (B_new - B_old).astype(jnp.float32)
    num = jnp.sqrt(jnp.sum(d * d, axis=(-2, -1)))
    b = B_old.astype(jnp.float32)
    den = jnp.sqrt(jnp.sum(b * b, axis=(-2, -1)))
    return num / jnp.maximum(den, eps)


def ema_update(
    smoothed: jnp.ndarray, value: jnp.ndarray, decay: float
) -> jnp.ndarray:
    """One step of an inf-aware, NaN-saturating exponential moving average.

    ``smoothed' = decay·smoothed + (1−decay)·value``, except that a
    non-finite ``smoothed`` (the ``inf`` "never measured" init used by
    ``BankState.conv`` and the serving monitors) is *replaced* by the first
    observation instead of poisoning the average forever, and a NaN
    ``value`` (a faulted tick's statistic) is *skipped* — the average holds
    its last state rather than carrying the NaN forward (the serving
    monitors count the skip; see ``ConvergenceMonitor.skipped``).
    ``decay == 0`` passes the raw value through.  jit/vmap-safe and
    shape-broadcasting — the in-graph counterpart of
    ``serve.engine.ConvergenceMonitor.update``'s host-side recurrence (a
    parity test pins the two to the same values), for callers that want the
    smoothing fused into the device step.
    """
    smoothed = jnp.asarray(smoothed, dtype=jnp.float32)
    value = jnp.asarray(value, dtype=jnp.float32)
    blended = decay * smoothed + (1.0 - decay) * value
    return jnp.where(
        jnp.isnan(value),
        smoothed,
        jnp.where(jnp.isfinite(smoothed), blended, value),
    )


def whiteness_error(Y: jnp.ndarray) -> jnp.ndarray:
    """‖cov(Y) − I‖_F / n — how well the symmetric EASI term has whitened the
    outputs.  EASI merges whitening with separation, so this must → 0 too."""
    Yc = Y - jnp.mean(Y, axis=0, keepdims=True)
    cov = (Yc.T @ Yc) / Y.shape[0]
    n = cov.shape[0]
    return jnp.linalg.norm(cov - jnp.eye(n, dtype=cov.dtype)) / n
