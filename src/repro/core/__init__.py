"""Core: the paper's contribution — EASI adaptive ICA with the SMBGD update rule."""
from repro.core.easi import (
    EASIConfig,
    batched_relative_gradient,
    easi_sgd_scan,
    easi_sgd_step,
    init_separation_matrix,
    relative_gradient,
    transform,
)
from repro.core.ica import AdaptiveICA
from repro.core.metrics import (
    amari_index,
    ema_update,
    global_system,
    iterations_to_converge,
    update_magnitude,
)
from repro.core.smbgd import (
    SMBGDConfig,
    SMBGDState,
    init_state,
    smbgd_batched_step,
    smbgd_epoch,
    smbgd_epoch_sequential,
    smbgd_sequential_step,
)

__all__ = [
    "EASIConfig",
    "SMBGDConfig",
    "SMBGDState",
    "AdaptiveICA",
    "amari_index",
    "batched_relative_gradient",
    "ema_update",
    "easi_sgd_scan",
    "easi_sgd_step",
    "global_system",
    "init_separation_matrix",
    "init_state",
    "iterations_to_converge",
    "relative_gradient",
    "smbgd_batched_step",
    "smbgd_epoch",
    "smbgd_epoch_sequential",
    "smbgd_sequential_step",
    "update_magnitude",
    "transform",
]
