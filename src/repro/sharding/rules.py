"""Logical-axis → mesh-axis sharding rules.

Parameter rules are path-based (Megatron-style TP over "model", optional
ZeRO-3/FSDP over "data"); serving-state rules are shape-based best-effort
(batch → "data", largest model-divisible dim → "model", which gives sequence-
parallel KV caches when head counts don't divide the TP degree).

All rules emit ``PartitionSpec``s; ``make_shardings`` binds them to a mesh as
``NamedSharding``s for pjit ``in_shardings``/``out_shardings``.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any

# stacked collections: leading axis is the scan (layer-group) axis → never sharded
_STACKED_PREFIXES = ("layers", "shared_lora")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path: str, ndim: int, cfg: ModelConfig, mesh_axes: Tuple[str, ...]) -> P:
    """PartitionSpec for one parameter.  ``ndim`` EXCLUDES the stack axis
    (caller re-prepends None for stacked params)."""
    if cfg.dp_only:
        # small-model policy: replicate params, parallelize over batch only —
        # avoids degenerate TP (e.g. 9 heads over a 16-way model axis)
        return P(*([None] * ndim))
    fsdp = ("data",) if (cfg.fsdp and "data" in mesh_axes) else None
    leaf = path.rsplit("/", 1)[-1]

    # embeddings / heads --------------------------------------------------
    if leaf == "embed":
        return P(None, "model", None) if ndim == 3 else P("model", None)
    if leaf == "lm_head":
        return P(None, None, "model") if ndim == 3 else P(None, "model")

    # attention ------------------------------------------------------------
    if leaf in ("wq", "wk", "wv", "w_gate", "w_up", "up", "in_proj", "w_gates", "w_if", "ffn_gate", "ffn_up"):
        if ndim == 3:  # MoE experts (E, d, f): EP over model, fsdp over d
            return P("model", fsdp, None)
        return P(fsdp, "model")
    if leaf in ("wo", "w_down", "down", "out_proj", "ffn_down"):
        if ndim == 3:  # (E, f, d)
            return P("model", None, fsdp)
        return P("model", fsdp)

    # LoRA ------------------------------------------------------------------
    if leaf == "A":
        return P(fsdp, None)
    if leaf == "B":
        return P(None, "model")

    # small / replicated ----------------------------------------------------
    # router, norms, conv kernels, gate biases, A_log, dt_bias, D, r_gates
    return P(*([None] * ndim))


def param_shardings(params_shape: PyTree, cfg: ModelConfig, mesh: Mesh) -> PyTree:
    """NamedSharding pytree matching ``params_shape`` (arrays or
    ShapeDtypeStructs)."""
    axes = tuple(mesh.axis_names)

    def one(path, leaf):
        ps = _path_str(path)
        stacked = any(part in _STACKED_PREFIXES for part in ps.split("/"))
        ndim = leaf.ndim - (1 if stacked else 0)
        spec = param_spec(ps, ndim, cfg, axes)
        if stacked:
            spec = P(None, *spec)
        spec = _truncate_spec(spec, leaf.ndim)
        spec = _validate_spec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def _truncate_spec(spec: P, ndim: int) -> P:
    parts = list(spec) + [None] * ndim
    return P(*parts[:ndim])


def _validate_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis assignments that don't divide the dim (replicate instead)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            out.append(None)
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([sizes[a] for a in axs]))
        out.append(ax if dim % total == 0 else None)
    return P(*out)


def data_spec(shape: Tuple[int, ...], mesh: Mesh, dp_only: bool = False) -> P:
    """Input batches: batch dim over all data axes ("pod","data") — or over
    EVERY axis under the dp_only policy; falls back to replication if not
    divisible (e.g. batch=1)."""
    dp_axes = (
        tuple(mesh.axis_names)
        if dp_only
        else tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    )
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = int(np.prod([sizes[a] for a in dp_axes])) if dp_axes else 1
    first = dp_axes if (shape and shape[0] % max(total, 1) == 0 and dp_axes) else None
    return P(first, *([None] * (len(shape) - 1)))


def state_spec(shape: Tuple[int, ...], mesh: Mesh, stacked: bool = True) -> P:
    """Best-effort sharding for serving state (KV caches / SSM states).

    Layout assumption: [L-stack,] batch, then feature/time dims.  Batch →
    data axes when divisible; the largest remaining dim divisible by the
    "model" axis → "model" (for 32k+ caches this is the sequence dim ⇒ SP).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = int(np.prod([sizes[a] for a in dp_axes])) if dp_axes else 1

    spec: list = [None] * len(shape)
    start = 1 if stacked and len(shape) > 1 else 0
    # batch dim
    if len(shape) > start and shape[start] % dp == 0 and dp > 1:
        spec[start] = dp_axes
    # model dim: largest remaining divisible dim
    cand = [
        (shape[i], i)
        for i in range(start + 1, len(shape))
        if shape[i] % model == 0 and model > 1
    ]
    if cand:
        _, i = max(cand)
        spec[i] = "model"
    return P(*spec)


def state_shardings(state_shape: PyTree, mesh: Mesh) -> PyTree:
    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, state_spec(leaf.shape, mesh, stacked=True))

    return jax.tree.map(one, state_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
