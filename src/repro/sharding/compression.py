"""Gradient compression for cross-pod all-reduce: int8 quantization with
error feedback (EF-SGD style).

At 1000+-node scale the "pod" axis rides slower inter-pod links; shipping
int8 gradients cuts that traffic 4× (vs f32) while error feedback keeps the
asymptotic convergence of the uncompressed method.  Composable as an optional
stage of the gradient path:

    grads, ef_state = compressed_psum(grads, ef_state, axis_name="pod")

inside a ``shard_map``-wrapped step, or standalone via ``quantize/dequantize``
for checkpoint/transfer compression.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return q.astype(dtype) * scale


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(
    grads: PyTree, ef: Optional[PyTree], axis_name: str
) -> Tuple[PyTree, PyTree]:
    """int8 all-reduce with error feedback.  Call under shard_map with
    ``axis_name`` bound (e.g. "pod")."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
        # shared scale first (one tiny pmax) so every shard quantizes onto the
        # same grid — the int32 psum of int8 payloads is then exact.
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name) + 1e-12
        scale = amax / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_e = g32 - q.astype(jnp.float32) * scale  # local residual (EF)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
        return (summed * scale).astype(g.dtype), new_e

    if ef is None:
        ef = init_error_feedback(grads)
    out = jax.tree.map(one, grads, ef)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_ef
