"""Public attention op: shape policy, padding, kernel/ref dispatch.

``attention(...)`` is the single entry point the model zoo calls.  It routes to
the Pallas flash kernel when shapes are tile-able (training/prefill) and to the
jnp reference otherwise (tiny smoke shapes, decode-with-cache fast path).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _use_pallas_default() -> bool:
    # Interpret-mode flash over 32k sequences is minutes-slow on CPU; default
    # to the XLA reference path on CPU and the kernel on real TPU.
    return os.environ.get("REPRO_USE_PALLAS_ATTN", "0") == "1"


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "softcap", "use_pallas", "block_q", "block_k",
        "softmax_dtype",
    ),
)
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    use_pallas: bool | None = None,
    block_q: int = 128,
    block_k: int = 128,
    softmax_dtype: str = "float32",
) -> jnp.ndarray:
    """Multi-head attention over (B, H, T, d) tensors; GQA via Hkv | Hq."""
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    B, Hq, Tq, d = q.shape
    Tk = k.shape[2]
    tileable = Tq % block_q == 0 and Tk % block_k == 0 and Tq >= block_q
    if not (use_pallas and tileable):
        return attention_ref(
            q, k, v, scale=scale, causal=causal, window=window, softcap=softcap,
            q_offset=Tk - Tq, softmax_dtype=jnp.dtype(softmax_dtype),
        )
    return flash_attention_pallas(
        q, k, v,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=_interpret_default(),
    )
