"""Pallas TPU kernel: block-wise online-softmax (flash) attention.

Supports the attention variants the assigned architectures need:
  * causal masking (decoder LMs),
  * GQA — KV heads indexed as ``q_head // group`` in the BlockSpec index maps
    (no KV replication in HBM),
  * sliding-window masking (gemma2 local layers),
  * logit soft-capping ``s ← c·tanh(s/c)`` (gemma2),
  * fp32 softmax state regardless of input dtype.

Grid: ``(batch, q_heads, Tq/block_q, Tk/block_k)`` with the KV axis innermost;
per-(q-block) running max/denominator/accumulator live in VMEM scratch and the
output tile is finalized on the last KV step.  The HBM traffic is O(T·d) per
head instead of the O(T²) score matrix — on the 32k-prefill shapes this is the
difference between memory-bound and compute-bound attention (see EXPERIMENTS.md
§Roofline).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_k: int,
    kv_steps: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= rows >= cols
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (bq, 1)
    l_prev = l_scr[...]  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
    p = jnp.exp(s - m_new)  # (bq, bk)
    p = jnp.where(mask, p, 0.0)  # fully-masked tiles must contribute zero
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, Tq, d)
    k: jnp.ndarray,  # (B, Hkv, Tk, d)
    v: jnp.ndarray,  # (B, Hkv, Tk, d)
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Hq, Tq, d = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    assert Tq % block_q == 0 and Tk % block_k == 0, (Tq, Tk, block_q, block_k)
    kv_steps = Tk // block_k
    grid = (B, Hq, Tq // block_q, kv_steps)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        kv_steps=kv_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b, h, qi, ki: (b, h // group, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b, h, qi, ki: (b, h // group, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b, h, qi, ki: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
