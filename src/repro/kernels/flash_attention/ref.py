"""Pure-jnp oracle: dense (materialized-scores) attention with GQA, causal,
sliding-window and soft-cap — the O(T²) reference the flash kernel must match.

Two execution paths:
  * fp32 softmax (default): straight autodiff — the validation oracle.
  * bf16 softmax (``softmax_dtype=bfloat16``, softcap-free): a custom-VJP
    memory-lean path whose BACKWARD is hand-written in bf16 — autodiff would
    otherwise emit fp32 cotangents for every (…,T,T) tensor, which the §Perf
    profile showed dominating the memory roofline term.  The softmax-row-sum
    rewrite uses Σ_k pn·(do·v) = do·o, so the backward touches only three
    bf16 T² tensors (pn, dpn, ds).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, Tq, d)
    k: jnp.ndarray,  # (B, Hkv, Tk, d)
    v: jnp.ndarray,  # (B, Hkv, Tk, d)
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    softmax_dtype=jnp.float32,
) -> jnp.ndarray:
    """``q_offset``: absolute position of q[0] (for decode: offset = Tk - Tq).

    GQA via a grouped einsum (K/V never replicated in HBM).  The T²-class
    score pipeline runs in ``softmax_dtype`` — bf16 halves the dominant HBM
    term on the 4k/32k cells at <1e-2 output error (validated in tests);
    the max-subtraction keeps exp() well-conditioned in bf16.
    """
    B, Hq, Tq, d = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    sd = jnp.dtype(softmax_dtype)
    qg = q.reshape(B, Hkv, group, Tq, d)
    rows = q_offset + jnp.arange(Tq)[:, None]
    cols = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= rows >= cols
    if window > 0:
        mask &= cols > rows - window

    if sd == jnp.bfloat16 and softcap == 0.0:
        o = _attention_bf16(qg, k, v, mask, scale)
        return o.reshape(B, Hq, Tq, d).astype(q.dtype)

    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg, k, preferred_element_type=sd
    ) * jnp.asarray(scale, sd)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask, s, jnp.asarray(-1e30, sd))
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32), 1e-30)
    o = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    ) / l
    return o.reshape(B, Hq, Tq, d).astype(q.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def _attention_bf16(qg, k, v, mask, scale):
    o, _ = _attention_bf16_fwd(qg, k, v, mask, scale)
    return o


def _attention_bf16_fwd(qg, k, v, mask, scale):
    bf = jnp.bfloat16
    d = v.shape[-1]
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k, preferred_element_type=bf)
    s = s * jnp.asarray(scale, bf)
    s = jnp.where(mask, s, jnp.asarray(-30000.0, bf))
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)  # bf16 (…,T,T)
    # fp32 denominator accumulated inside the PV dot via an appended
    # ones-column — no fp32 T² materialization (flash-style l fold)
    v_ext = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
    o_ext = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p, v_ext, preferred_element_type=jnp.float32
    )
    l = jnp.maximum(o_ext[..., d:], 1e-30)
    o = o_ext[..., :d] / l
    pn = (p / l.astype(bf)).astype(bf)  # normalized probs, bf16
    return o, (pn, qg, k, v, o)


def _attention_bf16_bwd(scale, res, do):
    bf = jnp.bfloat16
    pn, qg, k, v, o = res
    do32 = do.astype(jnp.float32)
    # Σ_k dpn·pn over the row == do·o (softmax-vjp row-sum rewrite): fp32 but
    # only (…,T,1) — never a T² fp32 tensor.
    rowsum = jnp.sum(do32 * o, axis=-1, keepdims=True)
    dv = jnp.einsum(
        "bhgqk,bhgqd->bhkd", pn, do.astype(bf), preferred_element_type=jnp.float32
    ).astype(v.dtype)
    dpn = jnp.einsum(
        "bhgqd,bhkd->bhgqk", do.astype(bf), v, preferred_element_type=bf
    )
    ds = pn * (dpn - rowsum.astype(bf)) * jnp.asarray(scale, bf)  # bf16 T²
    dq = jnp.einsum(
        "bhgqk,bhkd->bhgqd", ds, k, preferred_element_type=jnp.float32
    ).astype(qg.dtype)
    dk = jnp.einsum(
        "bhgqk,bhgqd->bhkd", ds, qg, preferred_element_type=jnp.float32
    ).astype(k.dtype)
    return dq, dk, dv, None


_attention_bf16.defvjp(_attention_bf16_fwd, _attention_bf16_bwd)
