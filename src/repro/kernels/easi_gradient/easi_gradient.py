"""Pallas TPU kernel: fused batched EASI relative gradient (the paper's datapath).

Computes, for ``Y (P, n)`` and within-batch SMBGD weights ``w (P,)``:

    S = (Σ_p w_p) I − Yᵀ W Y − Gᵀ W Y + (Gᵀ W Y)ᵀ,   G = g(Y),  W = diag(w)

in ONE pass over Y tiled along P: each grid step loads a ``(block_p, n)`` tile
into VMEM, evaluates the nonlinearity in-register (never materializing G in
HBM), performs the two weighted MXU matmuls, and accumulates the (n, n) result
in place.  This is the TPU-native replacement for the paper's one-sample-per-
clock FPGA pipeline: arithmetic intensity grows from O(1) (rank-1 outer-product
updates) to O(block_p) (rank-P matmuls) — MXU-bound instead of HBM-bound.

The *bank* variant (``easi_gradient_bank_pallas``) adds a leading **streams**
grid dimension: for ``Y (S, P, n)`` the grid is ``(S, P // block_p)`` and one
launch folds every stream's tiles — S independent separator sessions cost one
kernel dispatch instead of S.  The stream axis is the majormost grid dim, so
for each stream the tile index still iterates innermost and the per-stream
(n, n) accumulator pattern is unchanged.

The *whole-step* variant (``smbgd_step_bank_pallas``) is the megakernel: the
same ``(streams, P-tiles)`` grid, but each grid step also computes its tile of
``Y = X Bᵀ`` in VMEM (X never leaves the kernel as Y in HBM until the output
write), and each stream's LAST tile performs the SMBGD commit in-register:

``prefetch=True`` swaps the X operand's block pipeline for an explicit
double-buffered DMA: X stays in ``pltpu.ANY`` (HBM on TPU) and the kernel
overlaps the NEXT tile's ``make_async_copy`` with the CURRENT tile's gradient
fold — the paper's "compute never waits on memory" pipelining one level
deeper than BlockSpec auto-pipelining, with the prefetch window crossing
stream-block boundaries (the last tile of stream-block s prefetches tile 0 of
stream-block s+1, so the only un-overlapped DMA is the very first one).  The
synchronous path stays the fallback/oracle: on the interpret path the two are
bit-identical (tested), so prefetch is purely a memory-system knob.

Reduced-precision persistent state rides the same launches for free: the
kernels cast every operand to f32 at load (``.astype`` below) and back to the
output ref's dtype at commit, so a bank whose ``B``/``Ĥ`` live in bf16 (see
``ops.BankLayout.dtype_policy``) runs the gradient fold and the commit
accumulation entirely in f32 — casts happen ONLY at the load/commit
boundaries, and frozen (inactive) slots round-trip bf16→f32→bf16 exactly.

    Ĥ' = γ̂·Ĥ + Σ_tiles S_tile      (γ̂ gated to 0 where step == 0)
    B' = B + Ĥ'·B ;  step' = step + 1

so one kernel dispatch per bank tick reads ``X, B, Ĥ, step, conv`` and writes
``Y, B', Ĥ', step', conv'`` — no intermediate ``Y``/``S_grad`` round-trips
HBM.  ``conv'`` is the per-stream convergence statistic ``‖Ĥ′B‖_F/‖B‖_F``
(relative update magnitude) folded from the commit's own ΔB, so the serving
layer's eviction policy reads an (S,)-float side channel instead of pulling
state matrices back to the host.
Per-stream weight rows ``W (S, P, 1)`` and momentum coefficients
``γ̂ (S, 1)`` make the bank heterogeneous (per-stream μ, β, γ) inside a single
launch, and ``active (S, 1)`` freezes evicted/idle slots in-kernel (their
``B``/``Ĥ``/``step`` are written back unchanged; their Y is still produced).
``block_s`` streams ride each grid cell as a leading batch dimension of every
block (batched ``dot_general``s inside the cell), so the grid is
``(S / block_s, P / block_p)`` — per-cell launch/loop overhead amortizes over
the stream block while the math stays per-stream independent.

Layout notes (TPU target; validated on CPU via interpret=True):
  * last dims (n for Y/Ĥ, m for X/B) are padded to a multiple of 128 (lane
    width) by ops.py — 8 (f32 sublane) in interpret mode,
  * block_p is a multiple of 8 (f32 sublane) — default 512,
  * accumulation in fp32 regardless of input dtype (preferred_element_type),
  * the whole-step kernel's gradient accumulator is a VMEM scratch buffer
    (``(n, n)`` fp32) that persists across the sequential grid: tiles iterate
    innermost, so it is re-initialized at each stream's tile 0 and consumed by
    the commit at tile T-1; ``B``/``Ĥ`` blocks are revisited (index map pins
    them per stream) and written once, on the commit tile,
  * per-stream scalars (``step``, ``γ̂``, ``active``) ride as (1, 1) blocks —
    on real TPU these are natural SMEM residents; interpret mode does not
    distinguish,
  * zero padding is exact end-to-end: padded m-columns of X/B keep padded Y
    zero (g(0) = 0 for every registered nonlinearity), padded w rows add
    nothing, and the only nonzero the commit writes into the padded region is
    the Σw diagonal of the identity term, which stays confined there (padded
    rows of B are zero, so it never couples back into the logical block —
    persistent padded state does not need re-zeroing between ticks).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.nonlinearities import NONLINEARITIES

# The kernel nonlinearity table IS the core registry: every g(.) there is pure
# jnp elementwise (VPU-lowerable), so registering a new nonlinearity in
# core/nonlinearities.py makes it available inside the kernel automatically —
# the two banks cannot drift.
NONLIN_KERNELS: dict = NONLINEARITIES

# Per-stream health word: an int32 bitmask folded in-register at commit time
# (one more reduction riding the conv statistic's pass — no extra HBM
# traffic).  0 means healthy; any set bit means the tick's commit was REFUSED
# for that stream (the slot keeps its pre-tick B/Ĥ/step/conv, exactly like
# the active-mask freeze) and the serving layer decides rollback/quarantine.
HEALTH_OK = 0
HEALTH_NONFINITE_B = 1 << 0  # B' picked up a NaN/Inf
HEALTH_NONFINITE_H = 1 << 1  # Ĥ' picked up a NaN/Inf
HEALTH_NONFINITE_Y = 1 << 2  # some Y tile was non-finite (bad input block)
HEALTH_BLOWUP = 1 << 3  # ‖Ĥ′B‖/‖B‖ above the static blow-up bound

# Static blow-up bound on the relative update magnitude ‖ΔB‖_F/‖B‖_F.  A
# legitimate SMBGD tick moves B by a few percent (early ticks by O(1) at
# most); the divergent μ-regime of online ICA (arXiv:1710.05384) multiplies
# B in a handful of ticks — 100 is far above any converging trajectory and
# far below a blow-up's second tick.
HEALTH_BLOWUP_BOUND = 100.0

# Per-stream moment telemetry: raw sums [Σy², Σy⁴] over the stream's whole Y
# block, folded tile-by-tile in the same in-register reduction pass as conv
# and the health word (Y never re-read from HBM; the only cost is one (S, 2)
# f32 output leaf — 8 bytes/stream/tick).  The serving layer turns the sums
# into a kurtosis estimate κ = N·Σy⁴/(Σy²)² (N = logical P·n, known to the
# host) and drives the moment-scaled adaptive μ controller from it
# (arXiv:2509.15127: learning rate ∝ 1/high-order moments).  Padding-exact:
# padded Y entries are exactly zero and contribute nothing to either sum.
MOMENT_LEAVES = 2  # [Σy², Σy⁴]


def _health_word(b_new, h_new, ybad, delta, blowup: float):
    """Fold the per-stream health bitmask from commit-time registers:
    ``b_new``/``h_new`` (bs, n, ·) f32, ``ybad`` (bs, 1) int (nonzero where
    some Y tile was non-finite), ``delta`` (bs, 1) the conv statistic.
    ``~(delta <= blowup)`` deliberately catches NaN deltas too."""
    i32 = jnp.int32
    bbad = jnp.any(~jnp.isfinite(b_new), axis=(1, 2))[:, None]
    hbad = jnp.any(~jnp.isfinite(h_new), axis=(1, 2))[:, None]
    blow = ~(delta <= blowup)
    return (
        bbad.astype(i32) * HEALTH_NONFINITE_B
        + hbad.astype(i32) * HEALTH_NONFINITE_H
        + (ybad != 0).astype(i32) * HEALTH_NONFINITE_Y
        + blow.astype(i32) * HEALTH_BLOWUP
    )


def _fold_tile(y, w, nonlin: str):
    """Fold one (bp, n) fp32 tile of Y into an (n, n) gradient contribution."""
    g = NONLIN_KERNELS[nonlin](y)
    yw = y * w  # weighted rows — one VPU pass
    # Two MXU contractions over the tile's P dimension (rank-bp updates).
    gram = jax.lax.dot_general(
        y, yw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # Yᵀ W Y  (n, n)
    cross = jax.lax.dot_general(
        g, yw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # Gᵀ W Y  (n, n)
    n = gram.shape[0]
    # Per-tile identity contribution: Σ_tiles sum(w_tile)·I == sum(w)·I overall.
    eye = jnp.eye(n, dtype=jnp.float32) * jnp.sum(w)
    return eye - gram - cross + cross.T


def _easi_gradient_kernel(y_ref, w_ref, out_ref, *, nonlin: str):
    """One grid step: fold a (block_p, n) tile of Y into the (n, n) accumulator."""
    i = pl.program_id(0)
    y = y_ref[...].astype(jnp.float32)  # (bp, n)
    w = w_ref[...].astype(jnp.float32)  # (bp, 1)
    s_tile = _fold_tile(y, w, nonlin)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = s_tile

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += s_tile


def easi_gradient_pallas(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Launch the fused gradient kernel.  Expects pre-padded inputs:
    ``Y (P, n)`` with P % block_p == 0 and n lane-aligned; ``w (P, 1)``.
    Returns ``S (n, n)`` in fp32."""
    P, n = Y.shape
    assert P % block_p == 0, (P, block_p)
    grid = (P // block_p,)
    kernel = functools.partial(_easi_gradient_kernel, nonlin=nonlinearity)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, n), lambda i: (i, 0)),
            pl.BlockSpec((block_p, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(Y, w)


def _easi_gradient_bank_kernel(y_ref, w_ref, out_ref, *, nonlin: str):
    """One grid step of the bank kernel: fold stream s's tile i into its
    (n, n) accumulator.  Grid is (streams, tiles); tiles iterate innermost so
    ``i == 0`` marks the first visit to stream s's output block."""
    i = pl.program_id(1)
    y = y_ref[0].astype(jnp.float32)  # (bp, n) — block is (1, bp, n)
    w = w_ref[...].astype(jnp.float32)  # (bp, 1) — shared across streams
    s_tile = _fold_tile(y, w, nonlin)

    @pl.when(i == 0)
    def _init():
        out_ref[0] = s_tile

    @pl.when(i > 0)
    def _acc():
        out_ref[0] += s_tile


def easi_gradient_bank_pallas(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched-stream launch: ``Y (S, P, n)``, shared weights ``w (P, 1)`` →
    ``S_out (S, n, n)`` fp32.  One kernel dispatch folds all S·(P/block_p)
    tiles via the (streams, tiles) grid.  Expects pre-padded inputs as in
    ``easi_gradient_pallas``."""
    S, P, n = Y.shape
    assert P % block_p == 0, (P, block_p)
    grid = (S, P // block_p)
    kernel = functools.partial(_easi_gradient_bank_kernel, nonlin=nonlinearity)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_p, n), lambda s, i: (s, i, 0)),
            pl.BlockSpec((block_p, 1), lambda s, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, n), lambda s, i: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, n, n), jnp.float32),
        interpret=interpret,
    )(Y, w)


def _fold_tile_batched(y, w, nonlin: str):
    """Batched ``_fold_tile``: fold a (bs, bp, n) block of Y tiles — one per
    stream in the stream-block — into (bs, n, n) gradient contributions."""
    g = NONLIN_KERNELS[nonlin](y)
    yw = y * w  # (bs, bp, n) * (bs, bp, 1)
    dims = (((1,), (1,)), ((0,), (0,)))  # contract bp, batch over streams
    gram = jax.lax.dot_general(y, yw, dims, preferred_element_type=jnp.float32)
    cross = jax.lax.dot_general(g, yw, dims, preferred_element_type=jnp.float32)
    n = gram.shape[-1]
    eye = jnp.eye(n, dtype=jnp.float32)[None] * jnp.sum(w, axis=1, keepdims=True)
    return eye - gram - cross + cross.transpose(0, 2, 1)


def _commit_streams(
    b,
    h_ref,
    step_ref,
    gamma_hat_ref,
    active_ref,
    conv_ref,
    b_out_ref,
    h_out_ref,
    step_out_ref,
    conv_out_ref,
    health_out_ref,
    moment_out_ref,
    acc_ref,
    ybad_ref,
    mom_ref,
    *,
    with_health: bool,
    with_moments: bool,
    blowup: float,
):
    """The SMBGD commit tail shared by the sync and prefetch step kernels:
    fold the accumulated gradient into ``Ĥ'``/``B'``/``step'``/``conv'`` for
    one stream-block.  ``b`` is the block's B already cast to f32; all math
    runs in f32 and casts back to the output refs' (storage) dtype only at
    the final writes — frozen slots round-trip bf16→f32→bf16 exactly.

    ``with_health=True`` additionally folds the per-stream health bitmask
    (``_health_word``) and REFUSES the commit for unhealthy streams: their
    slots keep the pre-tick B/Ĥ/step/conv exactly like the active-mask
    freeze, so one poisoned input block can never contaminate persistent
    state.  ``with_health=False`` writes health 0 and commits on ``active``
    alone (the pre-containment behaviour; kept as the overhead baseline).

    ``with_moments=True`` publishes the cross-tile moment fold (``mom_ref``,
    per-stream [Σy², Σy⁴]) for the streams actually served this tick; like
    health it is a fresh per-tick verdict — frozen slots report 0 and
    ``with_moments=False`` writes zeros.  The moment write is observational
    only: B'/Ĥ'/step'/conv'/health' are bit-identical with moments on or
    off."""
    step = step_ref[...]  # (bs, 1)
    active = active_ref[...] != 0  # (bs, 1)
    # the paper's first-batch rule, per stream: γ̂ gated off at step 0
    gamma_hat = jnp.where(step == 0, 0.0, gamma_hat_ref[...])[:, :, None]
    h_prev = h_ref[...].astype(jnp.float32)  # (bs, n, n)
    h_new = gamma_hat * h_prev + acc_ref[...]
    db = jax.lax.dot_general(
        h_new, b, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # ΔB = Ĥ′B (bs, n, m)
    b_new = b + db
    # per-stream convergence statistic ‖ΔB‖_F / ‖B‖_F, in-register — no
    # extra HBM round-trip.  Padding-exact: padded rows/cols of B are
    # zero, so the padded Σw diagonal of Ĥ′ never reaches ΔB.
    num = jnp.sqrt(jnp.sum(db * db, axis=(1, 2)))  # (bs,)
    den = jnp.sqrt(jnp.sum(b * b, axis=(1, 2)))
    delta = (num / jnp.maximum(den, 1e-12))[:, None]  # (bs, 1)
    conv_prev = conv_ref[...].astype(jnp.float32)  # (bs, 1)
    if with_health:
        health = _health_word(b_new, h_new, ybad_ref[...], delta, blowup)
        commit = active & (health == 0)  # (bs, 1)
        # frozen slots report 0: health is a fresh per-tick verdict on the
        # streams that were actually served, not a carried statistic
        health_out_ref[...] = jnp.where(active, health, 0)
    else:
        commit = active
        health_out_ref[...] = jnp.zeros_like(health_out_ref)
    if with_moments:
        # (bs, 1) active mask broadcasts over the (bs, 2) [Σy², Σy⁴] fold
        moment_out_ref[...] = jnp.where(active, mom_ref[...], 0.0)
    else:
        moment_out_ref[...] = jnp.zeros_like(moment_out_ref)
    commit3 = commit[:, :, None]  # (bs, 1, 1)
    h_out_ref[...] = jnp.where(commit3, h_new, h_prev).astype(h_out_ref.dtype)
    b_out_ref[...] = jnp.where(commit3, b_new, b).astype(b_out_ref.dtype)
    step_out_ref[...] = step + jnp.where(commit, 1, 0).astype(step.dtype)
    conv_out_ref[...] = jnp.where(commit, delta, conv_prev)


def _fold_ybad_tile(y, ybad_ref, i, with_health: bool):
    """OR this tile's per-stream "Y went non-finite" flag into the (bs, 1)
    int32 scratch — the cross-tile leg of the health reduction.  A trace-time
    no-op when health is off (``with_health`` is static)."""
    if not with_health:
        return
    # Σ(y·0) is NaN iff the tile holds any non-finite (Inf·0 = NaN·0 = NaN)
    # and exactly 0 otherwise — no finite-overflow corner, and one multiply +
    # one reduction instead of the isfinite/not/any triple pass.
    marker = jnp.sum(y * 0.0, axis=(1, 2))[:, None]
    ybad = (~(marker == 0.0)).astype(jnp.int32)

    @pl.when(i == 0)
    def _ybad_init():
        ybad_ref[...] = ybad

    @pl.when(i > 0)
    def _ybad_acc():
        ybad_ref[...] = ybad_ref[...] | ybad


def _fold_moment_tile(y, mom_ref, i, with_moments: bool):
    """Accumulate this tile's per-stream raw moments [Σy², Σy⁴] into the
    (bs, 2) f32 scratch — the cross-tile leg of the kurtosis reduction, a
    third reduction riding the same Y registers as conv and the health fold.
    A trace-time no-op when moments are off (``with_moments`` is static)."""
    if not with_moments:
        return
    y2 = y * y  # one VPU square; y⁴ = (y²)² reuses it
    mom = jnp.stack(
        [jnp.sum(y2, axis=(1, 2)), jnp.sum(y2 * y2, axis=(1, 2))], axis=-1
    )  # (bs, 2)

    @pl.when(i == 0)
    def _mom_init():
        mom_ref[...] = mom

    @pl.when(i > 0)
    def _mom_acc():
        mom_ref[...] += mom


def _smbgd_step_bank_kernel(
    x_ref,
    w_ref,
    b_ref,
    h_ref,
    step_ref,
    gamma_hat_ref,
    active_ref,
    conv_ref,
    y_ref,
    b_out_ref,
    h_out_ref,
    step_out_ref,
    conv_out_ref,
    health_out_ref,
    moment_out_ref,
    acc_ref,
    ybad_ref,
    mom_ref,
    *,
    nonlin: str,
    n_tiles: int,
    with_health: bool,
    with_moments: bool,
    blowup: float,
):
    """One grid step of the whole-step megakernel (grid = (stream-blocks,
    tiles): each cell carries ``block_s`` streams as a batch dimension).

    Every tile: Y-tile batch-matmul + nonlinearity + weighted gradient fold
    into the VMEM scratch accumulator (plus, with health on, the Y-finite
    flag fold).  The stream-block's last tile additionally commits the SMBGD
    update and writes ``B'``/``Ĥ'``/``step'``/``health'`` for its streams.
    """
    i = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)  # (bs, bp, m)
    b = b_ref[...].astype(jnp.float32)  # (bs, n, m)
    y = jax.lax.dot_general(
        x, b, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # (bs, bp, n) — these streams' Y tiles, never re-read from HBM
    y_ref[...] = y.astype(y_ref.dtype)
    w = w_ref[...].astype(jnp.float32)  # (bs, bp, 1) — per-stream weight rows
    s_tile = _fold_tile_batched(y, w, nonlin)
    _fold_ybad_tile(y, ybad_ref, i, with_health)
    _fold_moment_tile(y, mom_ref, i, with_moments)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = s_tile

    @pl.when(i > 0)
    def _acc():
        acc_ref[...] += s_tile

    @pl.when(i == n_tiles - 1)
    def _commit():
        _commit_streams(
            b, h_ref, step_ref, gamma_hat_ref, active_ref, conv_ref,
            b_out_ref, h_out_ref, step_out_ref, conv_out_ref, health_out_ref,
            moment_out_ref, acc_ref, ybad_ref, mom_ref,
            with_health=with_health, with_moments=with_moments, blowup=blowup,
        )


def _x_tile_dma(x_hbm, xbuf_ref, sem_ref, slot, t, n_tiles, block_s, block_p):
    """Async-copy descriptor for global tile ``t``'s X block (stream-block
    ``t // n_tiles``, tile ``t % n_tiles``) into double-buffer ``slot``."""
    sb = t // n_tiles
    i = jax.lax.rem(t, n_tiles)
    return pltpu.make_async_copy(
        x_hbm.at[
            pl.ds(sb * block_s, block_s), pl.ds(i * block_p, block_p), :
        ],
        xbuf_ref.at[slot],
        sem_ref.at[slot],
    )


def _smbgd_step_bank_kernel_prefetch(
    x_hbm,
    w_ref,
    b_ref,
    h_ref,
    step_ref,
    gamma_hat_ref,
    active_ref,
    conv_ref,
    y_ref,
    b_out_ref,
    h_out_ref,
    step_out_ref,
    conv_out_ref,
    health_out_ref,
    moment_out_ref,
    acc_ref,
    ybad_ref,
    mom_ref,
    xbuf_ref,
    sem_ref,
    *,
    nonlin: str,
    n_tiles: int,
    n_sblocks: int,
    block_s: int,
    block_p: int,
    with_health: bool,
    with_moments: bool,
    blowup: float,
):
    """Double-buffered variant of ``_smbgd_step_bank_kernel``: X rides in
    ``pltpu.ANY`` (HBM) and each grid step starts the NEXT tile's DMA before
    folding the CURRENT tile, alternating two VMEM buffers.  The prefetch
    window runs over the GLOBAL tile counter ``t = sb·n_tiles + i``, so it
    crosses stream-block boundaries — only tile 0 of the whole launch pays an
    un-overlapped DMA.  Everything downstream of the X load is byte-for-byte
    the synchronous kernel (bit-identity on the interpret path is tested)."""
    sb = pl.program_id(0)
    i = pl.program_id(1)
    t = sb * n_tiles + i  # global tile counter — the prefetch clock
    total = n_sblocks * n_tiles

    def dma(slot, t_idx):
        return _x_tile_dma(
            x_hbm, xbuf_ref, sem_ref, slot, t_idx, n_tiles, block_s, block_p
        )

    @pl.when(t == 0)
    def _warmup():  # the one DMA nothing can hide
        dma(0, 0).start()

    @pl.when(t + 1 < total)
    def _prefetch_next():  # overlap the next tile's DMA with this fold
        dma(jax.lax.rem(t + 1, 2), t + 1).start()

    dma(jax.lax.rem(t, 2), t).wait()
    x = xbuf_ref[jax.lax.rem(t, 2)].astype(jnp.float32)  # (bs, bp, m)
    b = b_ref[...].astype(jnp.float32)  # (bs, n, m)
    y = jax.lax.dot_general(
        x, b, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    y_ref[...] = y.astype(y_ref.dtype)
    w = w_ref[...].astype(jnp.float32)
    s_tile = _fold_tile_batched(y, w, nonlin)
    _fold_ybad_tile(y, ybad_ref, i, with_health)
    _fold_moment_tile(y, mom_ref, i, with_moments)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = s_tile

    @pl.when(i > 0)
    def _acc():
        acc_ref[...] += s_tile

    @pl.when(i == n_tiles - 1)
    def _commit():
        _commit_streams(
            b, h_ref, step_ref, gamma_hat_ref, active_ref, conv_ref,
            b_out_ref, h_out_ref, step_out_ref, conv_out_ref, health_out_ref,
            moment_out_ref, acc_ref, ybad_ref, mom_ref,
            with_health=with_health, with_moments=with_moments, blowup=blowup,
        )


def _smbgd_probe_bank_kernel(
    x_ref,
    w_ref,
    b_ref,
    h_ref,
    step_ref,
    gamma_hat_ref,
    active_ref,
    conv_ref,
    conv_out_ref,
    health_out_ref,
    moment_out_ref,
    acc_ref,
    ybad_ref,
    mom_ref,
    *,
    nonlin: str,
    n_tiles: int,
    with_health: bool,
    with_moments: bool,
    blowup: float,
):
    """Freeze-only probe variant of the megakernel: same ``(stream-blocks,
    tiles)`` grid and the same per-tile math (Y-tile batch-matmul +
    nonlinearity + weighted gradient fold), but the last tile computes ONLY
    the convergence statistic the commit WOULD produce — ``‖Ĥ′B‖_F/‖B‖_F``
    from the virtual ``Ĥ′ = γ̂Ĥ + S`` — and writes nothing else.  No ``Y``,
    ``B'``, ``Ĥ'`` or ``step'`` ever reach HBM: the out-of-band drift probe
    of thousands of parked (frozen) separators is one (S,)-float launch."""
    i = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)  # (bs, bp, m)
    b = b_ref[...].astype(jnp.float32)  # (bs, n, m)
    y = jax.lax.dot_general(
        x, b, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # (bs, bp, n) — stays in VMEM; probes never publish Y
    w = w_ref[...].astype(jnp.float32)  # (bs, bp, 1)
    s_tile = _fold_tile_batched(y, w, nonlin)
    _fold_ybad_tile(y, ybad_ref, i, with_health)
    _fold_moment_tile(y, mom_ref, i, with_moments)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = s_tile

    @pl.when(i > 0)
    def _acc():
        acc_ref[...] += s_tile

    @pl.when(i == n_tiles - 1)
    def _probe():
        _probe_streams(
            b, h_ref, step_ref, gamma_hat_ref, active_ref, conv_ref,
            conv_out_ref, health_out_ref, moment_out_ref,
            acc_ref, ybad_ref, mom_ref,
            with_health=with_health, with_moments=with_moments, blowup=blowup,
        )


def _probe_streams(
    b,
    h_ref,
    step_ref,
    gamma_hat_ref,
    active_ref,
    conv_ref,
    conv_out_ref,
    health_out_ref,
    moment_out_ref,
    acc_ref,
    ybad_ref,
    mom_ref,
    *,
    with_health: bool,
    with_moments: bool,
    blowup: float,
):
    """The freeze-only probe tail shared by the sync and prefetch probe
    kernels: the conv statistic a commit WOULD produce, and nothing else.
    ``with_health`` additionally reports the health word that commit WOULD
    have raised (from the virtual ``B' = B + ΔB``) — quarantined sessions
    are probed for sanity through the same launch that probes parked ones
    for drift."""
    step = step_ref[...]  # (bs, 1)
    active = active_ref[...] != 0  # (bs, 1)
    gamma_hat = jnp.where(step == 0, 0.0, gamma_hat_ref[...])[:, :, None]
    h_new = gamma_hat * h_ref[...].astype(jnp.float32) + acc_ref[...]
    db = jax.lax.dot_general(
        h_new, b, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # virtual ΔB = Ĥ′B (bs, n, m) — computed, never committed
    num = jnp.sqrt(jnp.sum(db * db, axis=(1, 2)))  # (bs,)
    den = jnp.sqrt(jnp.sum(b * b, axis=(1, 2)))
    delta = (num / jnp.maximum(den, 1e-12))[:, None]  # (bs, 1)
    conv_prev = conv_ref[...].astype(jnp.float32)
    if with_health:
        health = _health_word(b + db, h_new, ybad_ref[...], delta, blowup)
        health_out_ref[...] = jnp.where(active, health, 0)
    else:
        health_out_ref[...] = jnp.zeros_like(health_out_ref)
    if with_moments:
        moment_out_ref[...] = jnp.where(active, mom_ref[...], 0.0)
    else:
        moment_out_ref[...] = jnp.zeros_like(moment_out_ref)
    conv_out_ref[...] = jnp.where(active, delta, conv_prev)


def _smbgd_probe_bank_kernel_prefetch(
    x_hbm,
    w_ref,
    b_ref,
    h_ref,
    step_ref,
    gamma_hat_ref,
    active_ref,
    conv_ref,
    conv_out_ref,
    health_out_ref,
    moment_out_ref,
    acc_ref,
    ybad_ref,
    mom_ref,
    xbuf_ref,
    sem_ref,
    *,
    nonlin: str,
    n_tiles: int,
    n_sblocks: int,
    block_s: int,
    block_p: int,
    with_health: bool,
    with_moments: bool,
    blowup: float,
):
    """Double-buffered variant of ``_smbgd_probe_bank_kernel`` — the same
    global-tile-counter prefetch window as the step kernel's prefetch
    variant, with the freeze-only probe tail (no ``Y``/state writes)."""
    sb = pl.program_id(0)
    i = pl.program_id(1)
    t = sb * n_tiles + i
    total = n_sblocks * n_tiles

    def dma(slot, t_idx):
        return _x_tile_dma(
            x_hbm, xbuf_ref, sem_ref, slot, t_idx, n_tiles, block_s, block_p
        )

    @pl.when(t == 0)
    def _warmup():
        dma(0, 0).start()

    @pl.when(t + 1 < total)
    def _prefetch_next():
        dma(jax.lax.rem(t + 1, 2), t + 1).start()

    dma(jax.lax.rem(t, 2), t).wait()
    x = xbuf_ref[jax.lax.rem(t, 2)].astype(jnp.float32)  # (bs, bp, m)
    b = b_ref[...].astype(jnp.float32)  # (bs, n, m)
    y = jax.lax.dot_general(
        x, b, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    w = w_ref[...].astype(jnp.float32)
    s_tile = _fold_tile_batched(y, w, nonlin)
    _fold_ybad_tile(y, ybad_ref, i, with_health)
    _fold_moment_tile(y, mom_ref, i, with_moments)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = s_tile

    @pl.when(i > 0)
    def _acc():
        acc_ref[...] += s_tile

    @pl.when(i == n_tiles - 1)
    def _probe():
        _probe_streams(
            b, h_ref, step_ref, gamma_hat_ref, active_ref, conv_ref,
            conv_out_ref, health_out_ref, moment_out_ref,
            acc_ref, ybad_ref, mom_ref,
            with_health=with_health, with_moments=with_moments, blowup=blowup,
        )


def smbgd_probe_bank_pallas(
    X: jnp.ndarray,
    W: jnp.ndarray,
    B: jnp.ndarray,
    H_hat: jnp.ndarray,
    step: jnp.ndarray,
    gamma_hat: jnp.ndarray,
    active: jnp.ndarray,
    conv: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int = 512,
    block_s: int = 1,
    interpret: bool = True,
    prefetch: bool = False,
    health: bool = True,
    moments: bool = False,
    blowup: float = HEALTH_BLOWUP_BOUND,
):
    """Batched virtual-conv probe: ONE launch over frozen bank state.

    Same pre-padded persistent-layout contract as ``smbgd_step_bank_pallas``
    but the only outputs are ``conv' (S, 1)`` — the per-stream statistic a
    commit would have produced (``conv`` carried through for masked-out
    streams) — ``health' (S, 1)`` int32, the health word that commit
    would have raised (0 when ``health=False`` or for masked-out streams),
    and ``moments' (S, 2)`` f32, the raw [Σy², Σy⁴] fold over the probe's Y
    (0 when ``moments=False`` or for masked-out streams).  The state
    operands are read-only: probing never mutates the frozen separators.
    ``prefetch=True`` double-buffers the X tile DMA (see the step kernel's
    prefetch notes; bit-identical on the interpret path).
    """
    S, P, m = X.shape
    n = B.shape[1]
    assert P % block_p == 0, (P, block_p)
    assert S % block_s == 0, (S, block_s)
    assert B.shape == (S, n, m) and H_hat.shape == (S, n, n)
    n_tiles = P // block_p
    bs = block_s
    n_sblocks = S // bs
    common_specs = [
        pl.BlockSpec((bs, block_p, 1), lambda s, i: (s, i, 0)),
        pl.BlockSpec((bs, n, m), lambda s, i: (s, 0, 0)),
        pl.BlockSpec((bs, n, n), lambda s, i: (s, 0, 0)),
        pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
        pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
        pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
        pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
    ]
    if prefetch:
        kernel = functools.partial(
            _smbgd_probe_bank_kernel_prefetch,
            nonlin=nonlinearity, n_tiles=n_tiles, n_sblocks=n_sblocks,
            block_s=bs, block_p=block_p, with_health=health,
            with_moments=moments, blowup=blowup,
        )
        x_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch = [
            pltpu.VMEM((bs, n, n), jnp.float32),
            pltpu.VMEM((bs, 1), jnp.int32),  # cross-tile Y-finite fold
            pltpu.VMEM((bs, MOMENT_LEAVES), jnp.float32),  # [Σy², Σy⁴] fold
            pltpu.VMEM((2, bs, block_p, m), X.dtype),  # the double buffer
            pltpu.SemaphoreType.DMA((2,)),
        ]
        extra = _prefetch_call_params()
    else:
        kernel = functools.partial(
            _smbgd_probe_bank_kernel, nonlin=nonlinearity, n_tiles=n_tiles,
            with_health=health, with_moments=moments, blowup=blowup,
        )
        x_spec = pl.BlockSpec((bs, block_p, m), lambda s, i: (s, i, 0))
        scratch = [
            pltpu.VMEM((bs, n, n), jnp.float32),
            pltpu.VMEM((bs, 1), jnp.int32),
            pltpu.VMEM((bs, MOMENT_LEAVES), jnp.float32),
        ]
        extra = {}
    return pl.pallas_call(
        kernel,
        grid=(n_sblocks, n_tiles),
        in_specs=[x_spec] + common_specs,
        out_specs=[
            pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
            pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
            pl.BlockSpec((bs, MOMENT_LEAVES), lambda s, i: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
            jax.ShapeDtypeStruct((S, MOMENT_LEAVES), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
        **extra,
    )(X, W, B, H_hat, step, gamma_hat, active, conv)


def _prefetch_call_params() -> dict:
    """Extra ``pallas_call`` kwargs for the prefetch kernels: the global-tile
    prefetch window threads DMA state across grid cells, so BOTH grid
    dimensions must execute sequentially on real TPU ("arbitrary", never
    "parallel" — Mosaic must not megacore-split the grid).  Interpret mode
    executes sequentially anyway; older JAX without ``TPUCompilerParams``
    just omits the hint (interpret-only environments)."""
    params = getattr(pltpu, "TPUCompilerParams", None)
    if params is None:
        return {}
    return {
        "compiler_params": params(
            dimension_semantics=("arbitrary", "arbitrary")
        )
    }


def smbgd_step_bank_pallas(
    X: jnp.ndarray,
    W: jnp.ndarray,
    B: jnp.ndarray,
    H_hat: jnp.ndarray,
    step: jnp.ndarray,
    gamma_hat: jnp.ndarray,
    active: jnp.ndarray,
    conv: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int = 512,
    block_s: int = 1,
    interpret: bool = True,
    prefetch: bool = False,
    health: bool = True,
    moments: bool = False,
    blowup: float = HEALTH_BLOWUP_BOUND,
):
    """Whole-step fused SMBGD bank tick: ONE ``(stream-blocks, P-tiles)``
    launch.

    Expects pre-padded persistent-layout inputs (see ops.bank_layout):
    ``X (S, P, m)``, ``W (S, P, 1)``, ``B (S, n, m)``, ``H_hat (S, n, n)``,
    ``step (S, 1) int32``, ``gamma_hat (S, 1) f32``, ``active (S, 1) int32``,
    ``conv (S, 1) f32`` (previous per-stream convergence statistic — carried
    through unchanged for frozen streams).  ``block_s`` streams ride one grid
    cell as a batch dimension (S % block_s == 0) — per-stream math is
    independent, so the result is block_s invariant; larger blocks amortize
    per-cell grid overhead.  ``prefetch=True`` replaces the X BlockSpec
    pipeline with an explicit double-buffered ``make_async_copy`` from
    ``pltpu.ANY`` — overlapping the next tile's DMA with the current fold —
    and is bit-identical on the interpret path (tested).  ``B``/``H_hat``
    may live in a reduced-precision storage dtype (bf16): the kernel casts
    to f32 at load, accumulates the gradient and the commit in f32, and
    casts back only at the output writes.  Returns ``(Y (S, P, n), B',
    H_hat', step', conv', health', moments')`` — the full next bank state
    plus outputs, with no intermediate tensors materialized in HBM;
    ``conv'`` is the relative update magnitude ``‖Ĥ′B‖_F/‖B‖_F`` computed
    at commit time, ``health' (S, 1)`` int32 is the per-stream fault bitmask
    (see ``_health_word``; all-zero when ``health=False``), and
    ``moments' (S, 2)`` f32 is the raw [Σy², Σy⁴] per-stream fold over this
    tick's Y (all-zero when ``moments=False`` or for frozen slots; purely
    observational — every other output is bit-identical with moments on or
    off).  With ``health=True`` an unhealthy stream's commit is REFUSED
    in-kernel: its slot keeps the pre-tick state exactly like an
    ``active``-masked stream.
    """
    S, P, m = X.shape
    n = B.shape[1]
    assert P % block_p == 0, (P, block_p)
    assert S % block_s == 0, (S, block_s)
    assert B.shape == (S, n, m) and H_hat.shape == (S, n, n)
    n_tiles = P // block_p
    bs = block_s
    n_sblocks = S // bs
    common_specs = [
        pl.BlockSpec((bs, block_p, 1), lambda s, i: (s, i, 0)),
        pl.BlockSpec((bs, n, m), lambda s, i: (s, 0, 0)),
        pl.BlockSpec((bs, n, n), lambda s, i: (s, 0, 0)),
        pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
        pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
        pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
        pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
    ]
    if prefetch:
        kernel = functools.partial(
            _smbgd_step_bank_kernel_prefetch,
            nonlin=nonlinearity, n_tiles=n_tiles, n_sblocks=n_sblocks,
            block_s=bs, block_p=block_p, with_health=health,
            with_moments=moments, blowup=blowup,
        )
        x_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch = [
            pltpu.VMEM((bs, n, n), jnp.float32),
            pltpu.VMEM((bs, 1), jnp.int32),  # cross-tile Y-finite fold
            pltpu.VMEM((bs, MOMENT_LEAVES), jnp.float32),  # [Σy², Σy⁴] fold
            pltpu.VMEM((2, bs, block_p, m), X.dtype),  # the double buffer
            pltpu.SemaphoreType.DMA((2,)),
        ]
        extra = _prefetch_call_params()
    else:
        kernel = functools.partial(
            _smbgd_step_bank_kernel, nonlin=nonlinearity, n_tiles=n_tiles,
            with_health=health, with_moments=moments, blowup=blowup,
        )
        x_spec = pl.BlockSpec((bs, block_p, m), lambda s, i: (s, i, 0))
        scratch = [
            pltpu.VMEM((bs, n, n), jnp.float32),
            pltpu.VMEM((bs, 1), jnp.int32),
            pltpu.VMEM((bs, MOMENT_LEAVES), jnp.float32),
        ]
        extra = {}
    return pl.pallas_call(
        kernel,
        grid=(n_sblocks, n_tiles),
        in_specs=[x_spec] + common_specs,
        out_specs=[
            pl.BlockSpec((bs, block_p, n), lambda s, i: (s, i, 0)),
            pl.BlockSpec((bs, n, m), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((bs, n, n), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
            pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
            pl.BlockSpec((bs, 1), lambda s, i: (s, 0)),
            pl.BlockSpec((bs, MOMENT_LEAVES), lambda s, i: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, P, n), X.dtype),
            jax.ShapeDtypeStruct((S, n, m), B.dtype),
            jax.ShapeDtypeStruct((S, n, n), H_hat.dtype),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
            jax.ShapeDtypeStruct((S, MOMENT_LEAVES), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
        **extra,
    )(X, W, B, H_hat, step, gamma_hat, active, conv)
