"""Pallas TPU kernel: fused batched EASI relative gradient (the paper's datapath).

Computes, for ``Y (P, n)`` and within-batch SMBGD weights ``w (P,)``:

    S = (Σ_p w_p) I − Yᵀ W Y − Gᵀ W Y + (Gᵀ W Y)ᵀ,   G = g(Y),  W = diag(w)

in ONE pass over Y tiled along P: each grid step loads a ``(block_p, n)`` tile
into VMEM, evaluates the nonlinearity in-register (never materializing G in
HBM), performs the two weighted MXU matmuls, and accumulates the (n, n) result
in place.  This is the TPU-native replacement for the paper's one-sample-per-
clock FPGA pipeline: arithmetic intensity grows from O(1) (rank-1 outer-product
updates) to O(block_p) (rank-P matmuls) — MXU-bound instead of HBM-bound.

The *bank* variant (``easi_gradient_bank_pallas``) adds a leading **streams**
grid dimension: for ``Y (S, P, n)`` the grid is ``(S, P // block_p)`` and one
launch folds every stream's tiles — S independent separator sessions cost one
kernel dispatch instead of S.  The stream axis is the majormost grid dim, so
for each stream the tile index still iterates innermost and the per-stream
(n, n) accumulator pattern is unchanged.

Layout notes (TPU target; validated on CPU via interpret=True):
  * last dim n is padded to a multiple of 128 (lane width) by ops.py,
  * block_p is a multiple of 8 (f32 sublane) — default 512,
  * accumulation in fp32 regardless of input dtype (preferred_element_type).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.nonlinearities import NONLINEARITIES

# The kernel nonlinearity table IS the core registry: every g(.) there is pure
# jnp elementwise (VPU-lowerable), so registering a new nonlinearity in
# core/nonlinearities.py makes it available inside the kernel automatically —
# the two banks cannot drift.
NONLIN_KERNELS: dict = NONLINEARITIES


def _fold_tile(y, w, nonlin: str):
    """Fold one (bp, n) fp32 tile of Y into an (n, n) gradient contribution."""
    g = NONLIN_KERNELS[nonlin](y)
    yw = y * w  # weighted rows — one VPU pass
    # Two MXU contractions over the tile's P dimension (rank-bp updates).
    gram = jax.lax.dot_general(
        y, yw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # Yᵀ W Y  (n, n)
    cross = jax.lax.dot_general(
        g, yw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # Gᵀ W Y  (n, n)
    n = gram.shape[0]
    # Per-tile identity contribution: Σ_tiles sum(w_tile)·I == sum(w)·I overall.
    eye = jnp.eye(n, dtype=jnp.float32) * jnp.sum(w)
    return eye - gram - cross + cross.T


def _easi_gradient_kernel(y_ref, w_ref, out_ref, *, nonlin: str):
    """One grid step: fold a (block_p, n) tile of Y into the (n, n) accumulator."""
    i = pl.program_id(0)
    y = y_ref[...].astype(jnp.float32)  # (bp, n)
    w = w_ref[...].astype(jnp.float32)  # (bp, 1)
    s_tile = _fold_tile(y, w, nonlin)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = s_tile

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += s_tile


def easi_gradient_pallas(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Launch the fused gradient kernel.  Expects pre-padded inputs:
    ``Y (P, n)`` with P % block_p == 0 and n lane-aligned; ``w (P, 1)``.
    Returns ``S (n, n)`` in fp32."""
    P, n = Y.shape
    assert P % block_p == 0, (P, block_p)
    grid = (P // block_p,)
    kernel = functools.partial(_easi_gradient_kernel, nonlin=nonlinearity)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, n), lambda i: (i, 0)),
            pl.BlockSpec((block_p, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(Y, w)


def _easi_gradient_bank_kernel(y_ref, w_ref, out_ref, *, nonlin: str):
    """One grid step of the bank kernel: fold stream s's tile i into its
    (n, n) accumulator.  Grid is (streams, tiles); tiles iterate innermost so
    ``i == 0`` marks the first visit to stream s's output block."""
    i = pl.program_id(1)
    y = y_ref[0].astype(jnp.float32)  # (bp, n) — block is (1, bp, n)
    w = w_ref[...].astype(jnp.float32)  # (bp, 1) — shared across streams
    s_tile = _fold_tile(y, w, nonlin)

    @pl.when(i == 0)
    def _init():
        out_ref[0] = s_tile

    @pl.when(i > 0)
    def _acc():
        out_ref[0] += s_tile


def easi_gradient_bank_pallas(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched-stream launch: ``Y (S, P, n)``, shared weights ``w (P, 1)`` →
    ``S_out (S, n, n)`` fp32.  One kernel dispatch folds all S·(P/block_p)
    tiles via the (streams, tiles) grid.  Expects pre-padded inputs as in
    ``easi_gradient_pallas``."""
    S, P, n = Y.shape
    assert P % block_p == 0, (P, block_p)
    grid = (S, P // block_p)
    kernel = functools.partial(_easi_gradient_bank_kernel, nonlin=nonlinearity)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_p, n), lambda s, i: (s, i, 0)),
            pl.BlockSpec((block_p, 1), lambda s, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, n), lambda s, i: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, n, n), jnp.float32),
        interpret=interpret,
    )(Y, w)
