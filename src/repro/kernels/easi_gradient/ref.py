"""Pure-jnp oracle for the fused EASI-gradient kernel.

Independent re-derivation (kept deliberately naive — per-sample outer products
via einsum) so kernel bugs cannot hide behind a shared closed form.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import nonlinearities


def easi_gradient_ref(
    Y: jnp.ndarray, w: jnp.ndarray, nonlinearity: str = "cubic"
) -> jnp.ndarray:
    """S = Σ_p w_p [ I − y_p y_pᵀ − g(y_p) y_pᵀ + y_p g(y_p)ᵀ ]   (fp32)."""
    Y = Y.astype(jnp.float32)
    w = w.reshape(-1).astype(jnp.float32)
    g = nonlinearities.get(nonlinearity)
    G = g(Y)
    n = Y.shape[1]
    eye = jnp.eye(n, dtype=jnp.float32) * jnp.sum(w)
    yy = jnp.einsum("p,pi,pj->ij", w, Y, Y)
    gy = jnp.einsum("p,pi,pj->ij", w, G, Y)
    yg = jnp.einsum("p,pi,pj->ij", w, Y, G)
    return eye - yy - gy + yg


def easi_gradient_bank_ref(
    Y: jnp.ndarray, w: jnp.ndarray, nonlinearity: str = "cubic"
) -> jnp.ndarray:
    """Bank oracle: per-stream ``easi_gradient_ref`` stacked over the leading
    stream axis of ``Y (S, P, n)`` — deliberately a plain Python loop so the
    fused (streams, tiles) kernel is checked against S truly independent
    single-stream computations."""
    return jnp.stack(
        [easi_gradient_ref(Y[s], w, nonlinearity) for s in range(Y.shape[0])]
    )


def health_word_ref(B_new, H_new, Y, delta, blowup: float) -> int:
    """Independent per-stream health-word derivation (plain Python ints, no
    shared helper with the kernel): bit 0 non-finite B', bit 1 non-finite
    Ĥ', bit 2 non-finite Y, bit 3 relative update above ``blowup`` (a NaN
    delta counts as a blow-up)."""
    word = 0
    if not bool(jnp.all(jnp.isfinite(B_new))):
        word |= 1
    if not bool(jnp.all(jnp.isfinite(H_new))):
        word |= 2
    if not bool(jnp.all(jnp.isfinite(Y))):
        word |= 4
    if not bool(delta <= blowup):
        word |= 8
    return word


def moments_ref(Y: jnp.ndarray):
    """Independent raw-moment derivation for one stream's ``Y (P, n)``:
    the [Σy², Σy⁴] pair the kernel folds tile-by-tile, re-derived here as
    whole-array reductions (no tiling, no shared helper)."""
    Y = Y.astype(jnp.float32)
    return jnp.stack([jnp.sum(Y**2), jnp.sum(Y**4)])


def smbgd_step_bank_ref(
    X: jnp.ndarray,
    W: jnp.ndarray,
    B: jnp.ndarray,
    H_hat: jnp.ndarray,
    step: jnp.ndarray,
    gamma_hat: jnp.ndarray,
    active: jnp.ndarray,
    conv=None,
    nonlinearity: str = "cubic",
    health: bool = True,
    moments: bool = False,
    blowup: float = 100.0,
):
    """Whole-step oracle for the megakernel: a plain per-stream Python loop of
    naive single-stream steps (``Y = X Bᵀ``, per-sample outer-product gradient
    sum via ``easi_gradient_ref``, then the literal commit with the step-0 γ
    gate and active-mask freeze) plus the per-stream convergence statistic
    ``‖Ĥ′B‖_F/‖B‖_F`` (carried through unchanged for frozen streams; ``conv``
    defaults to +inf), the per-stream health word (``health_word_ref``;
    unhealthy streams refuse their commit exactly like frozen ones) and the
    per-stream raw moments [Σy², Σy⁴] (``moments_ref``; zeros for frozen
    streams or when ``moments=False``).  Same signature/shapes as
    ``ops.smbgd_step_bank`` minus the padding requirement."""
    S = X.shape[0]
    W = jnp.asarray(W).reshape(S, -1)
    step = jnp.asarray(step).reshape(S)
    gamma_hat = jnp.asarray(gamma_hat).reshape(S)
    active = jnp.asarray(active).reshape(S)
    if conv is None:
        conv = jnp.full((S,), jnp.inf, jnp.float32)
    conv = jnp.asarray(conv).reshape(S).astype(jnp.float32)
    Ys, Bs, Hs, steps, convs, healths, moms = [], [], [], [], [], [], []
    for s in range(S):
        B_s = B[s].astype(jnp.float32)
        Y_s = X[s].astype(jnp.float32) @ B_s.T
        S_s = easi_gradient_ref(Y_s, W[s], nonlinearity)
        gam = jnp.where(step[s] == 0, 0.0, gamma_hat[s])
        H_new = gam * H_hat[s].astype(jnp.float32) + S_s
        dB = H_new @ B_s
        B_new = B_s + dB
        delta = jnp.sqrt(jnp.sum(dB * dB)) / jnp.maximum(
            jnp.sqrt(jnp.sum(B_s * B_s)), 1e-12
        )
        act = bool(active[s])
        word = health_word_ref(B_new, H_new, Y_s, delta, blowup) if health else 0
        commit = act and word == 0
        Ys.append(Y_s.astype(X.dtype))
        Bs.append((B_new if commit else B[s].astype(jnp.float32)).astype(B.dtype))
        Hs.append(
            (H_new if commit else H_hat[s].astype(jnp.float32)).astype(H_hat.dtype)
        )
        steps.append(step[s] + (1 if commit else 0))
        convs.append(delta if commit else conv[s])
        healths.append(word if act else 0)
        if moments and act:
            moms.append(moments_ref(Y_s))
        else:
            moms.append(jnp.zeros((2,), jnp.float32))
    return (
        jnp.stack(Ys),
        jnp.stack(Bs),
        jnp.stack(Hs),
        jnp.stack(steps),
        jnp.stack(convs),
        jnp.asarray(healths, jnp.int32),
        jnp.stack(moms),
    )
