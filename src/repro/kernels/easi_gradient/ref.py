"""Pure-jnp oracle for the fused EASI-gradient kernel.

Independent re-derivation (kept deliberately naive — per-sample outer products
via einsum) so kernel bugs cannot hide behind a shared closed form.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import nonlinearities


def easi_gradient_ref(
    Y: jnp.ndarray, w: jnp.ndarray, nonlinearity: str = "cubic"
) -> jnp.ndarray:
    """S = Σ_p w_p [ I − y_p y_pᵀ − g(y_p) y_pᵀ + y_p g(y_p)ᵀ ]   (fp32)."""
    Y = Y.astype(jnp.float32)
    w = w.reshape(-1).astype(jnp.float32)
    g = nonlinearities.get(nonlinearity)
    G = g(Y)
    n = Y.shape[1]
    eye = jnp.eye(n, dtype=jnp.float32) * jnp.sum(w)
    yy = jnp.einsum("p,pi,pj->ij", w, Y, Y)
    gy = jnp.einsum("p,pi,pj->ij", w, G, Y)
    yg = jnp.einsum("p,pi,pj->ij", w, Y, G)
    return eye - yy - gy + yg


def easi_gradient_bank_ref(
    Y: jnp.ndarray, w: jnp.ndarray, nonlinearity: str = "cubic"
) -> jnp.ndarray:
    """Bank oracle: per-stream ``easi_gradient_ref`` stacked over the leading
    stream axis of ``Y (S, P, n)`` — deliberately a plain Python loop so the
    fused (streams, tiles) kernel is checked against S truly independent
    single-stream computations."""
    return jnp.stack(
        [easi_gradient_ref(Y[s], w, nonlinearity) for s in range(Y.shape[0])]
    )
