"""Jit'd public wrappers for the EASI-gradient kernels: padding, alignment,
dtype policy and the interpret-mode switch.

``REPRO_PALLAS_INTERPRET`` controls lowering: the default (``1``) runs the
kernels through the Pallas interpreter so the CPU container can execute and
test them; on real TPU set ``REPRO_PALLAS_INTERPRET=0`` to compile to Mosaic.
Both entry points honour it:

  * ``easi_gradient``       — single stream,   ``Y (P, n)``    → ``S (n, n)``
  * ``easi_gradient_bank``  — S streams fused, ``Y (S, P, n)`` → ``S (S, n, n)``
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.easi_gradient.easi_gradient import (
    easi_gradient_bank_pallas,
    easi_gradient_pallas,
)

_LANE = 128  # TPU lane width (last-dim alignment)
_SUBLANE = 8  # f32 sublane


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_geometry(P: int, n: int, block_p: int | None, interpret: bool):
    n_pad = _round_up(max(n, _SUBLANE), _LANE if not interpret else _SUBLANE)
    if block_p is None:
        block_p = min(512, _round_up(P, _SUBLANE))
    P_pad = _round_up(P, block_p)
    return P_pad, n_pad, block_p


@functools.partial(jax.jit, static_argnames=("nonlinearity", "block_p", "interpret"))
def easi_gradient(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Weighted EASI relative-gradient sum ``S (n, n)`` for ``Y (P, n)``, ``w (P,)``.

    Pads n to the 128-lane boundary and P to a sublane-aligned block; zero
    padding is exact (zero rows/cols contribute nothing; the identity term is
    computed from the real Σw and sliced back).  All nonlinearities in the bank
    satisfy g(0)=0, which the padding relies on (asserted in tests).
    """
    if interpret is None:
        interpret = _interpret_default()
    P, n = Y.shape
    P_pad, n_pad, block_p = _pad_geometry(P, n, block_p, interpret)
    Yp = jnp.zeros((P_pad, n_pad), Y.dtype).at[:P, :n].set(Y)
    wp = jnp.zeros((P_pad, 1), jnp.float32).at[:P, 0].set(w.reshape(-1))
    S = easi_gradient_pallas(
        Yp, wp, nonlinearity=nonlinearity, block_p=block_p, interpret=interpret
    )
    # Padded diagonal entries carry sum(w)·I — slicing removes them.
    return S[:n, :n]


@functools.partial(jax.jit, static_argnames=("nonlinearity", "block_p", "interpret"))
def easi_gradient_bank(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Bank form: ``Y (S, P, n)`` with shared weights ``w (P,)`` →
    ``S_out (S, n, n)`` in one fused (streams, tiles) launch.

    Same padding contract as ``easi_gradient`` — padding rows/cols are zero and
    contribute nothing (g(0)=0 for the whole bank), so each stream's slice is
    bit-identical to a single-stream launch with the same block geometry.
    """
    if interpret is None:
        interpret = _interpret_default()
    S_streams, P, n = Y.shape
    P_pad, n_pad, block_p = _pad_geometry(P, n, block_p, interpret)
    Yp = jnp.zeros((S_streams, P_pad, n_pad), Y.dtype).at[:, :P, :n].set(Y)
    wp = jnp.zeros((P_pad, 1), jnp.float32).at[:P, 0].set(w.reshape(-1))
    S = easi_gradient_bank_pallas(
        Yp, wp, nonlinearity=nonlinearity, block_p=block_p, interpret=interpret
    )
    return S[:, :n, :n]
