"""Jit'd public wrappers for the EASI-gradient kernels: padding, alignment,
dtype policy and the interpret-mode switch.

``REPRO_PALLAS_INTERPRET`` controls lowering: the default (``1``) runs the
kernels through the Pallas interpreter so the CPU container can execute and
test them; on real TPU set ``REPRO_PALLAS_INTERPRET=0`` to compile to Mosaic.
Both entry points honour it:

  * ``easi_gradient``       — single stream,   ``Y (P, n)``    → ``S (n, n)``
  * ``easi_gradient_bank``  — S streams fused, ``Y (S, P, n)`` → ``S (S, n, n)``
  * ``smbgd_step_bank``     — whole-step megakernel: one launch computes
    ``Y = X Bᵀ``, the weighted gradient sum, the SMBGD commit AND the
    per-stream convergence statistic (relative update magnitude) for all S
    streams, on persistent-padded state (``BankLayout``).
  * ``smbgd_probe_bank``    — freeze-only fast path of the megakernel: the
    same launch geometry computes ONLY the per-stream convergence statistic
    a commit WOULD produce — no ``Y``/``B'``/``Ĥ'`` writes.  The batched
    out-of-band drift probe of parked (frozen) separators.

Block-aligned inputs take the zero-copy fast path: when an array already
matches its padded geometry the ``zeros().at[].set()`` staging copy is skipped
entirely — persistent-layout callers (``stream.SeparatorBank`` in fused mode)
pay no per-step padding.

Memory-system knobs (PR 6):

  * ``prefetch=True`` on the megakernel/probe entry points swaps the X
    BlockSpec pipeline for an explicit double-buffered ``make_async_copy``
    (bit-identical on the interpret path),
  * ``bank_layout(dtype_policy="bf16")`` stores persistent ``B``/``Ĥ`` in
    bf16 (f32 accumulation inside the kernels) — ``BankLayout`` owns the
    byte accounting (``persistent_bytes_per_session``,
    ``tick_hbm_bytes_per_stream``),
  * the default ``block_s`` is derived from the layout's actual VMEM
    residency against a budget (``default_block_s``), not a hardcoded cap.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.easi_gradient.easi_gradient import (  # noqa: F401 — health
    HEALTH_BLOWUP,  # constants re-exported: ops is the public kernel surface
    HEALTH_BLOWUP_BOUND,
    HEALTH_NONFINITE_B,
    HEALTH_NONFINITE_H,
    HEALTH_NONFINITE_Y,
    HEALTH_OK,
    MOMENT_LEAVES,
    easi_gradient_bank_pallas,
    easi_gradient_pallas,
    smbgd_probe_bank_pallas,
    smbgd_step_bank_pallas,
)

_HEALTH_BITS = (
    (HEALTH_NONFINITE_B, "nonfinite-B"),
    (HEALTH_NONFINITE_H, "nonfinite-H"),
    (HEALTH_NONFINITE_Y, "nonfinite-Y"),
    (HEALTH_BLOWUP, "blowup"),
)


def describe_health(word: int) -> str:
    """Human-readable rendering of a per-stream health word (for eviction
    provenance and logs): ``"ok"`` or a ``+``-joined flag list."""
    flags = [name for bit, name in _HEALTH_BITS if int(word) & bit]
    return "+".join(flags) if flags else "ok"


# The ENTIRE extra HBM traffic of ``health_checks=True``: one int32 health
# word written per stream per tick.  Every other ingredient of the word (the
# isfinite folds, the blow-up bound on the conv statistic) reads values the
# kernel already holds in registers — benchmarks/stream_throughput.py --health
# gates this against the ≤5% acceptance bar using the layout's analytic tick
# bytes.
HEALTH_TICK_BYTES_PER_STREAM = 4

# The ENTIRE extra HBM traffic of ``moments=True``: one (2,) f32 row of raw
# [Σy², Σy⁴] sums written per stream per tick.  Both sums fold from the Y
# registers the gradient pass already holds (see ``_fold_moment_tile``), so —
# exactly like the health word — the telemetry's HBM cost is its output leaf
# and nothing else.  benchmarks/stream_throughput.py --adapt gates this
# against the same ≤5% bar.
MOMENT_TICK_BYTES_PER_STREAM = MOMENT_LEAVES * 4

_LANE = 128  # TPU lane width (last-dim alignment)
_SUBLANE = 8  # f32 sublane

# Persistent-state storage dtypes selectable via ``dtype_policy``.  Storage is
# what B/Ĥ occupy in HBM between ticks; the kernels ALWAYS accumulate the
# gradient fold and the commit in f32 (casts only at load/commit boundaries),
# so "bf16" halves the persistent HBM footprint per session without touching
# the accumulation precision.
STORAGE_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}

# VMEM budget for the default block_s derivation: resident bytes per stream x
# block_s must fit.  Compiled kernels get half of a 16 MiB TPU VMEM (the
# other half is headroom for Mosaic's own pipeline buffers); the interpreter
# has no VMEM but the same accounting bounds its per-cell host temporaries.
_VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET_BYTES"
_DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024
_DEFAULT_INTERPRET_BUDGET = 64 * 1024 * 1024


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_geometry(P: int, n: int, block_p: int | None, interpret: bool):
    n_pad = _round_up(max(n, _SUBLANE), _LANE if not interpret else _SUBLANE)
    if block_p is None:
        block_p = min(512, _round_up(P, _SUBLANE))
    P_pad = _round_up(P, block_p)
    return P_pad, n_pad, block_p


@dataclasses.dataclass(frozen=True)
class BankLayout:
    """Persistent padded layout of a separator bank's state and batches.

    Established once (at ``SeparatorBank.init``); every per-tick tensor is
    carried at these padded shapes so the steady-state serving path never
    re-pads.  Pad/unpad happen only at the API boundary (admission, eviction,
    diagnostics).  ``interpret`` relaxes lane alignment to the f32 sublane so
    CPU interpret-mode tests exercise realistic (non-trivial) padding.

    ``dtype_policy`` names the persistent storage dtype of ``B``/``Ĥ``
    (``"f32"`` or ``"bf16"``; see ``STORAGE_DTYPES``) — the layout owns the
    bank's HBM byte accounting, so capacity math (sessions per device,
    bytes per tick) reads straight off it.
    """

    n: int  # logical components
    m: int  # logical features
    P: int  # logical mini-batch
    n_pad: int
    m_pad: int
    P_pad: int
    block_p: int
    dtype_policy: str = "f32"

    @property
    def storage_dtype(self):
        """Persistent B/Ĥ storage dtype (kernels still accumulate in f32)."""
        return STORAGE_DTYPES[self.dtype_policy]

    @property
    def persistent_bytes_per_session(self) -> int:
        """HBM bytes one session's persistent state occupies between ticks:
        padded ``B`` + ``Ĥ`` at the storage dtype, plus the int32 ``step``
        and f32 ``conv`` scalars.  THE capacity number — sessions per device
        = HBM budget / this."""
        itemsize = jnp.dtype(self.storage_dtype).itemsize
        return (self.n_pad * self.m_pad + self.n_pad * self.n_pad) * itemsize + 4 + 4

    @property
    def tick_hbm_bytes_per_stream(self) -> int:
        """Estimated HBM traffic one stream contributes to one megakernel
        tick: read X + W, read AND write B/Ĥ (storage dtype), write Y, plus
        the scalar side channels.  An analytic floor — actual traffic adds
        re-reads only if the compiler spills."""
        itemsize = jnp.dtype(self.storage_dtype).itemsize
        x_bytes = self.P_pad * self.m_pad * 4
        w_bytes = self.P_pad * 4
        y_bytes = self.P_pad * self.n_pad * 4
        state_bytes = 2 * (self.n_pad * self.m_pad + self.n_pad * self.n_pad) * itemsize
        return x_bytes + w_bytes + y_bytes + state_bytes + 4 * 2 + 4 * 2

    def vmem_resident_bytes_per_stream(self, prefetch: bool = False) -> int:
        """Conservative per-stream VMEM residency of one megakernel grid
        cell — what the default ``block_s`` derivation budgets against."""
        return _resident_bytes_per_stream(
            self.block_p, self.n_pad, self.m_pad,
            x_itemsize=4,
            state_itemsize=jnp.dtype(self.storage_dtype).itemsize,
            prefetch=prefetch,
        )


def bank_layout(
    n: int,
    m: int,
    P: int,
    *,
    block_p: int | None = None,
    interpret: bool | None = None,
    dtype_policy: str = "f32",
) -> BankLayout:
    """Compute the lane/sublane-aligned persistent layout for ``(n, m, P)``.

    One geometry rule for the whole stack: ``n`` (last dim of Y/Ĥ) and ``m``
    (last dim of X/B) are lane-aligned; ``P`` rounds up to a whole number of
    ``block_p`` tiles.  ``dtype_policy`` selects the persistent storage dtype
    (see ``BankLayout``).
    """
    if interpret is None:
        interpret = _interpret_default()
    if dtype_policy not in STORAGE_DTYPES:
        raise ValueError(
            f"dtype_policy must be one of {sorted(STORAGE_DTYPES)}, "
            f"got {dtype_policy!r}"
        )
    P_pad, n_pad, block_p = _pad_geometry(P, n, block_p, interpret)
    m_pad = _round_up(max(m, _SUBLANE), _LANE if not interpret else _SUBLANE)
    return BankLayout(
        n=n, m=m, P=P, n_pad=n_pad, m_pad=m_pad, P_pad=P_pad, block_p=block_p,
        dtype_policy=dtype_policy,
    )


@functools.partial(jax.jit, static_argnames=("nonlinearity", "block_p", "interpret"))
def easi_gradient(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Weighted EASI relative-gradient sum ``S (n, n)`` for ``Y (P, n)``, ``w (P,)``.

    Pads n to the 128-lane boundary and P to a sublane-aligned block; zero
    padding is exact (zero rows/cols contribute nothing; the identity term is
    computed from the real Σw and sliced back).  All nonlinearities in the bank
    satisfy g(0)=0, which the padding relies on (asserted in tests).
    """
    if interpret is None:
        interpret = _interpret_default()
    P, n = Y.shape
    P_pad, n_pad, block_p = _pad_geometry(P, n, block_p, interpret)
    if (P_pad, n_pad) == (P, n):  # block-aligned: no staging copy
        Yp = Y
        wp = w.reshape(P, 1).astype(jnp.float32)
    else:
        Yp = jnp.zeros((P_pad, n_pad), Y.dtype).at[:P, :n].set(Y)
        wp = jnp.zeros((P_pad, 1), jnp.float32).at[:P, 0].set(w.reshape(-1))
    S = easi_gradient_pallas(
        Yp, wp, nonlinearity=nonlinearity, block_p=block_p, interpret=interpret
    )
    # Padded diagonal entries carry sum(w)·I — slicing removes them.
    return S[:n, :n]


@functools.partial(jax.jit, static_argnames=("nonlinearity", "block_p", "interpret"))
def easi_gradient_bank(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Bank form: ``Y (S, P, n)`` with shared weights ``w (P,)`` →
    ``S_out (S, n, n)`` in one fused (streams, tiles) launch.

    Same padding contract as ``easi_gradient`` — padding rows/cols are zero and
    contribute nothing (g(0)=0 for the whole bank), so each stream's slice is
    bit-identical to a single-stream launch with the same block geometry.
    """
    if interpret is None:
        interpret = _interpret_default()
    S_streams, P, n = Y.shape
    P_pad, n_pad, block_p = _pad_geometry(P, n, block_p, interpret)
    if (P_pad, n_pad) == (P, n):  # block-aligned: no per-step staging copy
        Yp = Y
        wp = w.reshape(P, 1).astype(jnp.float32)
    else:
        Yp = jnp.zeros((S_streams, P_pad, n_pad), Y.dtype).at[:, :P, :n].set(Y)
        wp = jnp.zeros((P_pad, 1), jnp.float32).at[:P, 0].set(w.reshape(-1))
    S = easi_gradient_bank_pallas(
        Yp, wp, nonlinearity=nonlinearity, block_p=block_p, interpret=interpret
    )
    return S[:, :n, :n]


def _resident_bytes_per_stream(
    block_p: int,
    n_pad: int,
    m_pad: int,
    *,
    x_itemsize: int = 4,
    state_itemsize: int = 4,
    prefetch: bool = False,
) -> int:
    """Conservative VMEM bytes ONE stream keeps resident in a megakernel grid
    cell: the X tile (doubled when prefetch double-buffers it), the W rows,
    B/Ĥ in+out blocks at the storage dtype, the f32 gradient accumulator, the
    Y output tile, and the f32 tile-fold temporaries (y, g, y·w)."""
    x_bytes = block_p * m_pad * x_itemsize * (2 if prefetch else 1)
    w_bytes = block_p * 4
    state_bytes = 2 * (n_pad * m_pad + n_pad * n_pad) * state_itemsize
    acc_bytes = n_pad * n_pad * 4
    y_bytes = block_p * n_pad * x_itemsize
    tmp_bytes = 3 * block_p * n_pad * 4
    return x_bytes + w_bytes + state_bytes + acc_bytes + y_bytes + tmp_bytes


def vmem_budget_bytes(interpret: bool) -> int:
    """The per-cell VMEM budget the default ``block_s`` derivation targets.
    Override with ``REPRO_VMEM_BUDGET_BYTES`` (note: resolved at trace time —
    a jitted caller caches the resolution with the program)."""
    env = os.environ.get(_VMEM_BUDGET_ENV)
    if env:
        return int(env)
    return _DEFAULT_INTERPRET_BUDGET if interpret else _DEFAULT_VMEM_BUDGET


def _default_block_s(
    S: int, *, resident_bytes: int, interpret: bool
) -> int:
    """Largest divisor of S whose stream-block fits the VMEM budget —
    ``resident_bytes × block_s ≤ vmem_budget_bytes()``.  Streams batched per
    grid cell amortize per-cell launch overhead (and, in interpret mode, the
    per-cell grid-loop cost); per-stream math is independent so any divisor
    is numerically equivalent (tested).  Deriving the cap from the layout's
    actual residency (instead of a hardcoded 8/32) means large ``(m, n)``
    shapes shrink ``block_s`` instead of silently blowing VMEM — and a shape
    whose SINGLE stream exceeds the budget fails loudly on compiled backends
    (the interpreter clamps to 1: no VMEM to blow, only host memory)."""
    budget = vmem_budget_bytes(interpret)
    cap = budget // max(resident_bytes, 1)
    if cap < 1:
        if not interpret:
            raise ValueError(
                f"one stream's megakernel residency ({resident_bytes} bytes) "
                f"exceeds the VMEM budget ({budget} bytes) — shrink block_p "
                f"or raise {_VMEM_BUDGET_ENV}"
            )
        cap = 1
    for bs in range(min(S, cap), 0, -1):
        if S % bs == 0:
            return bs
    return 1


def default_block_s(
    S: int,
    layout: BankLayout,
    *,
    prefetch: bool = False,
    interpret: bool | None = None,
) -> int:
    """Public form of the default ``block_s`` derivation for a layout —
    what ``smbgd_step_bank`` resolves when ``block_s=None`` (benchmarks and
    tests use this to predict/verify the resolution)."""
    if interpret is None:
        interpret = _interpret_default()
    return _default_block_s(
        S,
        resident_bytes=layout.vmem_resident_bytes_per_stream(prefetch),
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "nonlinearity", "block_p", "block_s", "interpret", "prefetch",
        "health", "moments", "blowup",
    ),
)
def smbgd_step_bank(
    X: jnp.ndarray,
    W: jnp.ndarray,
    B: jnp.ndarray,
    H_hat: jnp.ndarray,
    step: jnp.ndarray,
    gamma_hat: jnp.ndarray,
    active: jnp.ndarray,
    conv: jnp.ndarray | None = None,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    block_s: int | None = None,
    interpret: bool | None = None,
    prefetch: bool = False,
    health: bool = True,
    moments: bool = False,
    blowup: float = HEALTH_BLOWUP_BOUND,
):
    """Whole-step fused bank tick on persistent-padded state (zero staging).

    All tensor inputs must already be in the ``bank_layout`` geometry — this
    is the steady-state serving hot path and it refuses to silently pad:

      * ``X (S, P_pad, m_pad)``, ``W (S, P_pad, 1)`` f32 weight rows
        (per-stream w_p = μ_s β_s^{P-1-p}, zero in padded rows),
      * ``B (S, n_pad, m_pad)``, ``H_hat (S, n_pad, n_pad)`` — in the
        layout's storage dtype (f32 or bf16; the kernel accumulates in f32
        either way and writes back in the storage dtype),
      * ``step (S,)`` or ``(S, 1)`` int32, ``gamma_hat (S,)`` or ``(S, 1)``
        f32 (γ̂_s = γ_s β_s^{P-1}), ``active (S,)`` or ``(S, 1)`` bool/int,
      * ``conv (S,)`` or ``(S, 1)`` f32 — previous per-stream convergence
        statistic, carried through for frozen streams (defaults to +inf,
        "never measured").

    ``block_s`` batches that many streams per grid cell (default: the
    largest divisor of S whose per-cell residency fits the VMEM budget —
    see ``default_block_s``).  ``prefetch=True`` double-buffers the X tile
    DMA (bit-identical on the interpret path).  Returns
    ``(Y (S, P_pad, n_pad), B', H_hat', step' (S,), conv' (S,),
    health' (S,), moments' (S, 2))`` where ``conv'`` is the relative update
    magnitude ``‖Ĥ′B‖_F/‖B‖_F`` computed inside the commit (see
    ``core.metrics.update_magnitude`` for the reference formula),
    ``health'`` is the int32 per-stream fault bitmask (``HEALTH_*``;
    non-finite B'/Ĥ'/Y or ``conv' > blowup``) and ``moments'`` the raw
    per-stream [Σy², Σy⁴] fold over this tick's Y (zeros when
    ``moments=False`` — purely observational, every other output is
    bit-identical either way).  ``health=True`` (default) also refuses
    unhealthy commits in-kernel — the slot keeps its pre-tick state like a
    frozen stream; ``health=False`` restores the pre-containment
    commit-on-active behaviour and returns zeros (the overhead baseline for
    ``benchmarks --health``).
    """
    if interpret is None:
        interpret = _interpret_default()
    S_streams, P_pad, m_pad = X.shape
    n_pad = B.shape[1]
    if block_p is None:
        block_p = min(512, _round_up(P_pad, _SUBLANE))
    if block_s is None:
        block_s = _default_block_s(
            S_streams,
            resident_bytes=_resident_bytes_per_stream(
                block_p, n_pad, m_pad,
                x_itemsize=X.dtype.itemsize,
                state_itemsize=B.dtype.itemsize,
                prefetch=prefetch,
            ),
            interpret=interpret,
        )
    if P_pad % block_p or n_pad % _SUBLANE or m_pad % _SUBLANE:
        raise ValueError(
            f"smbgd_step_bank requires persistent-layout inputs; got "
            f"P={P_pad} (block_p={block_p}), n={n_pad}, m={m_pad}"
        )
    if S_streams % block_s:
        raise ValueError(
            f"block_s={block_s} must divide the stream count {S_streams}"
        )
    Wp = W.reshape(S_streams, P_pad, 1).astype(jnp.float32)
    step2 = step.reshape(S_streams, 1).astype(jnp.int32)
    gamma2 = gamma_hat.reshape(S_streams, 1).astype(jnp.float32)
    active2 = active.reshape(S_streams, 1).astype(jnp.int32)
    if conv is None:
        conv = jnp.full((S_streams, 1), jnp.inf, jnp.float32)
    conv2 = conv.reshape(S_streams, 1).astype(jnp.float32)
    Y, B_new, H_new, step_new, conv_new, health_new, mom_new = (
        smbgd_step_bank_pallas(
            X,
            Wp,
            B,
            H_hat,
            step2,
            gamma2,
            active2,
            conv2,
            nonlinearity=nonlinearity,
            block_p=block_p,
            block_s=block_s,
            interpret=interpret,
            prefetch=prefetch,
            health=health,
            moments=moments,
            blowup=blowup,
        )
    )
    return (
        Y,
        B_new,
        H_new,
        step_new.reshape(S_streams),
        conv_new.reshape(S_streams),
        health_new.reshape(S_streams),
        mom_new,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "nonlinearity", "block_p", "block_s", "interpret", "prefetch",
        "health", "moments", "blowup",
    ),
)
def smbgd_probe_bank(
    X: jnp.ndarray,
    W: jnp.ndarray,
    B: jnp.ndarray,
    H_hat: jnp.ndarray,
    step: jnp.ndarray,
    gamma_hat: jnp.ndarray,
    active: jnp.ndarray,
    conv: jnp.ndarray | None = None,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    block_s: int | None = None,
    interpret: bool | None = None,
    prefetch: bool = False,
    health: bool = True,
    moments: bool = False,
    blowup: float = HEALTH_BLOWUP_BOUND,
):
    """Freeze-only probe launch: the conv statistic a ``smbgd_step_bank``
    tick WOULD commit, without committing anything.

    Same persistent-layout contract and block geometry as ``smbgd_step_bank``
    (it refuses to silently pad); returns ``(conv' (S,), health' (S,),
    moments' (S, 2))`` — the virtual per-stream relative update magnitude,
    with ``conv`` (default +inf) carried through for streams masked out by
    ``active``, the int32 health word that commit would have raised
    (all-zero when ``health=False``; quarantined sessions are probed for
    sanity through it), and the raw [Σy², Σy⁴] fold over the probe's Y
    (zeros when ``moments=False``).  The state operands are never written:
    this is the batched out-of-band drift probe of parked (frozen)
    separators, one launch per ``S``-wide probe batch.
    """
    if interpret is None:
        interpret = _interpret_default()
    S_streams, P_pad, m_pad = X.shape
    n_pad = B.shape[1]
    if block_p is None:
        block_p = min(512, _round_up(P_pad, _SUBLANE))
    if block_s is None:
        block_s = _default_block_s(
            S_streams,
            resident_bytes=_resident_bytes_per_stream(
                block_p, n_pad, m_pad,
                x_itemsize=X.dtype.itemsize,
                state_itemsize=B.dtype.itemsize,
                prefetch=prefetch,
            ),
            interpret=interpret,
        )
    if P_pad % block_p or n_pad % _SUBLANE or m_pad % _SUBLANE:
        raise ValueError(
            f"smbgd_probe_bank requires persistent-layout inputs; got "
            f"P={P_pad} (block_p={block_p}), n={n_pad}, m={m_pad}"
        )
    if S_streams % block_s:
        raise ValueError(
            f"block_s={block_s} must divide the stream count {S_streams}"
        )
    Wp = W.reshape(S_streams, P_pad, 1).astype(jnp.float32)
    step2 = step.reshape(S_streams, 1).astype(jnp.int32)
    gamma2 = gamma_hat.reshape(S_streams, 1).astype(jnp.float32)
    active2 = active.reshape(S_streams, 1).astype(jnp.int32)
    if conv is None:
        conv = jnp.full((S_streams, 1), jnp.inf, jnp.float32)
    conv2 = conv.reshape(S_streams, 1).astype(jnp.float32)
    conv_new, health_new, mom_new = smbgd_probe_bank_pallas(
        X,
        Wp,
        B,
        H_hat,
        step2,
        gamma2,
        active2,
        conv2,
        nonlinearity=nonlinearity,
        block_p=block_p,
        block_s=block_s,
        interpret=interpret,
        prefetch=prefetch,
        health=health,
        moments=moments,
        blowup=blowup,
    )
    return conv_new.reshape(S_streams), health_new.reshape(S_streams), mom_new
