"""Jit'd public wrapper for the EASI-gradient kernel: padding, alignment,
dtype policy and the interpret-mode switch (CPU container → interpret=True;
on real TPU set REPRO_PALLAS_INTERPRET=0)."""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.easi_gradient.easi_gradient import easi_gradient_pallas

_LANE = 128  # TPU lane width (last-dim alignment)
_SUBLANE = 8  # f32 sublane


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("nonlinearity", "block_p", "interpret"))
def easi_gradient(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Weighted EASI relative-gradient sum ``S (n, n)`` for ``Y (P, n)``, ``w (P,)``.

    Pads n to the 128-lane boundary and P to a sublane-aligned block; zero
    padding is exact (zero rows/cols contribute nothing; the identity term is
    computed from the real Σw and sliced back).  All nonlinearities in the bank
    satisfy g(0)=0, which the padding relies on (asserted in tests).
    """
    if interpret is None:
        interpret = _interpret_default()
    P, n = Y.shape
    n_pad = _round_up(max(n, _SUBLANE), _LANE if not interpret else _SUBLANE)
    if block_p is None:
        block_p = min(512, _round_up(P, _SUBLANE))
    P_pad = _round_up(P, block_p)
    Yp = jnp.zeros((P_pad, n_pad), Y.dtype).at[:P, :n].set(Y)
    wp = jnp.zeros((P_pad, 1), jnp.float32).at[:P, 0].set(w.reshape(-1))
    S = easi_gradient_pallas(
        Yp, wp, nonlinearity=nonlinearity, block_p=block_p, interpret=interpret
    )
    # Padded diagonal entries carry sum(w)·I — slicing removes them.
    return S[:n, :n]
