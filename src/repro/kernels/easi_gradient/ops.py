"""Jit'd public wrappers for the EASI-gradient kernels: padding, alignment,
dtype policy and the interpret-mode switch.

``REPRO_PALLAS_INTERPRET`` controls lowering: the default (``1``) runs the
kernels through the Pallas interpreter so the CPU container can execute and
test them; on real TPU set ``REPRO_PALLAS_INTERPRET=0`` to compile to Mosaic.
Both entry points honour it:

  * ``easi_gradient``       — single stream,   ``Y (P, n)``    → ``S (n, n)``
  * ``easi_gradient_bank``  — S streams fused, ``Y (S, P, n)`` → ``S (S, n, n)``
  * ``smbgd_step_bank``     — whole-step megakernel: one launch computes
    ``Y = X Bᵀ``, the weighted gradient sum, the SMBGD commit AND the
    per-stream convergence statistic (relative update magnitude) for all S
    streams, on persistent-padded state (``BankLayout``).
  * ``smbgd_probe_bank``    — freeze-only fast path of the megakernel: the
    same launch geometry computes ONLY the per-stream convergence statistic
    a commit WOULD produce — no ``Y``/``B'``/``Ĥ'`` writes.  The batched
    out-of-band drift probe of parked (frozen) separators.

Block-aligned inputs take the zero-copy fast path: when an array already
matches its padded geometry the ``zeros().at[].set()`` staging copy is skipped
entirely — persistent-layout callers (``stream.SeparatorBank`` in fused mode)
pay no per-step padding.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.easi_gradient.easi_gradient import (
    easi_gradient_bank_pallas,
    easi_gradient_pallas,
    smbgd_probe_bank_pallas,
    smbgd_step_bank_pallas,
)

_LANE = 128  # TPU lane width (last-dim alignment)
_SUBLANE = 8  # f32 sublane


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_geometry(P: int, n: int, block_p: int | None, interpret: bool):
    n_pad = _round_up(max(n, _SUBLANE), _LANE if not interpret else _SUBLANE)
    if block_p is None:
        block_p = min(512, _round_up(P, _SUBLANE))
    P_pad = _round_up(P, block_p)
    return P_pad, n_pad, block_p


@dataclasses.dataclass(frozen=True)
class BankLayout:
    """Persistent padded layout of a separator bank's state and batches.

    Established once (at ``SeparatorBank.init``); every per-tick tensor is
    carried at these padded shapes so the steady-state serving path never
    re-pads.  Pad/unpad happen only at the API boundary (admission, eviction,
    diagnostics).  ``interpret`` relaxes lane alignment to the f32 sublane so
    CPU interpret-mode tests exercise realistic (non-trivial) padding.
    """

    n: int  # logical components
    m: int  # logical features
    P: int  # logical mini-batch
    n_pad: int
    m_pad: int
    P_pad: int
    block_p: int


def bank_layout(
    n: int,
    m: int,
    P: int,
    *,
    block_p: int | None = None,
    interpret: bool | None = None,
) -> BankLayout:
    """Compute the lane/sublane-aligned persistent layout for ``(n, m, P)``.

    One geometry rule for the whole stack: ``n`` (last dim of Y/Ĥ) and ``m``
    (last dim of X/B) are lane-aligned; ``P`` rounds up to a whole number of
    ``block_p`` tiles.
    """
    if interpret is None:
        interpret = _interpret_default()
    P_pad, n_pad, block_p = _pad_geometry(P, n, block_p, interpret)
    m_pad = _round_up(max(m, _SUBLANE), _LANE if not interpret else _SUBLANE)
    return BankLayout(
        n=n, m=m, P=P, n_pad=n_pad, m_pad=m_pad, P_pad=P_pad, block_p=block_p
    )


@functools.partial(jax.jit, static_argnames=("nonlinearity", "block_p", "interpret"))
def easi_gradient(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Weighted EASI relative-gradient sum ``S (n, n)`` for ``Y (P, n)``, ``w (P,)``.

    Pads n to the 128-lane boundary and P to a sublane-aligned block; zero
    padding is exact (zero rows/cols contribute nothing; the identity term is
    computed from the real Σw and sliced back).  All nonlinearities in the bank
    satisfy g(0)=0, which the padding relies on (asserted in tests).
    """
    if interpret is None:
        interpret = _interpret_default()
    P, n = Y.shape
    P_pad, n_pad, block_p = _pad_geometry(P, n, block_p, interpret)
    if (P_pad, n_pad) == (P, n):  # block-aligned: no staging copy
        Yp = Y
        wp = w.reshape(P, 1).astype(jnp.float32)
    else:
        Yp = jnp.zeros((P_pad, n_pad), Y.dtype).at[:P, :n].set(Y)
        wp = jnp.zeros((P_pad, 1), jnp.float32).at[:P, 0].set(w.reshape(-1))
    S = easi_gradient_pallas(
        Yp, wp, nonlinearity=nonlinearity, block_p=block_p, interpret=interpret
    )
    # Padded diagonal entries carry sum(w)·I — slicing removes them.
    return S[:n, :n]


@functools.partial(jax.jit, static_argnames=("nonlinearity", "block_p", "interpret"))
def easi_gradient_bank(
    Y: jnp.ndarray,
    w: jnp.ndarray,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Bank form: ``Y (S, P, n)`` with shared weights ``w (P,)`` →
    ``S_out (S, n, n)`` in one fused (streams, tiles) launch.

    Same padding contract as ``easi_gradient`` — padding rows/cols are zero and
    contribute nothing (g(0)=0 for the whole bank), so each stream's slice is
    bit-identical to a single-stream launch with the same block geometry.
    """
    if interpret is None:
        interpret = _interpret_default()
    S_streams, P, n = Y.shape
    P_pad, n_pad, block_p = _pad_geometry(P, n, block_p, interpret)
    if (P_pad, n_pad) == (P, n):  # block-aligned: no per-step staging copy
        Yp = Y
        wp = w.reshape(P, 1).astype(jnp.float32)
    else:
        Yp = jnp.zeros((S_streams, P_pad, n_pad), Y.dtype).at[:, :P, :n].set(Y)
        wp = jnp.zeros((P_pad, 1), jnp.float32).at[:P, 0].set(w.reshape(-1))
    S = easi_gradient_bank_pallas(
        Yp, wp, nonlinearity=nonlinearity, block_p=block_p, interpret=interpret
    )
    return S[:, :n, :n]


def _default_block_s(S: int, cap: int) -> int:
    """Largest divisor of S ≤ cap — streams batched per grid cell.  Per-cell
    launch overhead (and, in interpret mode, the per-cell grid-loop cost)
    amortizes over the stream block; per-stream math is independent so any
    divisor is numerically equivalent (tested).  The cap is backend-aware at
    the call site: compiled kernels budget VMEM (block_s scales every resident
    block), the interpreter only pays grid-loop iterations."""
    for bs in range(min(S, cap), 0, -1):
        if S % bs == 0:
            return bs
    return 1


@functools.partial(
    jax.jit, static_argnames=("nonlinearity", "block_p", "block_s", "interpret")
)
def smbgd_step_bank(
    X: jnp.ndarray,
    W: jnp.ndarray,
    B: jnp.ndarray,
    H_hat: jnp.ndarray,
    step: jnp.ndarray,
    gamma_hat: jnp.ndarray,
    active: jnp.ndarray,
    conv: jnp.ndarray | None = None,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    block_s: int | None = None,
    interpret: bool | None = None,
):
    """Whole-step fused bank tick on persistent-padded state (zero staging).

    All tensor inputs must already be in the ``bank_layout`` geometry — this
    is the steady-state serving hot path and it refuses to silently pad:

      * ``X (S, P_pad, m_pad)``, ``W (S, P_pad, 1)`` f32 weight rows
        (per-stream w_p = μ_s β_s^{P-1-p}, zero in padded rows),
      * ``B (S, n_pad, m_pad)``, ``H_hat (S, n_pad, n_pad)``,
      * ``step (S,)`` or ``(S, 1)`` int32, ``gamma_hat (S,)`` or ``(S, 1)``
        f32 (γ̂_s = γ_s β_s^{P-1}), ``active (S,)`` or ``(S, 1)`` bool/int,
      * ``conv (S,)`` or ``(S, 1)`` f32 — previous per-stream convergence
        statistic, carried through for frozen streams (defaults to +inf,
        "never measured").

    ``block_s`` batches that many streams per grid cell (default: largest
    divisor of S ≤ 8 compiled / ≤ 32 interpreted).  Returns
    ``(Y (S, P_pad, n_pad), B', H_hat', step' (S,), conv' (S,))`` where
    ``conv'`` is the relative update magnitude ``‖Ĥ′B‖_F/‖B‖_F`` computed
    inside the commit (see ``core.metrics.update_magnitude`` for the
    reference formula).
    """
    if interpret is None:
        interpret = _interpret_default()
    S_streams, P_pad, m_pad = X.shape
    n_pad = B.shape[1]
    if block_p is None:
        block_p = min(512, _round_up(P_pad, _SUBLANE))
    if block_s is None:
        block_s = _default_block_s(S_streams, cap=32 if interpret else 8)
    if P_pad % block_p or n_pad % _SUBLANE or m_pad % _SUBLANE:
        raise ValueError(
            f"smbgd_step_bank requires persistent-layout inputs; got "
            f"P={P_pad} (block_p={block_p}), n={n_pad}, m={m_pad}"
        )
    if S_streams % block_s:
        raise ValueError(
            f"block_s={block_s} must divide the stream count {S_streams}"
        )
    Wp = W.reshape(S_streams, P_pad, 1).astype(jnp.float32)
    step2 = step.reshape(S_streams, 1).astype(jnp.int32)
    gamma2 = gamma_hat.reshape(S_streams, 1).astype(jnp.float32)
    active2 = active.reshape(S_streams, 1).astype(jnp.int32)
    if conv is None:
        conv = jnp.full((S_streams, 1), jnp.inf, jnp.float32)
    conv2 = conv.reshape(S_streams, 1).astype(jnp.float32)
    Y, B_new, H_new, step_new, conv_new = smbgd_step_bank_pallas(
        X,
        Wp,
        B,
        H_hat,
        step2,
        gamma2,
        active2,
        conv2,
        nonlinearity=nonlinearity,
        block_p=block_p,
        block_s=block_s,
        interpret=interpret,
    )
    return Y, B_new, H_new, step_new.reshape(S_streams), conv_new.reshape(S_streams)


@functools.partial(
    jax.jit, static_argnames=("nonlinearity", "block_p", "block_s", "interpret")
)
def smbgd_probe_bank(
    X: jnp.ndarray,
    W: jnp.ndarray,
    B: jnp.ndarray,
    H_hat: jnp.ndarray,
    step: jnp.ndarray,
    gamma_hat: jnp.ndarray,
    active: jnp.ndarray,
    conv: jnp.ndarray | None = None,
    *,
    nonlinearity: str = "cubic",
    block_p: int | None = None,
    block_s: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Freeze-only probe launch: the conv statistic a ``smbgd_step_bank``
    tick WOULD commit, without committing anything.

    Same persistent-layout contract and block geometry as ``smbgd_step_bank``
    (it refuses to silently pad); returns only ``conv' (S,)`` — the virtual
    per-stream relative update magnitude, with ``conv`` (default +inf)
    carried through for streams masked out by ``active``.  The state
    operands are never written: this is the batched out-of-band drift probe
    of parked (frozen) separators, one launch per ``S``-wide probe batch.
    """
    if interpret is None:
        interpret = _interpret_default()
    S_streams, P_pad, m_pad = X.shape
    n_pad = B.shape[1]
    if block_p is None:
        block_p = min(512, _round_up(P_pad, _SUBLANE))
    if block_s is None:
        block_s = _default_block_s(S_streams, cap=32 if interpret else 8)
    if P_pad % block_p or n_pad % _SUBLANE or m_pad % _SUBLANE:
        raise ValueError(
            f"smbgd_probe_bank requires persistent-layout inputs; got "
            f"P={P_pad} (block_p={block_p}), n={n_pad}, m={m_pad}"
        )
    if S_streams % block_s:
        raise ValueError(
            f"block_s={block_s} must divide the stream count {S_streams}"
        )
    Wp = W.reshape(S_streams, P_pad, 1).astype(jnp.float32)
    step2 = step.reshape(S_streams, 1).astype(jnp.int32)
    gamma2 = gamma_hat.reshape(S_streams, 1).astype(jnp.float32)
    active2 = active.reshape(S_streams, 1).astype(jnp.int32)
    if conv is None:
        conv = jnp.full((S_streams, 1), jnp.inf, jnp.float32)
    conv2 = conv.reshape(S_streams, 1).astype(jnp.float32)
    conv_new = smbgd_probe_bank_pallas(
        X,
        Wp,
        B,
        H_hat,
        step2,
        gamma2,
        active2,
        conv2,
        nonlinearity=nonlinearity,
        block_p=block_p,
        block_s=block_s,
        interpret=interpret,
    )
    return conv_new.reshape(S_streams)
