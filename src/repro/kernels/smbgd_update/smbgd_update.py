"""Pallas kernel: fused SMBGD commit —  Ĥ ← γ̂·Ĥ + S ;  B ← B + Ĥ·B.

The commit touches three B-sized tensors and two Ĥ-sized tensors; unfused it
costs three HBM round-trips of ``B``.  Fused, ``B`` streams through VMEM once:
each grid step loads one ``(n, block_m)`` column tile of B, applies the fresh
``Ĥ`` held in VMEM, and writes the tile back.  ``Ĥ`` is emitted once (step 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _smbgd_update_kernel(gamma_ref, h_ref, s_ref, b_ref, h_out_ref, b_out_ref):
    i = pl.program_id(0)
    gamma = gamma_ref[0, 0]
    h_new = gamma * h_ref[...] + s_ref[...]  # (n, n) — recomputed per tile, tiny

    @pl.when(i == 0)
    def _write_h():
        h_out_ref[...] = h_new

    b = b_ref[...]
    b_out_ref[...] = b + jax.lax.dot(
        h_new, b, preferred_element_type=jnp.float32
    ).astype(b.dtype)


def smbgd_update_pallas(
    gamma_hat: jnp.ndarray,
    H_prev: jnp.ndarray,
    S: jnp.ndarray,
    B: jnp.ndarray,
    *,
    block_m: int = 512,
    interpret: bool = True,
):
    """Fused commit.  ``gamma_hat (1,1) f32``, ``H_prev/S (n,n)``, ``B (n,m)``
    with m % block_m == 0 (ops.py pads).  Returns ``(H_new, B_new)``."""
    n, m = B.shape
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)
    return pl.pallas_call(
        _smbgd_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, n), H_prev.dtype),
            jax.ShapeDtypeStruct((n, m), B.dtype),
        ],
        interpret=interpret,
    )(gamma_hat, H_prev, S, B)
