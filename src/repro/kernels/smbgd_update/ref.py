"""Pure-jnp oracle for the fused SMBGD commit."""
from __future__ import annotations

import jax.numpy as jnp


def smbgd_update_ref(gamma_hat, H_prev, S, B):
    """Ĥ = γ̂ Ĥ_prev + S ;  B' = B + Ĥ B.  Returns (Ĥ, B')."""
    H_new = gamma_hat * H_prev + S
    return H_new, B + H_new @ B
