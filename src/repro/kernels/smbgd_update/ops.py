"""Jit'd wrapper for the fused SMBGD commit kernel (padding + interpret switch)."""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.smbgd_update.smbgd_update import smbgd_update_pallas

_LANE = 128
_SUBLANE = 8


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("interpret",))
def smbgd_update(
    gamma_hat: jnp.ndarray,
    H_prev: jnp.ndarray,
    S: jnp.ndarray,
    B: jnp.ndarray,
    *,
    interpret: bool | None = None,
):
    """Fused Ĥ/B commit for arbitrary (n, m); pads to sublane/lane alignment.

    Zero-padding is exact: padded rows/cols of Ĥ stay zero (γ̂·0 + 0) and the
    padded block of B is zero so Ĥ·B contributes nothing outside [:n, :m].
    """
    if interpret is None:
        interpret = _interpret_default()
    n, m = B.shape
    align = _SUBLANE if interpret else _LANE
    n_pad = _round_up(max(n, _SUBLANE), align)
    block_m = min(512, _round_up(max(m, _SUBLANE), align))
    m_pad = _round_up(m, block_m)
    Hp = jnp.zeros((n_pad, n_pad), H_prev.dtype).at[:n, :n].set(H_prev)
    Sp = jnp.zeros((n_pad, n_pad), S.dtype).at[:n, :n].set(S)
    Bp = jnp.zeros((n_pad, m_pad), B.dtype).at[:n, :m].set(B)
    g = jnp.asarray(gamma_hat, jnp.float32).reshape(1, 1)
    H_new, B_new = smbgd_update_pallas(
        g, Hp, Sp, Bp, block_m=block_m, interpret=interpret
    )
    return H_new[:n, :n], B_new[:n, :m]
