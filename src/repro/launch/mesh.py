"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — "pod" is a
pure data-parallel axis across the inter-pod (DCN/ICI-wrapped) links.

Defined as a FUNCTION so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before any jax import; tests see 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Dev/test mesh over whatever devices exist (usually 1 CPU)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
