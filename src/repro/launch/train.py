"""Cluster training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --shape train_4k \
        --steps 1000 --optimizer smbgd [--multi-pod] [--local]

On a real TPU slice this binary runs once per host (jax.distributed initializes
from the TPU env); ``--local`` runs the same code path on whatever devices
exist here (1 CPU) with a reduced config — the CI-checkable smoke of the
production path.  The production mesh/shardings are exactly the dry-run's.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--optimizer", default="smbgd", choices=["smbgd", "adamw"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--local", action="store_true", help="reduced config on local devices")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    if not args.local:
        # production: bring up the distributed runtime before touching devices
        import jax

        try:
            jax.distributed.initialize()
        except Exception as e:  # single-process dev boxes
            print(f"[train] jax.distributed.initialize skipped: {e}", file=sys.stderr)

    import jax

    from repro.configs.base import SHAPES_BY_NAME
    from repro.configs.registry import get_config
    from repro.data.pipeline import make_lm_pipeline
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.models.model import init_params
    from repro.optim.optimizers import adamw
    from repro.optim.smbgd import smbgd
    from repro.sharding import rules
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    if args.local:
        cfg = cfg.reduced()
        mesh = make_local_mesh()
        seq_len, global_batch = 128, 8
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        seq_len, global_batch = shape.seq_len, shape.global_batch

    tx = (
        smbgd(args.lr, gamma=0.9, beta=0.98, microbatches=args.microbatches)
        if args.optimizer == "smbgd"
        else adamw(args.lr)
    )
    params_shape = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    shardings = rules.param_shardings(params_shape, cfg, mesh)

    pipe = make_lm_pipeline(cfg, seq_len=seq_len, global_batch=global_batch)
    tcfg = TrainerConfig(
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        microbatches=args.microbatches,
        metrics_path=f"{args.ckpt_dir}/metrics.jsonl",
    )
    with mesh:
        trainer = Trainer(cfg, tx, tcfg, mesh=mesh, param_shardings=shardings)
        _, _, losses = trainer.fit(jax.random.PRNGKey(0), pipe, args.steps)
    if losses:
        print(f"[train] {len(losses)} steps, loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
