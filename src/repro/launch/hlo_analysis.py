"""Roofline-term extraction from compiled XLA artifacts.

Sources:
  * ``compiled.cost_analysis()`` — HLO FLOPs and bytes accessed.  XLA counts
    every computation ONCE, so ``lax.scan``/while bodies are undercounted by
    their trip count (verified empirically: ratio is exactly 1/N).  The dry-run
    therefore compiles the scan *body* separately and reconstructs
        total ≈ cost(full_step) + (N_scan − 1) · cost(one_body)
  * ``compiled.as_text()`` — collective bytes: we sum the result-shape bytes of
    every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute instruction (same once-per-appearance caveat, same
    reconstruction).

Hardware model (TPU v5e-class target, per chip):
    peak bf16 compute 197 TFLOP/s · HBM BW 819 GB/s · ICI ~50 GB/s/link
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


_COLL_RE = re.compile(
    r" = (?P<type>.*?)\s(?P<kind>all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?P<async>-start|-done)?\("
)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the HLO text.

    The *result* shape is the communicated payload (for all-gather it is the
    gathered size, for reduce-scatter the scattered shard, etc.) — a
    consistent, slightly conservative proxy for wire bytes.  Async pairs are
    counted once (the -done result); -start tuple aliases are skipped.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        if m.group("async") == "-start":
            continue  # payload counted at the matching -done
        total = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(m.group("type"))
        )
        out[m.group("kind")] += total
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one (arch × shape × mesh) cell.

    IMPORTANT semantics (verified empirically): under SPMD partitioning,
    ``cost_analysis``/``memory_analysis`` and the partitioned-HLO shapes are all
    **per device**.  ``flops``/``hbm_bytes``/``coll_bytes`` here are therefore
    per-chip quantities; ``model_flops`` is the analytic **global** count and is
    divided by ``n_chips`` when compared.
    """

    flops: float  # reconstructed per-chip HLO FLOPs for one step
    hbm_bytes: float  # reconstructed per-chip bytes accessed
    coll_bytes: float  # reconstructed per-chip collective payload bytes
    n_chips: int
    model_flops: float = 0.0  # analytic global 6·N·D (train) / 2·N·D (serve)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per chip) — catches remat/dispatch waste."""
        return (self.model_flops / self.n_chips) / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """(useful compute time at peak) / (dominant roofline term) — the
        headline §Perf score per cell."""
        t_min = self.model_flops / self.n_chips / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_min / t_bound if t_bound else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "n_chips": self.n_chips,
            "model_flops_global": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }
