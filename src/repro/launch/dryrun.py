"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init) — 512 host devices back both the 16×16 single-pod
mesh and the 2×16×16 multi-pod mesh.

Scan-body reconstruction (see hlo_analysis.py): cost_analysis counts while
bodies once, so each single-pod cell is compiled three times — full model,
1 scan group, 2 scan groups — and
    total = cost(full) + (n_groups − 1) · [cost(2g) − cost(1g)]
"""
from __future__ import annotations

import os

# MUST precede any other import — jax locks the device count at first init.
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import functools
import json
import sys
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES_BY_NAME, ModelConfig, ShapeConfig
from repro.configs.registry import all_lm_configs, get_config
from repro.launch import flops as flops_lib
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim.smbgd import smbgd as make_smbgd
from repro.optim.base import apply_updates
from repro.sharding import rules


def _scan_period(cfg: ModelConfig) -> int:
    if cfg.family == "gemma2" and cfg.alt_local_global:
        return 2
    if cfg.family == "xlstm":
        return cfg.slstm_every or cfg.n_layers
    if cfg.family == "zamba2":
        return cfg.shared_attn_period
    return 1


def n_scan_groups(cfg: ModelConfig) -> int:
    return (cfg.n_layers - cfg.first_dense_layers) // _scan_period(cfg)


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "long_500k requires sub-quadratic attention (DESIGN.md §5)"
    return None


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, optimizer: str = "smbgd"):
    """Full update step: fwd + bwd + SMBGD (paper) or AdamW (baseline)."""
    if optimizer == "smbgd":
        tx = make_smbgd(learning_rate=1e-3, gamma=0.9, beta=0.98, microbatches=1)
    else:
        from repro.optim.optimizers import adamw

        tx = adamw(learning_rate=1e-3)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True
        )(params, batch, cfg)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return tx, train_step


def build_prefill(cfg: ModelConfig):
    def prefill(params, batch):
        logits, _ = M.forward(params, batch, cfg)
        return logits[:, -1:]  # next-token logits (don't materialize all)

    return prefill


def build_decode(cfg: ModelConfig):
    def decode(params, state, batch):
        return M.decode_step(params, state, batch, cfg)

    return decode


# ---------------------------------------------------------------------------
# shape-struct factories (no allocation anywhere)
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(tx, params_shape):
    return jax.eval_shape(tx.init, params_shape)


def abstract_serve_state(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        functools.partial(M.init_serve_state, cfg, shape.global_batch, shape.seq_len)
    )


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    optimizer: str = "smbgd",
):
    """Lower + compile one cell.  Returns (compiled, lowered)."""
    specs = M.input_specs(cfg, shape)
    batch_sh = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(
            mesh, rules.data_spec(s.shape, mesh, dp_only=cfg.dp_only)
        ),
        specs,
    )

    if shape.kind == "train":
        tx, step = build_train_step(cfg, optimizer)
        params_shape = abstract_params(cfg)
        opt_shape = abstract_opt_state(tx, params_shape)
        params_sh = rules.param_shardings(params_shape, cfg, mesh)
        opt_sh = _opt_shardings(opt_shape, cfg, mesh)
        with mesh:
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, opt_sh, batch_sh),
                out_shardings=(params_sh, opt_sh, rules.replicated(mesh)),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, specs)
            compiled = lowered.compile()
        return compiled, lowered

    if shape.kind == "prefill":
        step = build_prefill(cfg)
        params_shape = abstract_params(cfg)
        params_sh = rules.param_shardings(params_shape, cfg, mesh)
        with mesh:
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_shape, specs)
            compiled = lowered.compile()
        return compiled, lowered

    # decode
    step = build_decode(cfg)
    params_shape = abstract_params(cfg)
    params_sh = rules.param_shardings(params_shape, cfg, mesh)
    state_shape = abstract_serve_state(cfg, shape)
    state_sh = rules.state_shardings(state_shape, mesh)
    with mesh:
        jitted = jax.jit(
            step,
            in_shardings=(params_sh, state_sh, batch_sh),
            out_shardings=(
                jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                state_sh,
            ),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_shape, state_shape, specs)
        compiled = lowered.compile()
    return compiled, lowered


def _opt_shardings(opt_shape, cfg, mesh):
    """Optimizer state slots (Ĥ / mu / nu) mirror the param tree one level
    down, so the param path rules apply after stripping the slot prefix;
    scalars (step counters) are replicated."""

    def one(path, leaf):
        if leaf.ndim == 0:
            return rules.replicated(mesh)
        ps = rules._path_str(path)
        sub = ps.split("/", 1)[1] if "/" in ps else ps
        stacked = any(part in rules._STACKED_PREFIXES for part in sub.split("/"))
        ndim = leaf.ndim - (1 if stacked else 0)
        spec = rules.param_spec(sub, ndim, cfg, tuple(mesh.axis_names))
        if stacked:
            spec = jax.sharding.PartitionSpec(None, *spec)
        spec = rules._truncate_spec(spec, leaf.ndim)
        spec = rules._validate_spec(spec, leaf.shape, mesh)
        return jax.sharding.NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, opt_shape)


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------


def analyze_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    optimizer: str = "smbgd",
    reconstruct: bool = True,
    variant: Optional[str] = None,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    if variant == "opt":
        from repro.launch.variants import optimized_config

        opt_cfg = optimized_config(cfg, shape_name)
        if opt_cfg is None:
            return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "skipped": f"no optimized variant registered"}
        cfg = opt_cfg
    shape = SHAPES_BY_NAME[shape_name]
    skip = cell_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "skipped": skip}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    compiled, lowered = lower_cell(cfg, shape, mesh, optimizer)
    compile_s = time.time() - t0

    cost = hlo.cost_summary(compiled)
    mem = hlo.memory_summary(compiled)
    coll = hlo.collective_bytes(compiled.as_text())
    result: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "n_chips": n_chips,
        "optimizer": optimizer if shape.kind == "train" else None,
        "compile_s": round(compile_s, 1),
        "cost_once": cost,
        "collective_once": coll,
        "memory": mem,
        "n_scan_groups": n_scan_groups(cfg),
    }

    if reconstruct and n_scan_groups(cfg) > 1:
        # Body cost via UNROLLED 1-group vs 2-group models (a scanned body is
        # counted once by cost_analysis regardless of trip count, so the diff
        # of two scanned models would be zero — unrolling makes it exact).
        period = _scan_period(cfg)
        base = cfg.first_dense_layers
        cfg1 = dataclasses.replace(cfg, n_layers=base + period, scan_layers=False)
        cfg2 = dataclasses.replace(cfg, n_layers=base + 2 * period, scan_layers=False)
        c1, l1 = lower_cell(cfg1, shape, mesh, optimizer)
        c2, l2 = lower_cell(cfg2, shape, mesh, optimizer)
        cost1, cost2 = hlo.cost_summary(c1), hlo.cost_summary(c2)
        coll1 = hlo.collective_bytes(c1.as_text())
        coll2 = hlo.collective_bytes(c2.as_text())
        ng = n_scan_groups(cfg)
        body_flops = max(cost2["flops"] - cost1["flops"], 0.0)
        body_bytes = max(cost2["bytes"] - cost1["bytes"], 0.0)
        body_coll = max(coll2["total"] - coll1["total"], 0)
        flops_total = cost["flops"] + (ng - 1) * body_flops
        bytes_total = cost["bytes"] + (ng - 1) * body_bytes
        coll_total = coll["total"] + (ng - 1) * body_coll
        result["body"] = {
            "flops": body_flops,
            "bytes": body_bytes,
            "coll_bytes": body_coll,
        }
    else:
        flops_total = cost["flops"]
        bytes_total = cost["bytes"]
        coll_total = coll["total"]

    dp_shards = int(np.prod([
        s for a, s in zip(mesh.axis_names, mesh.devices.shape) if a in ("pod", "data")
    ]))
    flops_total += flops_lib.slstm_scan_correction(
        cfg, shape, n_chips=n_chips, dp_shards=dp_shards
    )
    mf = flops_lib.model_flops(cfg, shape)
    roof = hlo.Roofline(
        flops=flops_total,
        hbm_bytes=bytes_total,
        coll_bytes=float(coll_total),
        n_chips=n_chips,
        model_flops=mf,
    )
    result["roofline"] = roof.as_dict()
    return result


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--optimizer", default="smbgd", choices=["smbgd", "adamw"])
    ap.add_argument("--all", action="store_true", help="sweep every cell")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--no-reconstruct", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute existing results")
    ap.add_argument("--variant", default=None, choices=[None, "opt"],
                    help="'opt': apply the registered optimized config (§Perf)")
    args = ap.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [
            (a, s.name)
            for a, cfg in all_lm_configs().items()
            for s in SHAPES_BY_NAME.values()
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            name = f"{arch}__{shape}__{mesh_kind}"
            if args.variant:
                name += f"__{args.variant}"
            path = outdir / f"{name}.json"
            if path.exists() and not args.force:
                print(f"[skip-cached] {name}")
                continue
            try:
                # multi-pod pass proves partitioning; reconstruction only on single
                rec = (mesh_kind == "single") and not args.no_reconstruct
                res = analyze_cell(arch, shape, mesh_kind, args.optimizer, rec, args.variant)
                path.write_text(json.dumps(res, indent=2, default=float))
                roof = res.get("roofline", {})
                skip = res.get("skipped")
                if skip:
                    print(f"[skipped] {name}: {skip}")
                else:
                    print(
                        f"[ok] {name}: compile={res['compile_s']}s "
                        f"bottleneck={roof.get('bottleneck')} "
                        f"frac={roof.get('roofline_fraction', 0):.3f}"
                    )
            except Exception as e:
                failures += 1
                print(f"[FAIL] {name}: {type(e).__name__}: {e}")
                traceback.print_exc()
                (outdir / f"{name}.error.txt").write_text(traceback.format_exc())
            sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
