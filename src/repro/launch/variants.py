"""Optimized ("beyond-paper") per-cell config variants for the §Perf
hillclimb.  The baseline is the paper-faithful generic TP+DP policy recorded
in ``benchmarks/results/dryrun``; each entry here is the winning configuration
from the hypothesis→change→measure log in EXPERIMENTS.md §Perf.

Apply with:  python -m repro.launch.dryrun --arch X --shape Y --variant opt
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig

# (arch, shape) → config overrides
VARIANTS: Dict[Tuple[str, str], Dict] = {
    # Cell 1 — the paper-representative SMBGD training cell.
    # 135M params need no TP (9 heads don't even divide the 16-way model
    # axis → XLA replicated the whole attention pipeline per chip).  DP over
    # all 256 chips + bf16 softmax + no remat.
    ("smollm-135m", "train_4k"): dict(
        dp_only=True, remat=False, attn_softmax_dtype="bfloat16"
    ),
    # Cell 2 — the most collective-bound cell: mLSTM's (B,H,T,T) decay/score
    # tensors were resharded every layer (H=4 can't split 16 ways).  DP-only
    # removes the per-layer gather storm; 1.3B params replicate fine.
    # (bf16 mLSTM T² math measured 7% WORSE on the CPU backend — XLA:CPU
    # emulates bf16 via convert→f32-math→convert; kept f32 here, bf16 is the
    # right setting on real TPU.  EXPERIMENTS.md §Perf iterations 2-3.)
    ("xlstm-1.3b", "train_4k"): dict(
        dp_only=True, remat=False, dtype="float32", mlstm_chunk=1024
    ),
    # Cell 3 — worst roofline fraction: B=1 single-token decode; per-token
    # latency is pure parameter/state streaming.  TP16 keeps the stream at
    # params/16 per chip; fp32 weights avoid the XLA:CPU bf16→f32 convert
    # (which tripled traffic: 2B read + 4B write per weight).  On real TPU
    # keep bf16 (native) — this is a backend-measurement adaptation, recorded
    # in EXPERIMENTS.md §Perf.
    ("zamba2-2.7b", "long_500k"): dict(dtype="float32"),
}


def optimized_config(cfg: ModelConfig, shape_name: str) -> Optional[ModelConfig]:
    kw = VARIANTS.get((cfg.name, shape_name))
    return dataclasses.replace(cfg, **kw) if kw else None
