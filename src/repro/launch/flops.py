"""Analytic MODEL_FLOPS (the "useful compute" yardstick for §Roofline).

Conventions:
  * train:    6·N·tokens  (fwd 2N + bwd 4N per token) + attention term
  * prefill:  2·N·tokens + attention term
  * decode:   2·N·batch (one token each) + cache-attention term
  * N = active non-embedding params (MoE: routed experts count k/E-weighted;
    embeddings excluded per the standard 6ND convention, LM head included).

Attention terms (per layer, causal halves the quadratic):
  * full-seq: 2 · 2 · B · Hq · dh · T²/2  (qk + pv)
  * decode:   2 · 2 · B · Hq · dh · T_ctx per step
  * sliding-window layers use min(T, window) as the effective context.
  * mamba2/mLSTM state terms are O(T·d·N_state) and folded in analytically.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def _param_counts(cfg: ModelConfig) -> Tuple[float, float]:
    """(total_params, active_params), excluding embeddings/LM-head from both
    (head flops are added separately since they always run)."""
    from repro.models.model import init_params

    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    total = active = 0.0

    def visit(path, leaf):
        nonlocal total, active
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if ps.split("/")[-1] in ("embed", "lm_head"):
            return
        total += leaf.size
        if "/moe/w_" in ps or ps.endswith("moe/w_gate") or ps.endswith("moe/w_up") or ps.endswith("moe/w_down"):
            active += leaf.size * cfg.experts_per_token / max(cfg.n_experts, 1)
        else:
            active += leaf.size

    jax.tree_util.tree_map_with_path(visit, shapes)
    return total, active


def _head_params(cfg: ModelConfig) -> float:
    k = max(cfg.n_codebooks, 1)
    return k * cfg.d_model * cfg.vocab_size


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "xlstm":
        return 0
    if cfg.family == "zamba2":
        return cfg.n_layers // cfg.shared_attn_period
    return cfg.n_layers


def _attention_flops_fullseq(cfg: ModelConfig, B: int, T: int) -> float:
    hq, dh = cfg.n_heads, cfg.head_dim_
    n_attn = _attn_layers(cfg)
    fl = 0.0
    for i in range(n_attn):
        if cfg.alt_local_global and i % 2 == 0 and cfg.sliding_window:
            t_eff = min(T, cfg.sliding_window)
            fl += 4 * B * hq * dh * T * t_eff  # window band
        else:
            fl += 4 * B * hq * dh * T * T / 2  # causal triangle
    # mamba2 SSD / mLSTM state terms
    if cfg.family == "zamba2":
        d_in = cfg.ssm_expand * cfg.d_model
        fl += cfg.n_layers * 6 * B * T * d_in * cfg.ssm_state
    if cfg.family == "xlstm":
        d_in = cfg.ssm_expand * cfg.d_model
        n_m = cfg.n_layers - cfg.n_layers // max(cfg.slstm_every, cfg.n_layers)
        if cfg.mlstm_chunk and cfg.mlstm_chunk < T:
            # chunkwise form: intra-chunk band + inter-chunk matrix state
            L = cfg.mlstm_chunk
            H = cfg.n_heads
            dqk = d_in // H // 2
            dv = d_in // H
            fl += n_m * (3 * B * d_in * T * L / 2 + 4 * B * T * H * dqk * dv)
        else:
            # quadratic parallel form (qk+pv at dqk=dv/2)
            fl += n_m * 3 * B * d_in * T * T / 2
    return fl


def _attention_flops_decode(cfg: ModelConfig, B: int, T_ctx: int) -> float:
    hq, dh = cfg.n_heads, cfg.head_dim_
    n_attn = _attn_layers(cfg)
    fl = 0.0
    for i in range(n_attn):
        if cfg.family == "zamba2" and cfg.sliding_window:
            t_eff = min(T_ctx, cfg.sliding_window)  # ring cache
        elif cfg.alt_local_global and i % 2 == 0 and cfg.sliding_window:
            t_eff = min(T_ctx, cfg.sliding_window)
        else:
            t_eff = T_ctx
        fl += 4 * B * hq * dh * t_eff
    if cfg.family == "zamba2":
        d_in = cfg.ssm_expand * cfg.d_model
        fl += cfg.n_layers * 6 * B * d_in * cfg.ssm_state
    if cfg.family == "xlstm":
        d_in = cfg.ssm_expand * cfg.d_model
        dqk = d_in // cfg.n_heads // 2 * cfg.n_heads
        fl += cfg.n_layers * 4 * B * dqk * (d_in // cfg.n_heads)  # C update+read
    return fl


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    total, active = _param_counts(cfg)
    head = _head_params(cfg)
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * T
        return 6 * (active + head) * tokens + 3 * _attention_flops_fullseq(cfg, B, T)
    if shape.kind == "prefill":
        tokens = B * T
        return 2 * (active + head) * tokens + _attention_flops_fullseq(cfg, B, T)
    # decode: one token per sequence against a T-long cache/state
    return 2 * (active + head) * B + _attention_flops_decode(cfg, B, T)


def slstm_scan_correction(
    cfg: ModelConfig, shape: ShapeConfig, n_chips: int = 1, dp_shards: int = 1
) -> float:
    """Extra **per-chip** HLO FLOPs hidden inside the sLSTM time-scan
    (cost_analysis counts the cell once; trip count = T).  Recurrent path only
    — the input path is computed outside the scan.  The cell body operates on
    the chip-local batch slice: B_local = B / (all axes if dp_only else the
    data axes), so the correction is divided accordingly."""
    if cfg.family != "xlstm" or not cfg.slstm_every:
        return 0.0
    n_s = cfg.n_layers // cfg.slstm_every
    shards = n_chips if cfg.dp_only else dp_shards
    B_local = max(shape.global_batch // max(shards, 1), 1)
    T = shape.seq_len if shape.kind != "decode" else 1
    H = cfg.n_heads
    dh = cfg.d_model // H
    per_step = 2 * 4 * H * dh * dh * B_local  # 4 gate recurrent matmuls
    mult = 3 if shape.kind == "train" else 1
    return n_s * (T - 1) * per_step * mult
