"""Cluster serving launcher (decode cells' production path).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --local
"""
from __future__ import annotations

import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    import jax

    from repro.configs.registry import get_config
    from repro.models.model import init_params
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_config(args.arch)
    if args.local:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(
        max_batch=args.batch, max_len=args.prompt_len + args.new_tokens + 8,
    ))
    shape = (
        (args.batch, args.prompt_len, cfg.n_codebooks)
        if cfg.n_codebooks else (args.batch, args.prompt_len)
    )
    prompts = jax.random.randint(jax.random.PRNGKey(1), shape, 0, cfg.vocab_size)
    out, _ = eng.prefill_and_generate(prompts, n_new=args.new_tokens)
    print(f"[serve] generated {out.shape}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
