"""Fault-tolerant checkpointing: atomic, async, topology-independent.

Design (orbax-lite, zero external deps):
  * a checkpoint is a directory ``step_<N>/`` holding one ``.npy`` per pytree
    leaf (named by its flattened path) + ``manifest.json`` (treedef, shapes,
    dtypes, step, timestamp),
  * writes go to ``step_<N>.tmp/`` then ``os.rename`` → readers never see a
    partial checkpoint (restore scans for the newest *complete* step),
  * leaves are saved **unsharded** (host-gathered): restore can reshard onto a
    different mesh/topology — this is the elastic-restart path (512 → 256 chips
    works; tested),
  * ``save_async`` hands the device→host copy result to a writer thread so the
    train loop only blocks for the D2H copy, not the filesystem,
  * ``keep`` bounds disk usage (old steps GC'd oldest-first),
  * a SIGTERM handler can be installed to flush a final checkpoint on
    preemption (``install_preemption_hook``).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_LEAF_SEP = "__"


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        names.append(_LEAF_SEP.join(parts) or "leaf")
        leaves.append(leaf)
    return names, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._writer: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: PyTree, block: bool = True) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # D2H
        if block:
            self._write(step, host_tree)
        else:
            self.wait()  # at most one in-flight write
            self._writer = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True
            )
            self._writer.start()

    def save_async(self, step: int, tree: PyTree) -> None:
        self.save(step, tree, block=False)

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    def _write(self, step: int, host_tree: PyTree) -> None:
        names, leaves, treedef = _flatten_with_names(host_tree)
        tmp = self.dir / f"step_{step:012d}.tmp"
        final = self.dir / f"step_{step:012d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": [],
        }
        for name, leaf in zip(names, leaves):
            arr = np.asarray(leaf)
            np.save(tmp / f"{name}.npy", arr)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:012d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def leaf_shapes(self, step: Optional[int] = None) -> dict:
        """Leaf name → shape (tuple) from the step's manifest, WITHOUT
        loading any array data.  This is the elastic-restore peek: a service
        whose bank width changed since save reads the checkpoint's true
        leading dimension here and sizes its restore target to match,
        instead of failing the per-leaf shape check in ``restore``."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self.dir / f"step_{step:012d}" / "manifest.json"
        if not path.exists():
            raise FileNotFoundError(
                f"checkpoint step {step} not found in {self.dir} "
                f"(available steps: {self.all_steps() or 'none'})"
            )
        manifest = json.loads(path.read_text())
        return {
            entry["name"]: tuple(entry["shape"])
            for entry in manifest.get("leaves", [])
        }

    def restore(
        self,
        target: PyTree,
        step: Optional[int] = None,
        shardings: Optional[PyTree] = None,
    ) -> Tuple[PyTree, int]:
        """Restore into the structure of ``target`` (arrays or
        ShapeDtypeStructs).  ``shardings``: optional NamedSharding pytree —
        leaves are ``jax.device_put`` with it (reshard-on-load; works across
        topology changes because files are unsharded)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:012d}"
        if not d.is_dir():
            raise FileNotFoundError(
                f"checkpoint step {step} not found in {self.dir} "
                f"(available steps: {self.all_steps() or 'none'})"
            )
        names, leaves, treedef = _flatten_with_names(target)
        sh_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else None
        )
        out = []
        for i, (name, ref) in enumerate(zip(names, leaves)):
            path = d / f"{name}.npy"
            if not path.exists():
                raise FileNotFoundError(
                    f"checkpoint step {step} is missing leaf {name!r} ({path}): "
                    f"the checkpoint was written by a different tree structure — "
                    f"restore with the matching target, or delete the stale step"
                )
            try:
                arr = np.load(path)
            except (ValueError, OSError, EOFError) as e:
                raise ValueError(
                    f"checkpoint step {step} leaf {name!r} is corrupt "
                    f"({path}: {e}) — the file is truncated or not a valid "
                    f".npy; delete the damaged step directory and restore an "
                    f"earlier step"
                ) from e
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"checkpoint leaf {name}: shape {arr.shape} != expected {ref.shape}"
                )
            # np.save round-trips extension dtypes (bfloat16 and friends) as
            # raw void bytes; reinterpret against the target's dtype — the
            # bits on disk ARE the storage-dtype bits, not a cast source
            ref_np = np.dtype(ref.dtype)
            if arr.dtype.kind == "V" and arr.dtype.itemsize == ref_np.itemsize:
                arr = arr.view(ref_np)
            if sh_leaves is not None:
                out.append(jax.device_put(arr, sh_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
        return jax.tree_util.tree_unflatten(treedef, out), step


def install_preemption_hook(fn: Callable[[], None]) -> None:
    """Run ``fn`` (e.g. a final blocking save) on SIGTERM, then exit.  At
    cluster scale this catches scheduler preemptions."""

    def handler(signum, frame):
        fn()
        raise SystemExit(143)

    signal.signal(signal.SIGTERM, handler)
