"""Baseline optimizers (pure JAX): SGD, momentum-SGD, AdamW, Adafactor-lite.

The paper benchmarks SMBGD against plain SGD; AdamW is included because it is
the de-facto LM-training baseline and its 2-slot state is the memory foil to
SMBGD's 1-slot state in the 1T-param dry-run cell.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation, tree_zeros_like


def sgd(learning_rate: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -learning_rate * g, grads), state

    return GradientTransformation(init, update)


class MomentumState(NamedTuple):
    velocity: jnp.ndarray


def momentum(learning_rate: float, decay: float = 0.9, nesterov: bool = False) -> GradientTransformation:
    def init(params):
        return MomentumState(velocity=tree_zeros_like(params))

    def update(grads, state, params=None):
        v = jax.tree.map(lambda v, g: decay * v + g, state.velocity, grads)
        if nesterov:
            upd = jax.tree.map(lambda v, g: -learning_rate * (decay * v + g), v, grads)
        else:
            upd = jax.tree.map(lambda v: -learning_rate * v, v)
        return upd, MomentumState(velocity=v)

    return GradientTransformation(init, update)


class AdamWState(NamedTuple):
    mu: jnp.ndarray
    nu: jnp.ndarray
    count: jnp.ndarray


def adamw(
    learning_rate: float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    state_dtype=jnp.float32,
) -> GradientTransformation:
    def init(params):
        return AdamWState(
            mu=tree_zeros_like(params, dtype=state_dtype),
            nu=tree_zeros_like(params, dtype=state_dtype),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params=None):
        count = state.count + 1
        t = count.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)), state.nu, grads
        )
        mu_hat_scale = 1.0 / (1.0 - b1**t)
        nu_hat_scale = 1.0 / (1.0 - b2**t)

        def upd(m, v, p):
            step = m * mu_hat_scale / (jnp.sqrt(v * nu_hat_scale) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(step.dtype)
            return (-learning_rate * step).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(mu=mu, nu=nu, count=count)

    return GradientTransformation(init, update)


class AdafactorState(NamedTuple):
    row: jnp.ndarray  # pytree of row second-moment factors (or full moments for <2D)
    col: jnp.ndarray
    count: jnp.ndarray


def adafactor_lite(
    learning_rate: float, decay: float = 0.8, eps: float = 1e-30, clip: float = 1.0
) -> GradientTransformation:
    """Factored second moments for matrix params — sub-linear optimizer memory,
    the standard trick for very large models (complements SMBGD's 1-slot state)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        row = jax.tree.map(
            lambda p: jnp.zeros(p.shape[:-1], jnp.float32)
            if _factored(p)
            else jnp.zeros(p.shape, jnp.float32),
            params,
        )
        col = jax.tree.map(
            lambda p: jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            if _factored(p)
            else jnp.zeros((), jnp.float32),
            params,
        )
        return AdafactorState(row=row, col=col, count=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        count = state.count + 1
        beta = 1.0 - (count.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, r, c, p):
            g32 = g.astype(jnp.float32)
            sq = jnp.square(g32) + eps
            if _factored(p):
                r_new = beta * r + (1 - beta) * jnp.mean(sq, axis=-1)
                c_new = beta * c + (1 - beta) * jnp.mean(sq, axis=-2)
                r_fac = r_new / jnp.mean(r_new, axis=-1, keepdims=True)
                denom = jnp.sqrt(r_fac[..., None] * c_new[..., None, :])
            else:
                r_new = beta * r + (1 - beta) * sq
                c_new = c
                denom = jnp.sqrt(r_new)
            step = g32 / jnp.maximum(denom, eps)
            norm = jnp.sqrt(jnp.mean(jnp.square(step)))
            step = step / jnp.maximum(1.0, norm / clip)
            return (-learning_rate * step).astype(p.dtype), r_new, c_new

        out = jax.tree.map(upd, grads, state.row, state.col, params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        row = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        col = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdafactorState(row=row, col=col, count=count)

    return GradientTransformation(init, update)


OPTIMIZERS = {
    "sgd": sgd,
    "momentum": momentum,
    "adamw": adamw,
    "adafactor": adafactor_lite,
}
