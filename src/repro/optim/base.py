"""Minimal pure-JAX optimizer core (optax-compatible signatures, no optax dep).

A ``GradientTransformation`` is an ``(init, update)`` pair:
    state = tx.init(params)
    updates, state = tx.update(grads, state, params)
    params = apply_updates(params, updates)

Updates follow the optax sign convention: they are *added* to params, so a
descent method emits negative multiples of the gradient.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class GradientTransformation(NamedTuple):
    init: Callable[[PyTree], Any]
    update: Callable[[PyTree, Any, Optional[PyTree]], Tuple[PyTree, Any]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def tree_zeros_like(params: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def chain(*txs: GradientTransformation) -> GradientTransformation:
    """Compose transformations left-to-right (like optax.chain)."""

    def init(params):
        return tuple(tx.init(params) for tx in txs)

    def update(grads, state, params=None):
        new_state = []
        for tx, st in zip(txs, state):
            grads, st = tx.update(grads, st, params)
            new_state.append(st)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params=None):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
        return jax.tree.map(lambda g: g * scale, grads), state

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: g * factor, grads), state

    return GradientTransformation(init, update)


class ScheduleState(NamedTuple):
    count: jnp.ndarray


def scale_by_schedule(schedule: Callable[[jnp.ndarray], jnp.ndarray]) -> GradientTransformation:
    def init(params):
        return ScheduleState(count=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        s = schedule(state.count)
        return (
            jax.tree.map(lambda g: g * s, grads),
            ScheduleState(count=state.count + 1),
        )

    return GradientTransformation(init, update)


# -- schedules ---------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.0):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak_lr - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return schedule


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)
