"""SMBGD as a *general* gradient transformation — the paper's §IV/§VI claim
("SMBGD is not limited to EASI and can be used in various machine learning
problems that implement some flavor of SGD") made concrete.

Mapping of Eq. 1 onto generic SGD training:
  * "training sample p within mini-batch k"  →  microbatch p within step k
    (gradient accumulation with exponentially decaying weights β), and
  * "mini-batch k"  →  optimizer step k (momentum γ on the accumulator Ĥ).

Two entry points:

``smbgd(...)``            — per-step transformation: the trainer hands it ONE
                            gradient per step (the usual case, P=1 in Eq. 1,
                            which degenerates to heavy-ball momentum with
                            coefficient γ — the paper's momentum term).

``smbgd_microbatched(...)`` — the faithful P>1 rule: the trainer scans P
                            microbatch gradients through ``accumulate`` with
                            stale params (exactly the paper's frozen-B
                            semantics), then calls ``update`` once to commit.
                            See ``repro.train.microbatch``.

Memory note (matters at 1T params): SMBGD keeps ONE state tensor per param —
half of Adam — which is what lets kimi-k2-1t fit the 512-chip training cell.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation, tree_zeros_like


class SMBGDOptState(NamedTuple):
    h_hat: jnp.ndarray  # pytree: the Ĥ accumulator (momentum slot)
    step: jnp.ndarray  # int32 mini-batch index k


def smbgd(
    learning_rate: float,
    gamma: float = 0.9,
    beta: float = 1.0,
    microbatches: int = 1,
    state_dtype=None,
) -> GradientTransformation:
    """Per-step SMBGD (P = ``microbatches`` folded upstream, or 1).

    Emits updates ``-Ĥ_k`` with
        Ĥ_k = γ̂ Ĥ_{k-1} + μ g_k,     γ̂ = γ β^{P-1}
    (for P=1: classical heavy-ball with the paper's γ).  The β-weighting of a
    P>1 microbatch fold happens in ``repro.train.microbatch`` because it needs
    the per-microbatch gradients; by the time this transformation runs they
    are already summed with weights μ β^{P-1-p}, so here we only apply γ̂.
    """
    gamma_hat = gamma * beta ** (microbatches - 1)

    def init(params):
        return SMBGDOptState(
            h_hat=tree_zeros_like(params, dtype=state_dtype),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state: SMBGDOptState, params=None):
        # Paper: γ gated to 0 for the first mini-batch.
        g_eff = jnp.where(state.step == 0, 0.0, gamma_hat)

        def fold(h, g):
            return (g_eff * h + learning_rate * g.astype(h.dtype)).astype(h.dtype)

        h_hat = jax.tree.map(fold, state.h_hat, grads)
        updates = jax.tree.map(lambda h, g: (-h).astype(g.dtype), h_hat, grads)
        return updates, SMBGDOptState(h_hat=h_hat, step=state.step + 1)

    return GradientTransformation(init, update)


def smbgd_weights(P: int, mu: float, beta: float, dtype=jnp.float32) -> jnp.ndarray:
    """Within-step microbatch weights w_p = μ β^{P-1-p} (Eq. 1 unrolled)."""
    p = jnp.arange(P, dtype=dtype)
    return mu * jnp.power(jnp.asarray(beta, dtype), (P - 1) - p)
