"""Optimizer library: SMBGD (the paper's rule, generalized) + standard baselines."""
from repro.optim.base import (
    GradientTransformation,
    apply_updates,
    chain,
    clip_by_global_norm,
    constant,
    global_norm,
    scale,
    scale_by_schedule,
    tree_zeros_like,
    warmup_cosine,
)
from repro.optim.optimizers import OPTIMIZERS, adafactor_lite, adamw, momentum, sgd
from repro.optim.smbgd import SMBGDOptState, smbgd, smbgd_weights

__all__ = [
    "GradientTransformation",
    "OPTIMIZERS",
    "SMBGDOptState",
    "adafactor_lite",
    "adamw",
    "apply_updates",
    "chain",
    "clip_by_global_norm",
    "constant",
    "global_norm",
    "momentum",
    "scale",
    "scale_by_schedule",
    "sgd",
    "smbgd",
    "smbgd_weights",
    "tree_zeros_like",
    "warmup_cosine",
]


def make_optimizer(name: str, learning_rate: float, **kw) -> GradientTransformation:
    """Registry entry point used by configs (``optimizer: smbgd|sgd|adamw|...``)."""
    if name == "smbgd":
        return smbgd(learning_rate, **kw)
    if name in OPTIMIZERS:
        return OPTIMIZERS[name](learning_rate, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
