"""Deterministic, shard-aware, exactly-resumable data pipelines.

Every batch is a pure function of ``(seed, step)`` — there is no iterator
state to checkpoint: after a restart the trainer just continues from
``step+1`` and sees exactly the stream it would have seen.  This is the
property that makes checkpoint/restore and elastic restarts exact.

Sources:
  * ``SyntheticLM`` — structured pseudo-text (Zipfian unigrams + deterministic
    bigram chains) so perplexity actually falls during the example runs,
  * ``MemmapTokens`` — binary token file (np.memmap) with step-derived offsets,
  * ``MixedSignals`` — the ICA substrate: mixed sources for EASI training.

Shard-awareness: ``batch_for_step`` takes (dp_rank, dp_size) and returns the
local slice of the global batch — ranks see disjoint data, and the global
stream is invariant to dp_size (elastic-safe).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_codebooks: int = 0
    vision_tokens: int = 0
    d_model: int = 0  # for vision stub embeddings

    def batch_for_step(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> Dict[str, jnp.ndarray]:
        """The GLOBAL batch is a pure function of (seed, step); ranks slice it.
        The global stream is therefore invariant to dp_size (elastic-safe)."""
        assert self.global_batch % dp_size == 0
        local = self.global_batch // dp_size
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        kz, kc, kv = jax.random.split(key, 3)
        T = self.seq_len - (self.vision_tokens or 0)
        gb = self.global_batch
        shape = (gb, T, self.n_codebooks) if self.n_codebooks else (gb, T)
        # Zipf-ish unigram draw via exponential transform of uniforms
        u = jax.random.uniform(kz, shape, minval=1e-6, maxval=1.0)
        zipf = jnp.minimum(
            (1.0 / u**0.7).astype(jnp.int32) % self.vocab_size, self.vocab_size - 1
        )
        # deterministic bigram structure: every other token = f(prev) → learnable
        nxt = (zipf * 31 + 7) % self.vocab_size
        toks = jnp.where(
            (jnp.arange(T) % 2 == 1)[(None,) * (zipf.ndim - (2 if self.n_codebooks else 1))].reshape(
                (1, T) + ((1,) if self.n_codebooks else ())
            ),
            jnp.roll(nxt, 1, axis=1),
            zipf,
        )
        sl = slice(dp_rank * local, (dp_rank + 1) * local)
        out = {"tokens": toks[sl]}
        if self.vision_tokens:
            out["vision_embeds"] = (
                jax.random.normal(kv, (gb, self.vision_tokens, self.d_model))[sl]
                * 0.02
            )
        return out


@dataclasses.dataclass(frozen=True)
class MemmapTokens:
    """Pretokenized corpus: flat int32 file, step-derived strided windows."""

    path: str
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(
            self, "_data", np.memmap(self.path, dtype=np.int32, mode="r")
        )

    @property
    def n_tokens(self) -> int:
        return len(self._data)

    def batch_for_step(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> Dict[str, jnp.ndarray]:
        assert self.global_batch % dp_size == 0
        local = self.global_batch // dp_size
        n_windows = self.n_tokens // (self.seq_len + 1)
        rng = np.random.default_rng(self.seed + step * 1_000_003)
        idx = rng.integers(0, n_windows, size=(self.global_batch,))
        idx = idx[dp_rank * local : (dp_rank + 1) * local]
        rows = np.stack(
            [self._data[i * (self.seq_len + 1) : i * (self.seq_len + 1) + self.seq_len] for i in idx]
        )
        return {"tokens": jnp.asarray(rows)}


@functools.lru_cache(maxsize=64)
def _base_mixing_cached(pipe: "MixedSignals", lo: int, hi: int) -> jnp.ndarray:
    """Per-stream stationary mixing matrices ``(hi-lo, m, n)`` — a pure
    function of the pipe's seeds, so computed (batched SVD) once per
    (pipe, range), not once per tick."""
    seeds, _ = pipe._stream_params(lo, hi)
    return jax.jit(jax.vmap(pipe._base_mixing))(seeds)


@functools.partial(jax.jit, static_argnums=0)  # frozen dataclass → hashable
def _streamed_batch_jit(pipe: "MixedSignals", seeds, A0s, phases, step) -> jnp.ndarray:
    """vmap the per-stream generator over the (local) stream axis; jitted so a
    bank serving loop pays one compiled dispatch per tick, not S traces."""
    return jax.vmap(lambda sd, a0, ph: pipe._stream_batch(sd, a0, ph, step))(
        seeds, A0s, phases
    )


def make_lm_pipeline(cfg: ModelConfig, seq_len: int, global_batch: int, seed: int = 0) -> SyntheticLM:
    return SyntheticLM(
        vocab_size=cfg.vocab_size,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        n_codebooks=cfg.n_codebooks,
        vision_tokens=cfg.vision_tokens,
        d_model=cfg.d_model,
    )


@dataclasses.dataclass(frozen=True)
class MixedSignals:
    """Streaming ICA input: (optionally drifting) mixtures, step-addressable.

    With ``streams == 0`` (default) this is the legacy single-stream source:
    ``batch_for_step`` returns ``(batch, m)`` and dp ranks slice the batch.

    With ``streams = S > 0`` the pipeline grows a leading stream axis — the
    substrate for ``repro.stream.SeparatorBank``: ``batch_for_step`` returns
    ``(S, batch, m)`` where stream s has its own seed (own sources, own mixing
    matrix) and its own drift phase, so a bank sees S genuinely distinct
    separation problems.  dp ranks then slice the *stream* axis (streams are
    the unit of device parallelism; ``streams % dp_size == 0``), matching
    ``stream.sharding.make_sharded_bank_step``.
    """

    m: int = 4
    n: int = 2
    batch: int = 8
    seed: int = 0
    drift_rate: float = 0.0  # >0: non-stationary mixing (adaptive regime)
    streams: int = 0  # 0 → legacy single-stream; S>0 → leading (S, ...) axis

    # per-stream seed/drift-phase derivation (stream=None → legacy stream)
    def _stream_seed(self, stream: Optional[int]) -> int:
        return self.seed if stream is None else self.seed + 1_000_003 * (stream + 1)

    def _drift_phase(self, stream: Optional[int]) -> float:
        # golden-angle stagger so concurrent streams never drift in phase
        return 0.0 if stream is None else 2.399963229728653 * (stream + 1)

    def _base_mixing(self, seed) -> jnp.ndarray:
        """Stationary mixing matrix A0 from a (traced) seed."""
        from repro.data import signals

        return signals.random_mixing_matrix(jax.random.PRNGKey(seed), self.m, self.n)

    def _drift(self, A0: jnp.ndarray, phase, step) -> jnp.ndarray:
        """Apply the drift rotation (no-op when drift_rate == 0)."""
        if not self.drift_rate:
            return A0
        theta = self.drift_rate * step * self.batch + phase
        c, s = jnp.cos(theta), jnp.sin(theta)
        R = jnp.eye(self.m).at[0, 0].set(c).at[1, 1].set(c).at[0, 1].set(-s).at[1, 0].set(s)
        return R @ A0

    def _mixing_traced(self, seed, phase, step) -> jnp.ndarray:
        """Mixing matrix from traced (seed, phase, step) — vmap/jit-safe."""
        return self._drift(self._base_mixing(seed), phase, step)

    def mixing_at(self, step: int, stream: Optional[int] = None) -> jnp.ndarray:
        """Mixing matrix at ``step``: ``(m, n)`` for one stream, or stacked
        ``(S, m, n)`` when ``streams > 0`` and ``stream`` is omitted."""
        if self.streams and stream is None:
            seeds, phases = self._stream_params(0, self.streams)
            return jax.vmap(lambda sd, ph: self._mixing_traced(sd, ph, step))(
                seeds, phases
            )
        return self._mixing_traced(
            self._stream_seed(stream), self._drift_phase(stream), step
        )

    def _stream_batch(self, seed, A0, phase, step) -> jnp.ndarray:
        """One stream's ``(batch, m)`` mini-batch from traced params (``A0``
        is the precomputed stationary mixing matrix — drift applied here)."""
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
        t = step * self.batch + jnp.arange(self.batch)
        # mixed sub-Gaussian bank: even components sinusoidal, odd uniform
        s_sine = jnp.sin(0.05 * t[:, None] + jnp.arange(self.n)[None, :] * 2.1)
        s_unif = jax.random.uniform(
            key, (self.batch, self.n), minval=-1.7320508, maxval=1.7320508
        )
        S = jnp.where(jnp.arange(self.n)[None, :] % 2 == 0, s_sine * 2**0.5, s_unif)
        A = self._drift(A0, phase, step)
        return S @ A.T

    @functools.lru_cache(maxsize=64)
    def _stream_params(self, lo: int, hi: int):
        """Per-stream (seeds, phases) arrays — pure in (self, lo, hi), cached
        so the per-tick path doesn't rebuild O(S) host lists."""
        seeds = jnp.asarray([self._stream_seed(s) for s in range(lo, hi)])
        phases = jnp.asarray([self._drift_phase(s) for s in range(lo, hi)])
        return seeds, phases

    def batch_for_step(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> jnp.ndarray:
        """Global mini-batch is a pure function of (seed, step); ranks slice —
        the batch axis in single-stream mode, the stream axis in bank mode."""
        if self.streams:
            # one traced program generates the whole (local_S, batch, m) block:
            # at bank scale the fused separator step is a single dispatch, so
            # host-side data gen must not become an O(S) Python loop per tick
            assert self.streams % dp_size == 0
            local = self.streams // dp_size
            lo = dp_rank * local
            seeds, phases = self._stream_params(lo, lo + local)
            A0s = _base_mixing_cached(self, lo, lo + local)
            return _streamed_batch_jit(self, seeds, A0s, phases, step)
        assert self.batch % dp_size == 0
        X = self._stream_batch(
            self._stream_seed(None),
            self._base_mixing(self._stream_seed(None)),
            self._drift_phase(None),
            step,
        )
        local = self.batch // dp_size
        return X[dp_rank * local : (dp_rank + 1) * local]
