"""Deterministic, shard-aware, exactly-resumable data pipelines.

Every batch is a pure function of ``(seed, step)`` — there is no iterator
state to checkpoint: after a restart the trainer just continues from
``step+1`` and sees exactly the stream it would have seen.  This is the
property that makes checkpoint/restore and elastic restarts exact.

Sources:
  * ``SyntheticLM`` — structured pseudo-text (Zipfian unigrams + deterministic
    bigram chains) so perplexity actually falls during the example runs,
  * ``MemmapTokens`` — binary token file (np.memmap) with step-derived offsets,
  * ``MixedSignals`` — the ICA substrate: mixed sources for EASI training.

Shard-awareness: ``batch_for_step`` takes (dp_rank, dp_size) and returns the
local slice of the global batch — ranks see disjoint data, and the global
stream is invariant to dp_size (elastic-safe).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_codebooks: int = 0
    vision_tokens: int = 0
    d_model: int = 0  # for vision stub embeddings

    def batch_for_step(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> Dict[str, jnp.ndarray]:
        """The GLOBAL batch is a pure function of (seed, step); ranks slice it.
        The global stream is therefore invariant to dp_size (elastic-safe)."""
        assert self.global_batch % dp_size == 0
        local = self.global_batch // dp_size
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        kz, kc, kv = jax.random.split(key, 3)
        T = self.seq_len - (self.vision_tokens or 0)
        gb = self.global_batch
        shape = (gb, T, self.n_codebooks) if self.n_codebooks else (gb, T)
        # Zipf-ish unigram draw via exponential transform of uniforms
        u = jax.random.uniform(kz, shape, minval=1e-6, maxval=1.0)
        zipf = jnp.minimum(
            (1.0 / u**0.7).astype(jnp.int32) % self.vocab_size, self.vocab_size - 1
        )
        # deterministic bigram structure: every other token = f(prev) → learnable
        nxt = (zipf * 31 + 7) % self.vocab_size
        toks = jnp.where(
            (jnp.arange(T) % 2 == 1)[(None,) * (zipf.ndim - (2 if self.n_codebooks else 1))].reshape(
                (1, T) + ((1,) if self.n_codebooks else ())
            ),
            jnp.roll(nxt, 1, axis=1),
            zipf,
        )
        sl = slice(dp_rank * local, (dp_rank + 1) * local)
        out = {"tokens": toks[sl]}
        if self.vision_tokens:
            out["vision_embeds"] = (
                jax.random.normal(kv, (gb, self.vision_tokens, self.d_model))[sl]
                * 0.02
            )
        return out


@dataclasses.dataclass(frozen=True)
class MemmapTokens:
    """Pretokenized corpus: flat int32 file, step-derived strided windows."""

    path: str
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(
            self, "_data", np.memmap(self.path, dtype=np.int32, mode="r")
        )

    @property
    def n_tokens(self) -> int:
        return len(self._data)

    def batch_for_step(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> Dict[str, jnp.ndarray]:
        assert self.global_batch % dp_size == 0
        local = self.global_batch // dp_size
        n_windows = self.n_tokens // (self.seq_len + 1)
        rng = np.random.default_rng(self.seed + step * 1_000_003)
        idx = rng.integers(0, n_windows, size=(self.global_batch,))
        idx = idx[dp_rank * local : (dp_rank + 1) * local]
        rows = np.stack(
            [self._data[i * (self.seq_len + 1) : i * (self.seq_len + 1) + self.seq_len] for i in idx]
        )
        return {"tokens": jnp.asarray(rows)}


def make_lm_pipeline(cfg: ModelConfig, seq_len: int, global_batch: int, seed: int = 0) -> SyntheticLM:
    return SyntheticLM(
        vocab_size=cfg.vocab_size,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        n_codebooks=cfg.n_codebooks,
        vision_tokens=cfg.vision_tokens,
        d_model=cfg.d_model,
    )


@dataclasses.dataclass(frozen=True)
class MixedSignals:
    """Streaming ICA input: (optionally drifting) mixtures, step-addressable."""

    m: int = 4
    n: int = 2
    batch: int = 8
    seed: int = 0
    drift_rate: float = 0.0  # >0: non-stationary mixing (adaptive regime)

    def mixing_at(self, step: int) -> jnp.ndarray:
        from repro.data import signals

        key = jax.random.PRNGKey(self.seed)
        A0 = signals.random_mixing_matrix(key, self.m, self.n)
        if not self.drift_rate:
            return A0
        theta = self.drift_rate * step * self.batch
        c, s = jnp.cos(theta), jnp.sin(theta)
        R = jnp.eye(self.m).at[0, 0].set(c).at[1, 1].set(c).at[0, 1].set(-s).at[1, 0].set(s)
        return R @ A0

    def batch_for_step(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> jnp.ndarray:
        """Global mini-batch is a pure function of (seed, step); ranks slice."""
        assert self.batch % dp_size == 0
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        t = step * self.batch + jnp.arange(self.batch)
        # mixed sub-Gaussian bank: even components sinusoidal, odd uniform
        s_sine = jnp.sin(0.05 * t[:, None] + jnp.arange(self.n)[None, :] * 2.1)
        s_unif = jax.random.uniform(
            key, (self.batch, self.n), minval=-1.7320508, maxval=1.7320508
        )
        S = jnp.where(jnp.arange(self.n)[None, :] % 2 == 0, s_sine * 2**0.5, s_unif)
        A = self.mixing_at(step)
        X = S @ A.T
        local = self.batch // dp_size
        return X[dp_rank * local : (dp_rank + 1) * local]
