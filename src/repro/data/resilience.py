"""Input-side fault containment: retry wrapper + fault-injection harness.

Production feeds fail in ways a recording never does: transient I/O errors,
stalls, short reads, sensor glitches that arrive as NaN/Inf or amplitude
spikes.  Two wrappers make those failure modes first-class:

  * ``ResilientSource`` — bounded retry-with-backoff and an optional stall
    timeout around any ``SignalSource.next_block``.  ``SourceExhausted``
    passes straight through (a drained feed is a finished session, not a
    fault); anything else is retried ``max_retries`` times with exponential
    backoff, then re-raised (``SourceStalled`` for timeouts) — at which point
    ``SeparationService.run_tick`` isolates the failure to that one session
    (degraded tick via the active mask) instead of failing the launch.

  * ``FaultInjector`` — the chaos harness: deterministic faults scheduled by
    block index (NaN burst, Inf burst, amplitude spike, truncated block,
    transient raise, stall).  Drives the end-to-end containment tests:
    inject → in-kernel detection → rollback/quarantine → healthy sessions
    bit-identical to a fault-free run.

Both wrappers delegate every other attribute (``position``, ``seek``,
``n_channels``, ``true_mixing``, ...) to the wrapped source, so the service's
cursor bookkeeping and the drift watchdog see straight through them.
"""
from __future__ import annotations

import concurrent.futures
import time
from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.data.sources import SourceExhausted


class SourceStalled(Exception):
    """Raised by ``ResilientSource`` when ``next_block`` exceeds the stall
    timeout (the wrapped call may still be running on its worker thread —
    the wrapper abandons it and the service degrades the session's tick)."""


class ResilientSource:
    """Bounded retry-with-backoff (+ optional stall timeout) around a source.

    ``max_retries`` extra attempts follow a failed ``next_block`` (so at most
    ``1 + max_retries`` calls per block), sleeping ``backoff_s * 2**attempt``
    between attempts.  ``timeout_s`` runs each attempt on a worker thread and
    raises ``SourceStalled`` when it doesn't return in time.  Retries are
    counted for the service's ``n_source_retries`` metric — drain the counter
    with ``pop_retries()``.
    """

    def __init__(
        self,
        source,
        max_retries: int = 3,
        backoff_s: float = 0.0,
        timeout_s: Optional[float] = None,
    ):
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")
        if timeout_s is not None and timeout_s <= 0:
            raise ValueError("timeout_s must be > 0")
        self._source = source
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.timeout_s = timeout_s
        self._retries = 0
        self._pool: Optional[concurrent.futures.ThreadPoolExecutor] = None

    def pop_retries(self) -> int:
        """Drain the retry counter (the service folds it into
        ``metrics['n_source_retries']`` every tick)."""
        out, self._retries = self._retries, 0
        return out

    def _attempt(self, n_samples: int) -> np.ndarray:
        if self.timeout_s is None:
            return self._source.next_block(n_samples)
        if self._pool is None:
            self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        fut = self._pool.submit(self._source.next_block, n_samples)
        try:
            return fut.result(timeout=self.timeout_s)
        except concurrent.futures.TimeoutError:
            # the worker may be wedged mid-call: abandon the pool so the next
            # attempt gets a fresh thread instead of queueing behind the stall
            fut.cancel()
            self._pool.shutdown(wait=False)
            self._pool = None
            raise SourceStalled(
                f"next_block({n_samples}) exceeded {self.timeout_s}s"
            ) from None

    def next_block(self, n_samples: int) -> np.ndarray:
        last: Optional[BaseException] = None
        for attempt in range(1 + self.max_retries):
            try:
                return self._attempt(n_samples)
            except SourceExhausted:
                raise  # drained, not faulted — never retried
            except Exception as e:  # noqa: BLE001 — the whole point
                last = e
                if attempt < self.max_retries:
                    self._retries += 1
                    if self.backoff_s:
                        time.sleep(self.backoff_s * (2**attempt))
        raise last

    def __getattr__(self, name):
        return getattr(self._source, name)


#: fault modes understood by ``FaultInjector`` (see class docstring)
FAULT_MODES = ("nan", "inf", "spike", "truncate", "raise", "stall")


class FaultInjector:
    """Deterministic chaos harness: inject one fault per scheduled block.

    ``faults`` maps block index (0-based count of ``next_block`` calls) to a
    fault mode, or to ``(mode, magnitude)`` for parameterized modes:

      * ``"nan"`` / ``"inf"`` — overwrite the first ``magnitude`` fraction of
        the block's samples (default 0.25) with NaN / +Inf,
      * ``"spike"``  — scale the whole block by ``magnitude`` (default 1e6),
      * ``"truncate"`` — return only the first half (``magnitude`` fraction)
        of the requested samples (a short read: wrong shape downstream),
      * ``"raise"`` — raise ``RuntimeError`` INSTEAD of pulling (transient:
        the block is not consumed, a retry pulls it clean),
      * ``"stall"`` — sleep ``magnitude`` seconds (default 0.25) before
        pulling (pairs with ``ResilientSource(timeout_s=...)``).

    Everything else passes through untouched, so a fault-free ``FaultInjector``
    is bit-identical to the bare source — the property the chaos tests'
    healthy-session comparisons rest on.
    """

    def __init__(
        self,
        source,
        faults: Dict[int, Union[str, Tuple[str, float]]],
    ):
        norm: Dict[int, Tuple[str, Optional[float]]] = {}
        for idx, spec in faults.items():
            mode, mag = spec if isinstance(spec, tuple) else (spec, None)
            if mode not in FAULT_MODES:
                raise ValueError(
                    f"unknown fault mode {mode!r} (choose from {FAULT_MODES})"
                )
            norm[int(idx)] = (mode, mag)
        self._source = source
        self._faults = norm
        self._blocks = 0  # next_block call counter (the fault schedule key)
        self.injected: Dict[int, str] = {}  # what actually fired (test probe)

    def next_block(self, n_samples: int) -> np.ndarray:
        idx = self._blocks
        fault = self._faults.get(idx)
        if fault is not None and fault[0] == "raise":
            # transient: the inner cursor does NOT advance — a retry sees
            # the same block, clean (exactly how a flaky read behaves)
            self._faults.pop(idx)
            self.injected[idx] = "raise"
            raise RuntimeError(f"injected transient failure at block {idx}")
        self._blocks += 1
        mode, mag = fault if fault is not None else (None, None)
        if mode == "stall":
            time.sleep(0.25 if mag is None else float(mag))
        blk = np.array(self._source.next_block(n_samples), dtype=np.float32)
        if mode == "nan" or mode == "inf":
            k = max(1, int(round(n_samples * (0.25 if mag is None else mag))))
            blk[:, :k] = np.nan if mode == "nan" else np.inf
        elif mode == "spike":
            blk *= 1e6 if mag is None else float(mag)
        elif mode == "truncate":
            k = max(1, int(round(n_samples * (0.5 if mag is None else mag))))
            blk = blk[:, :k]
        if mode is not None:
            self.injected[idx] = mode
        return blk

    def __getattr__(self, name):
        return getattr(self._source, name)
