"""Source-signal generation and mixing for ICA experiments.

The paper evaluates on blind source separation (m=4 mixtures, n=2 sources).
EASI with the cubic nonlinearity is stable for *sub-Gaussian* sources, so the
default source bank is the classic BSS set: sinusoids, square/sawtooth waves and
uniform noise (all negative-kurtosis).  A Laplacian (super-Gaussian) source is
available for tanh-based runs.

Non-stationary mixing (``drifting_mixing_matrix``) exercises the *adaptive*
regime the paper motivates: the mixing matrix rotates slowly over time and the
separator must track it.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _unit_rows(A: jnp.ndarray) -> jnp.ndarray:
    return A / jnp.linalg.norm(A, axis=1, keepdims=True)


def source_bank(
    key: jax.Array, n_sources: int, T: int, dtype=jnp.float32
) -> jnp.ndarray:
    """``(T, n)`` matrix of independent, zero-mean, unit-variance sources.

    Source i cycles through {sine, square, sawtooth, uniform, AM-sine} with
    randomized frequencies/phases so different seeds give different problems.
    """
    t = jnp.arange(T, dtype=dtype)
    keys = jax.random.split(key, n_sources)
    cols = []
    for i in range(n_sources):
        kf, kp, kn = jax.random.split(keys[i], 3)
        freq = 0.005 + 0.05 * jax.random.uniform(kf, dtype=dtype)
        phase = 2 * jnp.pi * jax.random.uniform(kp, dtype=dtype)
        kind = i % 5
        if kind == 0:  # sine — kurtosis -1.5
            s = jnp.sin(2 * jnp.pi * freq * t + phase)
        elif kind == 1:  # square — kurtosis -2
            s = jnp.sign(jnp.sin(2 * jnp.pi * freq * t + phase))
        elif kind == 2:  # sawtooth — kurtosis -1.2
            s = 2.0 * jnp.mod(freq * t + phase, 1.0) - 1.0
        elif kind == 3:  # uniform noise — kurtosis -1.2
            s = jax.random.uniform(kn, (T,), dtype=dtype, minval=-1.0, maxval=1.0)
        else:  # AM sine — sub-Gaussian
            s = jnp.sin(2 * jnp.pi * freq * t + phase) * jnp.sin(
                2 * jnp.pi * 0.1 * freq * t
            )
        s = s - jnp.mean(s)
        s = s / (jnp.std(s) + 1e-8)
        cols.append(s)
    return jnp.stack(cols, axis=1)


def random_mixing_matrix(
    key: jax.Array, m: int, n: int, dtype=jnp.float32, min_sv: float = 0.3
) -> jnp.ndarray:
    """Well-conditioned random mixing matrix ``A (m, n)``, unit-norm rows.

    Rejection-free conditioning: squash singular values away from zero so the
    separation problem is solvable at every seed.
    """
    A = jax.random.normal(key, (m, n), dtype=dtype)
    u, s, vt = jnp.linalg.svd(A, full_matrices=False)
    s = jnp.maximum(s, min_sv * jnp.max(s))
    return _unit_rows(u @ jnp.diag(s) @ vt)


def mix(A: jnp.ndarray, S: jnp.ndarray) -> jnp.ndarray:
    """Observed mixtures ``X (T, m) = S (T, n) @ Aᵀ``."""
    return S @ A.T


def make_problem(
    key: jax.Array, m: int = 4, n: int = 2, T: int = 20000, dtype=jnp.float32
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The paper's benchmark problem (default m=4, n=2): returns (A, S, X)."""
    ks, ka = jax.random.split(key)
    S = source_bank(ks, n, T, dtype)
    A = random_mixing_matrix(ka, m, n, dtype)
    return A, S, mix(A, S)


def drifting_mixing_matrix(
    key: jax.Array, m: int, n: int, T: int, rate: float = 1e-4, dtype=jnp.float32
) -> jnp.ndarray:
    """``(T, m, n)`` slowly rotating mixing matrix for adaptivity experiments.

    A(t) = R(rate·t) @ A0 with R a Givens rotation in a random plane of R^m —
    smooth drift of the kind §I says adaptive methods must track.
    """
    ka, kp = jax.random.split(key)
    A0 = random_mixing_matrix(ka, m, n, dtype)
    i, j = 0, 1 if m > 1 else 0
    theta = rate * jnp.arange(T, dtype=dtype)
    c, s = jnp.cos(theta), jnp.sin(theta)

    def rot(ct, st):
        R = jnp.eye(m, dtype=dtype)
        R = R.at[i, i].set(ct).at[j, j].set(ct).at[i, j].set(-st).at[j, i].set(st)
        return R

    Rs = jax.vmap(rot)(c, s)  # (T, m, m)
    return jnp.einsum("tij,jk->tik", Rs, A0)


def mix_nonstationary(At: jnp.ndarray, S: jnp.ndarray) -> jnp.ndarray:
    """X_t = A_t s_t for per-step mixing matrices ``At (T, m, n)``."""
    return jnp.einsum("tmn,tn->tm", At, S)
