"""Pluggable signal sources for the drift-aware serving pipeline.

The paper's datapath is fed by a fixed synthetic mixing experiment; a real
deployment (arXiv:2201.03206's CORTEX-style front end) sees heterogeneous
sources — EEG/RF channel banks, replayed recordings, synthetic drills — all
delivering windowed multi-channel blocks.  This module is the contract
between those feeds and ``serve.SeparationService.run_tick``:

``SignalSource`` protocol (structural — any object with the methods works):
  * ``next_block(n_samples) -> (m, n_samples)`` — the next contiguous
    channel-major block (CORTEX convention: channels are rows).  Raises
    ``SourceExhausted`` when the feed ends.
  * ``true_mixing() -> (m, n) | None`` — optional: the ground-truth mixing at
    the CURRENT cursor (synthetic/replayed workloads), used by the service's
    Amari confirmation and by drift experiments.  Real recordings return
    ``None`` or omit the method (see ``true_mixing_of``).
  * ``position`` / ``seek(position)`` — optional sample cursor, used by the
    service's lifecycle snapshots so a re-bound source resumes exactly where
    the checkpointed one stopped (see ``SeparationService.bind_source``).

Adapters:
  * ``SyntheticSource``   — wraps a ``MixedSignals`` stream (optionally one
    stream of a multi-stream pipe) behind a sample cursor, with an optional
    ``drift_start`` so the mixing rotates only after a known onset (the
    drift-watchdog drill).
  * ``ChannelBankSource`` — windowed reads from an ``.npy`` multi-channel
    recording (memory-mapped by default: the file never fully loads).
  * ``ReplaySource``      — a fixed in-memory array, for deterministic
    regression runs.

Trace capture + replay (the SLO harness's load-test substrate — see
``serve.slo``):
  * ``RecordingSource``   — transparent wrapper over ANY source: every block
    it serves (and the exhaustion point) is captured in served order.
  * ``save_recording`` / ``load_recording`` — persist captured blocks plus
    admission/eviction event stamps to one ``.npz`` trace and load them back
    as ``RecordedSource``s, which serve the captured blocks verbatim — a
    replayed run sees bit-identical data in bit-identical order, whatever
    the original source computed.
"""
from __future__ import annotations

import dataclasses
import functools
import json
from typing import Dict, Hashable, List, Optional, Protocol, Sequence, Union, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import MixedSignals


class SourceExhausted(Exception):
    """Raised by ``next_block`` when a finite source has no more samples.

    ``SeparationService.run_tick`` turns this into an eviction with reason
    ``"exhausted"`` — a drained recording is a finished session, not an error.
    """


@runtime_checkable
class SignalSource(Protocol):
    """Structural protocol for serving feeds (see module docstring)."""

    def next_block(self, n_samples: int) -> np.ndarray:  # (m, n_samples)
        ...


def true_mixing_of(source) -> Optional[np.ndarray]:
    """``source.true_mixing()`` if the source exposes one, else ``None`` —
    the service-side accessor that makes the method genuinely optional."""
    fn = getattr(source, "true_mixing", None)
    return None if fn is None else fn()


@functools.partial(jax.jit, static_argnums=0)  # frozen dataclass → hashable
def _source_batch_jit(pipe: MixedSignals, seed, A, phase, step) -> jnp.ndarray:
    """Module-level jit of the per-source block generator, keyed on the
    (frozen, hashable) stationary pipe: every ``SyntheticSource`` over the
    same pipe shape shares ONE compiled program.  A per-instance
    ``jax.jit(lambda ...)`` would give each source its own cache — and a
    full trace+compile on its first block, which on the serving path lands
    on whatever ``run_tick`` first pulls from a freshly activated session
    (ruinous right after an elastic grow backfills several at once)."""
    return pipe._stream_batch(seed, A, phase, step)


def _givens(m: int, theta) -> jnp.ndarray:
    """Rotation by ``theta`` in the (0, 1) plane of R^m — the same plane
    ``MixedSignals._drift`` and ``signals.drifting_mixing_matrix`` rotate."""
    c, s = jnp.cos(theta), jnp.sin(theta)
    return jnp.eye(m).at[0, 0].set(c).at[1, 1].set(c).at[0, 1].set(-s).at[1, 0].set(s)


class SyntheticSource:
    """A ``MixedSignals`` stream behind a sample cursor.

    Each ``next_block(P)`` (``P`` must equal ``pipe.batch`` — the generator's
    RNG is block-addressed) returns the next mini-batch as a channel-major
    ``(m, P)`` block and advances the cursor; two sources built from the same
    ``(pipe, stream)`` replay identical data (pure function of the cursor).

    Drift: the source applies the pipe's rotation itself — ``A(t) =
    R(drift_rate·(clip(t, start, stop)−start)·batch + phase)·A0`` — so
    ``drift_start`` delays the onset (stationary until a known block, then
    drifting: the watchdog drill) and ``drift_stop`` ends it (the mixing
    settles at a NEW stationary rotation, so a re-adapted separator can
    re-converge).  With ``drift_start == 0`` and no stop the blocks match
    ``pipe.batch_for_step`` exactly.  ``true_mixing()`` reports the mixing at
    the current cursor, which the service's Amari confirmation tracks live.
    """

    def __init__(
        self,
        pipe: MixedSignals,
        stream: Optional[int] = None,
        drift_start: int = 0,
        drift_stop: Optional[int] = None,
    ):
        if pipe.streams and stream is None:
            raise ValueError(
                f"pipe has {pipe.streams} streams; pass stream= to select one"
            )
        if drift_stop is not None and drift_stop < drift_start:
            raise ValueError(
                f"drift_stop {drift_stop} < drift_start {drift_start}"
            )
        self.pipe = pipe
        self.stream = stream
        self.drift_start = int(drift_start)
        self.drift_stop = None if drift_stop is None else int(drift_stop)
        self._seed = pipe._stream_seed(stream)
        self._phase = pipe._drift_phase(stream)
        self._A0 = pipe._base_mixing(self._seed)
        self._step = 0
        # one trace for every block: (seed, A_eff, phase, step) are traced,
        # the stationary-pipe shape knobs come from the frozen dataclass —
        # shared across instances via the module-level jit (see
        # ``_source_batch_jit``)
        pipe0 = dataclasses.replace(pipe, drift_rate=0.0, streams=0)
        self._gen = functools.partial(_source_batch_jit, pipe0)

    @property
    def n_channels(self) -> int:
        return self.pipe.m

    @property
    def block_size(self) -> int:
        return self.pipe.batch

    @property
    def position(self) -> int:
        """Sample cursor (``steps_served * batch``)."""
        return self._step * self.pipe.batch

    def seek(self, position: int) -> None:
        if position % self.pipe.batch:
            raise ValueError(
                f"position {position} not a multiple of batch {self.pipe.batch}"
            )
        self._step = position // self.pipe.batch

    def mixing_at(self, step: int) -> jnp.ndarray:
        """Ground-truth mixing at block ``step`` — the pipe's rotation with a
        delayed onset and optional end; ``drift_start == 0`` with no stop
        reproduces ``pipe.mixing_at`` exactly.  (Evaluating a separator
        against wall-clock time uses this directly; ``true_mixing`` is the
        cursor-relative protocol view.)"""
        if not self.pipe.drift_rate:
            return self._A0
        t = step if self.drift_stop is None else min(step, self.drift_stop)
        theta = (
            self.pipe.drift_rate
            * max(0, t - self.drift_start)
            * self.pipe.batch
            + self._phase
        )
        return _givens(self.pipe.m, theta) @ self._A0

    def true_mixing(self) -> np.ndarray:
        """Ground-truth mixing at the CURRENT cursor ``(m, n)``."""
        return np.asarray(self.mixing_at(self._step))

    def next_block(self, n_samples: int) -> np.ndarray:
        if n_samples != self.pipe.batch:
            raise ValueError(
                f"SyntheticSource generates fixed blocks of {self.pipe.batch} "
                f"samples (the pipe's RNG is block-addressed); got "
                f"n_samples={n_samples}"
            )
        A = self.mixing_at(self._step)
        X = self._gen(self._seed, A, self._phase, self._step)  # (P, m)
        self._step += 1
        return np.asarray(X, dtype=np.float32).T


class _WindowCursor:
    """Shared sample cursor over a finite recording: bounds-checked ``seek``
    and the loop-wrap / exhaustion advance both finite adapters use (one
    implementation, so the wrap-seam semantics cannot diverge).  Subclasses
    provide ``n_samples``, ``loop`` and ``_what`` (the noun for errors)."""

    _what = "source"

    @property
    def position(self) -> int:
        return self._pos

    def seek(self, position: int) -> None:
        if not 0 <= position <= self.n_samples:
            raise ValueError(f"position {position} outside [0, {self.n_samples}]")
        self._pos = position

    def _advance(self, n_samples: int) -> int:
        """Claim the next contiguous window; returns its start index.
        Wraps when ``loop`` (blocks never straddle the seam), raises
        ``SourceExhausted`` otherwise."""
        T = self.n_samples
        if self._pos + n_samples > T:
            if not self.loop:
                raise SourceExhausted(
                    f"{self._what} drained: {T - self._pos} of {T} samples "
                    f"left, {n_samples} requested"
                )
            self._pos %= T
            if self._pos + n_samples > T:
                self._pos = 0
        start = self._pos
        self._pos += n_samples
        return start


class ReplaySource(_WindowCursor):
    """A fixed ``(T, m)`` array served in order — deterministic regression
    feeds (and the adapter for data that is already in memory).

    ``loop=True`` wraps at the end; otherwise ``next_block`` raises
    ``SourceExhausted`` once fewer than ``n_samples`` remain.  ``mixing``
    (optional, ``(m, n)`` or ``(T, m, n)`` per-sample) makes the replay
    ground-truth-aware: ``true_mixing()`` reports the matrix at the cursor.
    """

    _what = "replay"

    def __init__(
        self,
        X: np.ndarray,
        loop: bool = False,
        mixing: Optional[np.ndarray] = None,
    ):
        X = np.asarray(X, dtype=np.float32)
        if X.ndim != 2:
            raise ValueError(f"X must be (T, m); got shape {X.shape}")
        self._X = X
        self.loop = loop
        self._mixing = None if mixing is None else np.asarray(mixing)
        if self._mixing is not None and self._mixing.ndim == 3:
            if self._mixing.shape[0] != X.shape[0]:
                raise ValueError(
                    f"per-sample mixing length {self._mixing.shape[0]} != "
                    f"T={X.shape[0]}"
                )
        self._pos = 0

    @property
    def n_channels(self) -> int:
        return self._X.shape[1]

    @property
    def n_samples(self) -> int:
        return self._X.shape[0]

    def reset(self) -> None:
        self._pos = 0

    def true_mixing(self) -> Optional[np.ndarray]:
        if self._mixing is None:
            return None
        if self._mixing.ndim == 3:
            return self._mixing[min(self._pos, self.n_samples - 1)]
        return self._mixing

    def next_block(self, n_samples: int) -> np.ndarray:
        start = self._advance(n_samples)
        return self._X[start : start + n_samples].T.copy()


class ChannelBankSource(_WindowCursor):
    """Windowed reads from a multi-channel ``.npy`` recording — the
    CORTEX-style channel bank (arXiv:2201.03206): a rack of EEG/RF channels
    mapped onto separator streams.

    ``path_or_array`` is an ``.npy`` file (memory-mapped by default, so a
    multi-GB recording streams without loading) or an in-memory array.
    ``layout="ct"`` (default) expects channel-major ``(C, T)``; ``"tc"``
    expects sample-major ``(T, C)``.  ``channels`` selects a sub-bank (one
    electrode group per session).  Each ``next_block(n)`` returns the next
    contiguous ``(C_sel, n)`` window and advances the cursor; ``loop=True``
    wraps, otherwise the source raises ``SourceExhausted`` at the end.
    ``center=True`` removes the per-channel mean of each window (EASI assumes
    zero-mean inputs; recordings have electrode offsets).
    """

    def __init__(
        self,
        path_or_array: Union[str, "np.ndarray"],
        channels: Optional[Sequence[int]] = None,
        layout: str = "ct",
        mmap: bool = True,
        loop: bool = False,
        center: bool = True,
    ):
        if layout not in ("ct", "tc"):
            raise ValueError(f"layout must be 'ct' or 'tc', got {layout!r}")
        if isinstance(path_or_array, (str,)) or hasattr(path_or_array, "__fspath__"):
            data = np.load(path_or_array, mmap_mode="r" if mmap else None)
        else:
            data = np.asarray(path_or_array)
        if data.ndim != 2:
            raise ValueError(f"recording must be 2-D, got shape {data.shape}")
        self._data = data if layout == "ct" else data.T  # view: (C, T)
        self._channels = None if channels is None else list(channels)
        if self._channels is not None:
            C = self._data.shape[0]
            bad = [c for c in self._channels if not 0 <= c < C]
            if bad:
                raise ValueError(f"channels {bad} outside [0, {C})")
        self.loop = loop
        self.center = center
        self._pos = 0

    @property
    def n_channels(self) -> int:
        return len(self._channels) if self._channels is not None else self._data.shape[0]

    _what = "recording"

    @property
    def n_samples(self) -> int:
        return self._data.shape[1]

    def next_block(self, n_samples: int) -> np.ndarray:
        start = self._advance(n_samples)
        win = self._data[:, start : start + n_samples]
        if self._channels is not None:
            win = win[self._channels]
        blk = np.asarray(win, dtype=np.float32)  # mmap → RAM only here
        if self.center:
            blk = blk - blk.mean(axis=1, keepdims=True)
        return blk


# -- trace capture + deterministic replay (serve.slo load tests) ------------


class RecordingSource:
    """Transparent tap over any ``SignalSource``: every block the wrapped
    source serves is captured (in served order, as f32 copies), and the
    exhaustion point is remembered — the raw material of a ``.npz`` trace
    (``save_recording``) that replays as a deterministic load test.

    Everything else (``position``/``seek``/``true_mixing``/``n_samples``/
    retry counters/...) delegates to the wrapped source via ``__getattr__``,
    so ``hasattr`` probes see exactly the inner source's capabilities and
    the wrapper is invisible to the serving engine."""

    def __init__(self, inner):
        self.inner = inner
        self._blocks: List[np.ndarray] = []
        self.exhausted = False

    def next_block(self, n_samples: int) -> np.ndarray:
        try:
            blk = self.inner.next_block(n_samples)
        except SourceExhausted:
            self.exhausted = True
            raise
        blk = np.asarray(blk, dtype=np.float32)
        self._blocks.append(blk.copy())
        return blk

    @property
    def blocks(self) -> List[np.ndarray]:
        """Captured ``(m, P)`` blocks, in served order (copies)."""
        return list(self._blocks)

    def __getattr__(self, name):
        # only reached when normal lookup fails → pure delegation
        return getattr(self.inner, name)


class RecordedSource:
    """Blocks captured by a ``RecordingSource``, served back verbatim.

    Serves the stacked ``(k, m, P)`` blocks in recorded order and raises
    ``SourceExhausted`` past the end — the replayed session drains exactly
    where the recording stopped.  Deliberately exposes NO ``seek``/cursor:
    a replay is faithful to the *served block sequence*, not to the wrapped
    source's sample clock (probe-time seek-ahead was already resolved into
    the recorded blocks at capture time)."""

    _what = "recorded trace"

    def __init__(self, blocks: np.ndarray, exhausted: bool = True):
        blocks = np.asarray(blocks, dtype=np.float32)
        if blocks.ndim != 3 and blocks.size:
            raise ValueError(
                f"blocks must be (k, m, P), got shape {blocks.shape}"
            )
        self._blocks = blocks
        self.exhausted = bool(exhausted)
        self._i = 0

    @property
    def n_blocks(self) -> int:
        return int(self._blocks.shape[0]) if self._blocks.size else 0

    @property
    def n_channels(self) -> int:
        return int(self._blocks.shape[1]) if self._blocks.size else 0

    def next_block(self, n_samples: int) -> np.ndarray:
        if self._i >= self.n_blocks:
            raise SourceExhausted(
                f"{self._what} drained: {self.n_blocks} recorded blocks served"
            )
        blk = self._blocks[self._i]
        if blk.shape[1] != n_samples:
            raise ValueError(
                f"recorded block {self._i} is {blk.shape[1]} samples wide; "
                f"{n_samples} requested (replay must use the recorded P)"
            )
        self._i += 1
        return blk.copy()


@dataclasses.dataclass
class Recording:
    """A loaded ``.npz`` trace: per-session ``RecordedSource``s (keyed by the
    recorded session ids — JSON round-tripped, so non-str/int ids come back
    stringified), the admission/eviction event stamps captured alongside
    (``[{"action": "admit"|"evict", "sid": ..., "tick": ...}, ...]``), and
    free-form metadata (bank geometry, seed, ...) for the harness that
    replays it."""

    sources: Dict[Hashable, RecordedSource]
    events: List[Dict]
    meta: Dict


def save_recording(
    path,
    sources: Dict[Hashable, RecordingSource],
    events: Optional[List[Dict]] = None,
    meta: Optional[Dict] = None,
) -> None:
    """Persist captured blocks + event stamps to one compressed ``.npz``.

    ``sources`` maps session id → its ``RecordingSource`` tap; ``events`` is
    the admission/eviction log (JSON-able dicts with at least ``action``/
    ``sid``/``tick`` — ``serve.slo.replay`` re-admits from the ``admit``
    entries); ``meta`` is free-form JSON-able context.  The manifest rides as
    a uint8 JSON leaf, so one file carries arrays and bookkeeping together."""
    arrays = {}
    manifest: Dict = {
        "version": 1,
        "sessions": [],
        "events": list(events or []),
        "meta": dict(meta or {}),
    }
    for i, (sid, rec) in enumerate(sources.items()):
        blocks = (
            np.stack(rec.blocks).astype(np.float32)
            if rec.blocks
            else np.zeros((0, 0, 0), dtype=np.float32)
        )
        key = f"blocks_{i}"
        arrays[key] = blocks
        manifest["sessions"].append(
            {
                "sid": sid,
                "key": key,
                "exhausted": bool(getattr(rec, "exhausted", True)),
                "n_blocks": int(blocks.shape[0]),
            }
        )
    arrays["manifest"] = np.frombuffer(
        json.dumps(manifest, default=str).encode("utf-8"), dtype=np.uint8
    )
    np.savez_compressed(path, **arrays)


def load_recording(path) -> Recording:
    """Load a ``save_recording`` trace back as replayable sources + events."""
    with np.load(path) as z:
        if "manifest" not in z:
            raise ValueError(f"{path}: not a recording (no manifest leaf)")
        manifest = json.loads(bytes(z["manifest"]).decode("utf-8"))
        srcs: Dict[Hashable, RecordedSource] = {}
        for s in manifest["sessions"]:
            srcs[s["sid"]] = RecordedSource(
                z[s["key"]], exhausted=s.get("exhausted", True)
            )
    return Recording(
        sources=srcs,
        events=list(manifest.get("events") or []),
        meta=dict(manifest.get("meta") or {}),
    )
