"""Fault-tolerant distributed trainer.

Scale features (the 1000+-node story, all exercised by tests/examples):
  * auto-resume: restores the newest complete checkpoint (params + optimizer
    state + step) and continues the exact data stream (step-derived batches),
  * async checkpointing every ``ckpt_every`` steps + SIGTERM preemption flush,
  * NaN guard: a non-finite loss aborts the step, restores the last good
    checkpoint and re-enters the loop (bad-node / bad-batch containment),
  * straggler telemetry: per-step wall times; steps slower than
    ``straggler_factor ×`` rolling median are flagged to the metrics log —
    at fleet scale this feeds the restart/drain decision,
  * metrics JSONL (one line per step — cheap to ship to a dashboard),
  * microbatched gradient accumulation with the paper's SMBGD β-weighting
    (``repro.train.microbatch``) — the paper's Eq. 1 IS the accumulation rule.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer, install_preemption_hook
from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim.base import GradientTransformation, apply_updates
from repro.train.microbatch import smbgd_accumulate_grads

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 2.0
    microbatches: int = 1
    smbgd_beta: float = 1.0  # β-weighting across microbatches (Eq. 1)
    nan_guard: bool = True
    metrics_path: Optional[str] = None


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tx: GradientTransformation,
        tcfg: TrainerConfig,
        mesh=None,
        param_shardings=None,
    ):
        self.cfg = cfg
        self.tx = tx
        self.tcfg = tcfg
        self.mesh = mesh
        self.param_shardings = param_shardings
        self.ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep)
        self._step_times: list = []
        self._metrics_f = (
            open(tcfg.metrics_path, "a") if tcfg.metrics_path else None
        )
        self._build_step()

    # -- jitted step ----------------------------------------------------------

    def _build_step(self):
        cfg, tx = self.cfg, self.tx
        mb, beta = self.tcfg.microbatches, self.tcfg.smbgd_beta

        def step_fn(params, opt_state, batch):
            if mb > 1:
                grads, loss = smbgd_accumulate_grads(
                    lambda p, b: M.loss_fn(p, b, cfg), params, batch, mb, beta
                )
            else:
                (loss, _), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(
                    params, batch, cfg
                )
            updates, opt_state = tx.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, opt_state, loss

        kwargs = {}
        if self.mesh is not None and self.param_shardings is not None:
            kwargs = dict(
                in_shardings=(self.param_shardings, None, None),
                out_shardings=(self.param_shardings, None, None),
            )
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1), **kwargs)

    # -- lifecycle --------------------------------------------------------------

    def init_state(self, key: jax.Array) -> Tuple[PyTree, PyTree, int]:
        params = M.init_params(key, self.cfg)
        opt_state = self.tx.init(params)
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            (params, opt_state), _ = self.ckpt.restore((params, opt_state), latest)
            start = latest + 1
        return params, opt_state, start

    def _log(self, step: int, payload: Dict[str, Any]) -> None:
        payload = {"step": step, **payload}
        if self._metrics_f:
            self._metrics_f.write(json.dumps(payload) + "\n")
            self._metrics_f.flush()

    # -- main loop --------------------------------------------------------------

    def fit(
        self,
        key: jax.Array,
        pipeline,
        n_steps: int,
        on_step: Optional[Callable[[int, float], None]] = None,
    ) -> Tuple[PyTree, PyTree, list]:
        params, opt_state, start = self.init_state(key)
        install_preemption_hook(
            lambda: self.ckpt.save(self._last_step, (params, opt_state))
        )
        losses = []
        self._last_step = start
        last_good = start - 1
        step = start
        while step < n_steps:
            batch = pipeline.batch_for_step(step)
            t0 = time.time()
            params, opt_state, loss = self.step_fn(params, opt_state, batch)
            loss = float(loss)
            dt = time.time() - t0
            self._step_times.append(dt)
            self._last_step = step

            if self.tcfg.nan_guard and not math.isfinite(loss):
                # bad step: restore last good checkpoint and continue after it
                self._log(step, {"event": "nan_guard", "loss": loss})
                latest = self.ckpt.latest_step()
                if latest is None:
                    raise FloatingPointError(f"non-finite loss at step {step}, no ckpt")
                self.ckpt.wait()
                (params, opt_state), _ = self.ckpt.restore(
                    jax.tree.map(lambda x: x, (params, opt_state)), latest
                )
                step = latest + 1
                continue

            losses.append(loss)
            if len(self._step_times) >= 8:
                med = sorted(self._step_times[-32:])[len(self._step_times[-32:]) // 2]
                if dt > self.tcfg.straggler_factor * med:
                    self._log(step, {"event": "straggler", "dt": dt, "median": med})
            if step % self.tcfg.log_every == 0:
                self._log(step, {"loss": loss, "dt": dt})
            if on_step:
                on_step(step, loss)
            if step % self.tcfg.ckpt_every == 0 and step > start:
                self.ckpt.save_async(step, (params, opt_state))
                last_good = step
            step += 1

        self.ckpt.wait()
        self.ckpt.save(n_steps - 1, (params, opt_state))
        return params, opt_state, losses
