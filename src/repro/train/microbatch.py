"""Microbatched gradient accumulation with the paper's SMBGD β-weighting.

This is Eq. 1 applied to generic training: the global batch is split into P
microbatches processed sequentially **with frozen params** (exactly the
paper's frozen-B semantics); per-microbatch gradients are folded with
exponentially decaying weights

    G = Σ_p β^{P-1-p} · g_p          (μ and γ applied by the optimizer)

With β=1 this is plain gradient accumulation (mean up to scale); β<1
accentuates recent microbatches — the paper's adaptivity argument.  Runs as a
``lax.scan`` so peak memory is one microbatch's activations, the standard
large-model memory trick — i.e. the paper's FPGA resource-sharing story maps
to activation-memory sharing on TPU.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def split_batch(batch: PyTree, n: int) -> PyTree:
    """(B, ...) → (n, B/n, ...) for every leaf."""

    def one(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])

    return jax.tree.map(one, batch)


def smbgd_accumulate_grads(
    loss_fn: Callable[[PyTree, PyTree], Tuple[jnp.ndarray, Any]],
    params: PyTree,
    batch: PyTree,
    microbatches: int,
    beta: float = 1.0,
) -> Tuple[PyTree, jnp.ndarray]:
    """Returns (accumulated grads, mean loss).  ``loss_fn(params, mb) ->
    (loss, aux)``.  Sequential fold: G ← β·G + g_p (≡ Σ β^{P-1-p} g_p)."""
    mbs = split_batch(batch, microbatches)
    vg = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])

    def body(carry, mb):
        acc, loss_sum = carry
        l, g = vg(params, mb)
        acc = jax.tree.map(lambda a, gi: beta * a + gi, acc, g)
        return (acc, loss_sum + l), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss_sum), _ = jax.lax.scan(
        body, (zeros, jnp.zeros((), jnp.float32)), mbs
    )
    # normalize so the effective step size is β-independent at β→1
    norm = sum(beta**i for i in range(microbatches))
    grads = jax.tree.map(lambda g: g / norm, grads)
    return grads, loss_sum / microbatches
