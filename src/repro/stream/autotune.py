"""Persisted 2-D autotune cache for the bank megakernel.

``benchmarks/stream_throughput.py --autotune`` sweeps the megakernel's
``(block_p, block_s)`` tile geometry (also toggling ``prefetch`` and the
``dtype_policy``) and persists the winning config here, keyed by the problem
shape and backend:

    "S=64,P=32,m=4,n=2,backend=cpu-interpret": {
        "block_p": 32, "block_s": 64, "prefetch": false,
        "fused_tick_s": ...,            # measured, f32 policy
        "bf16_fused_tick_s": ...,       # same geometry, bf16 storage
        "persistent_bytes_per_session": 1032,
        "bf16_persistent_bytes_per_session": 520,
        "tuned_at": "2026-08-07T..."
    }

``SeparatorBank`` consults the cache by default (``autotune=True``) for any
GEOMETRY knob left unset — ``block_p``, ``block_s``, ``prefetch`` — so a
tuned deployment gets the swept tiling without threading numbers by hand.
``dtype_policy`` is recorded but NEVER auto-applied: storage precision
changes results (within tested tolerance, but still), so it stays an
explicit caller decision.

The cache file defaults to ``AUTOTUNE.json`` at the repo root (checked in;
CI's ``--autotune-smoke`` gate keeps it fresh) and can be pointed elsewhere
with ``REPRO_AUTOTUNE_CACHE``.  All lookups are best-effort: a missing or
corrupt cache silently falls back to the derived defaults — tuning is a perf
knob, never a correctness dependency.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, Optional

import jax

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_DEFAULT_PATH = Path(__file__).resolve().parents[3] / "AUTOTUNE.json"

# knobs SeparatorBank may adopt from a cache hit (never dtype_policy)
GEOMETRY_KEYS = ("block_p", "block_s", "prefetch")

# (path, mtime) -> parsed cache; re-read only when the file changes
_memo: Dict[tuple, dict] = {}


def cache_path(path: Optional[str] = None) -> Path:
    if path is not None:
        return Path(path)
    env = os.environ.get(CACHE_ENV)
    return Path(env) if env else _DEFAULT_PATH


def backend_tag(interpret: Optional[bool] = None) -> str:
    """Backend half of the cache key: tuned numbers never steer a different
    lowering (interpret-mode timings are meaningless on real TPU)."""
    if interpret is None:
        interpret = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"
    return f"{jax.default_backend()}{'-interpret' if interpret else ''}"


def cache_key(
    S: int, P: int, m: int, n: int, backend: Optional[str] = None
) -> str:
    if backend is None:
        backend = backend_tag()
    return f"S={S},P={P},m={m},n={n},backend={backend}"


def load_cache(path: Optional[str] = None) -> dict:
    """Parsed cache file (``{}`` when absent/corrupt), memoized on mtime."""
    p = cache_path(path)
    try:
        mtime = p.stat().st_mtime_ns
    except OSError:
        return {}
    memo_key = (str(p), mtime)
    got = _memo.get(memo_key)
    if got is None:
        try:
            got = json.loads(p.read_text())
            if not isinstance(got, dict):
                got = {}
        except (OSError, ValueError):
            got = {}
        _memo.clear()  # one live entry per path is plenty
        _memo[memo_key] = got
    return got


def lookup(
    S: int,
    P: int,
    m: int,
    n: int,
    *,
    interpret: Optional[bool] = None,
    path: Optional[str] = None,
) -> Optional[dict]:
    """The cached entry for this shape on this backend, or None."""
    entry = load_cache(path).get(cache_key(S, P, m, n, backend_tag(interpret)))
    return entry if isinstance(entry, dict) else None


def store(
    S: int,
    P: int,
    m: int,
    n: int,
    entry: dict,
    *,
    interpret: Optional[bool] = None,
    path: Optional[str] = None,
) -> Path:
    """Write/overwrite one key's entry (read-modify-write of the JSON file)."""
    p = cache_path(path)
    cache = dict(load_cache(path))
    cache[cache_key(S, P, m, n, backend_tag(interpret))] = entry
    p.write_text(json.dumps(cache, indent=2, sort_keys=True) + "\n")
    _memo.clear()
    return p
