"""Device parallelism over the stream axis of a SeparatorBank.

Streams are independent sessions, so sharding the bank over devices needs no
collectives in the hot path — each device steps its local slice of the bank
with the same fused program (``shard_map`` with everything partitioned over
the stream axis).  This is the "rack of FPGAs" layout: bank state and the
incoming mini-batches live sharded; only diagnostics ever gather.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.stream.bank import BankState, SeparatorBank


def bank_sharding(mesh, axis: str = "stream") -> BankState:
    """NamedSharding pytree for a BankState: every leaf partitioned over
    ``axis`` on its leading (stream) dimension.  Feed to ``jax.device_put`` or
    ``Checkpointer.restore(shardings=...)`` for reshard-on-load."""
    return BankState(
        B=NamedSharding(mesh, P(axis)),
        H_hat=NamedSharding(mesh, P(axis)),
        step=NamedSharding(mesh, P(axis)),
    )


def make_sharded_bank_step(bank: SeparatorBank, mesh, axis: str = "stream"):
    """Build a jitted ``step(state, X[, active]) -> (state, Y)`` where the
    bank's stream axis is sharded over mesh axis ``axis``.

    Each device runs the fused bank step on its local streams; there are no
    cross-device collectives (streams are independent).  Requires
    ``bank.n_streams %% mesh.shape[axis] == 0``.
    """
    from jax.experimental.shard_map import shard_map

    n_dev = mesh.shape[axis]
    if bank.n_streams % n_dev:
        raise ValueError(
            f"n_streams {bank.n_streams} not divisible by {n_dev} devices on "
            f"axis {axis!r}"
        )

    def local_step(B, H_hat, step, X, active):
        st, Y = bank.step(BankState(B, H_hat, step), X, active=active)
        return st.B, st.H_hat, st.step, Y

    sharded = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis), P(axis)),
        check_rep=False,
    )

    @jax.jit
    def step(
        state: BankState, X: jnp.ndarray, active: Optional[jnp.ndarray] = None
    ) -> Tuple[BankState, jnp.ndarray]:
        if active is None:
            active = jnp.ones((bank.n_streams,), dtype=bool)
        B, H_hat, stp, Y = sharded(state.B, state.H_hat, state.step, X, active)
        return BankState(B, H_hat, stp), Y

    return step
