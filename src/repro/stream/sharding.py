"""Device parallelism over the stream axis of a SeparatorBank.

Streams are independent sessions, so sharding the bank over devices needs no
collectives in the hot path — each device steps its local slice of the bank
with the same fused program (``shard_map`` with everything partitioned over
the stream axis).  This is the "rack of FPGAs" layout: bank state and the
incoming mini-batches live sharded; only diagnostics ever gather.

Works for every bank flavour: the vmap paths, the PR-1 gradient-kernel path,
and the fused whole-step megakernel (``fused=True`` — persistent padded state
shards over its leading axis like any other; each device launches its own
``(local_streams, P-tiles)`` grid).  Per-stream ``BankHyperparams`` are
threaded through ``shard_map`` as explicit sharded operands (NOT closure
captures, which would silently replicate them and break the local shapes);
each device rebuilds a local-width bank around its slice.

The local banks inherit the parent's RESOLVED memory-system knobs
(``dtype_policy``, ``prefetch``, tile geometry) with ``autotune=False`` —
tuning keys on the global shape; per-device re-resolution against the
local stream count would silently pick a different (possibly untuned)
geometry on every device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.smbgd import BankHyperparams
from repro.stream.bank import BankState, SeparatorBank


def bank_sharding(mesh, axis: str = "stream") -> BankState:
    """NamedSharding pytree for a BankState: every leaf partitioned over
    ``axis`` on its leading (stream) dimension.  Feed to ``jax.device_put`` or
    ``Checkpointer.restore(shardings=...)`` for reshard-on-load.

    Expects a conv-bearing state (anything ``SeparatorBank.init`` produced);
    a legacy ``conv=None`` state has a different pytree structure — normalize
    it first with ``state._replace(conv=jnp.full((S,), jnp.inf))``."""
    return BankState(
        B=NamedSharding(mesh, P(axis)),
        H_hat=NamedSharding(mesh, P(axis)),
        step=NamedSharding(mesh, P(axis)),
        conv=NamedSharding(mesh, P(axis)),
        health=NamedSharding(mesh, P(axis)),
        moments=NamedSharding(mesh, P(axis)),
    )


def make_sharded_bank_step(
    bank: SeparatorBank, mesh, axis: str = "stream", donate: bool = False
):
    """Build a jitted ``step(state, X[, active]) -> (state, Y)`` where the
    bank's stream axis is sharded over mesh axis ``axis``.

    Each device runs the fused bank step on its local streams; there are no
    cross-device collectives (streams are independent).  Requires
    ``bank.n_streams %% mesh.shape[axis] == 0``.  ``donate=True`` donates the
    state buffers (persistent-padded fused banks: zero steady-state allocs
    per device).
    """
    from jax.experimental.shard_map import shard_map

    n_dev = mesh.shape[axis]
    if bank.n_streams % n_dev:
        raise ValueError(
            f"n_streams {bank.n_streams} not divisible by {n_dev} devices on "
            f"axis {axis!r}"
        )
    local_streams = bank.n_streams // n_dev
    # Pin the parent bank's RESOLVED geometry on the local bank: autotune ran
    # (or was opted out) against the global (S, P, m, n) key, and the local
    # bank must not re-resolve against the local-S key (different entry) or
    # re-derive block_s from local_streams vs a cached global block_s that no
    # longer divides.  dtype_policy/prefetch ride along via replace().
    local_block_s = bank.block_s
    if local_block_s is not None and local_streams % local_block_s:
        local_block_s = None  # fall back to the derived default locally
    local_bank = dataclasses.replace(
        bank,
        n_streams=local_streams,
        hyperparams=None,
        block_p=bank.layout.block_p if bank.fused else bank.block_p,
        block_s=local_block_s,
        prefetch=bool(bank.prefetch),
        autotune=False,
    )
    hetero = bank.hyperparams is not None

    def local_step(B, H_hat, step, conv, X, active, hp):
        lb = local_bank
        if hetero:
            lb = dataclasses.replace(lb, hyperparams=BankHyperparams(*hp))
        st, Y = lb.step(BankState(B, H_hat, step, conv), X, active=active)
        return st.B, st.H_hat, st.step, st.conv, st.health, st.moments, Y

    hp_spec = (P(axis),) * 3 if hetero else ()
    sharded = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis), hp_spec),
        out_specs=(P(axis),) * 7,
        check_rep=False,
    )

    def step(
        state: BankState, X: jnp.ndarray, active: Optional[jnp.ndarray] = None
    ) -> Tuple[BankState, jnp.ndarray]:
        if active is None:
            active = jnp.ones((bank.n_streams,), dtype=bool)
        hp = tuple(bank.hyperparams) if hetero else ()
        conv = state.conv
        if conv is None:  # legacy states: normalize before entering shard_map
            conv = jnp.full((bank.n_streams,), jnp.inf, jnp.float32)
        B, H_hat, stp, conv, health, moments, Y = sharded(
            state.B, state.H_hat, state.step, conv, X, active, hp
        )
        return BankState(B, H_hat, stp, conv, health, moments), Y

    return jax.jit(step, donate_argnums=(0,) if donate else ())
