"""Single-stream separator front-end: one API over the three epoch drivers.

Historically the repo exposed three parallel single-stream drivers
(``easi_sgd_scan``, ``smbgd_epoch``, ``smbgd_epoch_sequential``); ``Separator``
collapses them behind an ``algorithm`` config knob:

  * ``"sgd"``              — vanilla per-sample EASI (the paper's Table I
                             baseline; serial ``lax.scan``),
  * ``"smbgd_sequential"`` — literal Eq. 1 per-sample recurrence inside each
                             mini-batch (the FPGA-semantics equivalence
                             oracle),
  * ``"smbgd_batched"``    — the closed-form MXU step (production path;
                             ``use_pallas=True`` routes the gradient sum
                             through the fused Pallas kernel).

``"smbgd"`` is accepted as an alias of ``"smbgd_batched"`` for backwards
compatibility (``repro.core.ica.AdaptiveICA`` is now a thin subclass).

All methods are pure (state in / state out) over ``SMBGDState`` so they drop
into jit/scan/vmap — ``repro.stream.bank.SeparatorBank`` is literally this
class vmapped over a leading stream axis.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import easi as easi_lib
from repro.core import metrics as metrics_lib
from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig, SMBGDState

ALGORITHMS = ("sgd", "smbgd_sequential", "smbgd_batched")
_ALIASES = {"smbgd": "smbgd_batched"}


@dataclasses.dataclass(frozen=True)
class Separator:
    easi: EASIConfig
    opt: SMBGDConfig
    algorithm: str = "smbgd_batched"
    use_pallas: bool = False

    def __post_init__(self) -> None:
        canon = _ALIASES.get(self.algorithm, self.algorithm)
        if canon not in ALGORITHMS:
            raise ValueError(
                f"unknown algorithm {self.algorithm!r}; "
                f"available: {ALGORITHMS} (alias: {sorted(_ALIASES)})"
            )
        object.__setattr__(self, "algorithm", canon)

    def init(self, key: jax.Array) -> SMBGDState:
        return smbgd_lib.init_state(self.easi, key)

    # -- training ---------------------------------------------------------
    def epoch(self, state: SMBGDState, X: jnp.ndarray) -> Tuple[SMBGDState, jnp.ndarray]:
        """One pass over ``X (T, m)``; returns updated state and outputs."""
        if self.algorithm == "sgd":
            B, Y = easi_lib.easi_sgd_scan(state.B, X, self.easi)
            return state._replace(B=B, step=state.step + X.shape[0]), Y
        if self.algorithm == "smbgd_sequential":
            return smbgd_lib.smbgd_epoch_sequential(state, X, self.easi, self.opt)
        return smbgd_lib.smbgd_epoch(
            state, X, self.easi, self.opt, use_pallas=self.use_pallas
        )

    def step(
        self, state: SMBGDState, X_batch: jnp.ndarray
    ) -> Tuple[SMBGDState, jnp.ndarray]:
        """One mini-batch update (streaming deployment; tracks drift)."""
        if self.algorithm == "sgd":
            B, Y = easi_lib.easi_sgd_scan(state.B, X_batch, self.easi)
            return state._replace(B=B, step=state.step + X_batch.shape[0]), Y
        if self.algorithm == "smbgd_sequential":
            return smbgd_lib.smbgd_sequential_step(state, X_batch, self.easi, self.opt)
        return smbgd_lib.smbgd_batched_step(
            state, X_batch, self.easi, self.opt, use_pallas=self.use_pallas
        )

    # back-compat method names (the old AdaptiveICA estimator API)
    def fit(self, state: SMBGDState, X: jnp.ndarray):
        return self.epoch(state, X)

    def partial_fit(self, state: SMBGDState, X_batch: jnp.ndarray):
        return self.step(state, X_batch)

    # -- deployment --------------------------------------------------------
    def transform(self, state: SMBGDState, X: jnp.ndarray) -> jnp.ndarray:
        return easi_lib.transform(state.B, X)

    # -- diagnostics -------------------------------------------------------
    def performance_index(self, state: SMBGDState, A: jnp.ndarray) -> jnp.ndarray:
        return metrics_lib.amari_index(metrics_lib.global_system(state.B, A))
