"""Multi-stream separation: banks of independent EASI/SMBGD sessions stepped
as one array program.

The paper's SMBGD freezes ``B`` inside a mini-batch so the datapath has no
loop-carried dependency; this package exploits the same property across
*sessions*: S independent separators are carried as one batched state
(leading stream axis) and stepped by one fused program — ``vmap``-native math
on CPU/GPU/TPU, a batched ``(streams, P-tiles)`` Pallas kernel on the fused
path, and ``shard_map`` over the stream axis to scale banks across devices.

Public API:
  * ``Separator``       — single-stream front-end; ``algorithm`` knob collapses
                          the three historical epoch drivers
                          (``sgd | smbgd_sequential | smbgd_batched``).
  * ``SeparatorBank``   — S-stream bank; same algorithms, batched state.
                          ``fused=True`` runs the whole-step Pallas megakernel
                          on persistent padded state (``bank.layout``);
                          ``hyperparams=BankHyperparams(...)`` makes the bank
                          heterogeneous (per-stream μ, β, γ in one launch).
  * ``BankState``       — ``B (S, n, m)``, ``H_hat (S, n, n)``, ``step (S,)``
                          (padded shapes on the fused path).
  * ``BankHyperparams`` — per-stream ``(S,)`` SMBGD hyper-parameter arrays.
  * ``make_sharded_bank_step`` / ``bank_sharding`` — stream-axis device
    parallelism (streams are independent: no collectives in the hot path).

Pallas kernels run through the interpreter by default so the CPU container can
execute them; set ``REPRO_PALLAS_INTERPRET=0`` on real TPU hardware.
"""
from repro.core.smbgd import BankHyperparams
from repro.stream.bank import BankState, SeparatorBank
from repro.stream.separator import ALGORITHMS, Separator
from repro.stream.sharding import bank_sharding, make_sharded_bank_step

__all__ = [
    "ALGORITHMS",
    "BankHyperparams",
    "BankState",
    "Separator",
    "SeparatorBank",
    "bank_sharding",
    "make_sharded_bank_step",
]
