"""SeparatorBank: S independent separator sessions as one batched program.

State carries a leading stream axis — ``B (S, n, m)``, ``H_hat (S, n, n)``,
``step (S,)`` — and every step is one fused array program:

  * non-Pallas paths are the single-stream step functions ``jax.vmap``-ed over
    the stream axis (op-for-op the same math, so a bank of S streams matches S
    independent runs to float tolerance),
  * the Pallas path routes the weighted gradient sum of ALL streams through
    one ``(streams, P-tiles)`` grid launch of the fused EASI-gradient kernel
    (``kernels.easi_gradient.ops.easi_gradient_bank``) — S kernel dispatches
    collapse into one.

Per-stream ``step`` counters make the bank admission-friendly: a freshly
admitted stream has ``step == 0`` and its first mini-batch gates γ off (the
paper's first-batch rule) regardless of what the other streams are doing.
``step(..., active=mask)`` freezes masked-out slots entirely — the
continuous-batching hook used by ``serve.engine.SeparationService``.

Checkpointing: ``BankState`` is a plain pytree of arrays, so
``checkpoint.Checkpointer`` round-trips it unmodified (tested).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import metrics as metrics_lib
from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig, SMBGDState
from repro.stream.separator import Separator


class BankState(NamedTuple):
    """Batched carry for S separator sessions (leading stream axis)."""

    B: jnp.ndarray  # (S, n, m)
    H_hat: jnp.ndarray  # (S, n, n)
    step: jnp.ndarray  # (S,) int32 — per-stream mini-batch counter


@dataclasses.dataclass(frozen=True)
class SeparatorBank:
    """S-stream separation engine; same ``algorithm`` knob as ``Separator``."""

    easi: EASIConfig
    opt: SMBGDConfig
    n_streams: int
    algorithm: str = "smbgd_batched"
    use_pallas: bool = False

    def __post_init__(self) -> None:
        if self.n_streams < 1:
            raise ValueError("n_streams must be >= 1")
        # reuse Separator's alias resolution + validation
        sep = Separator(self.easi, self.opt, self.algorithm, self.use_pallas)
        object.__setattr__(self, "algorithm", sep.algorithm)

    @property
    def _sep(self) -> Separator:
        return Separator(self.easi, self.opt, self.algorithm, self.use_pallas)

    # -- state ------------------------------------------------------------
    def init(self, key: jax.Array) -> BankState:
        """Independent per-stream inits from ``jax.random.split(key, S)`` —
        stream s's state equals ``Separator.init(split_keys[s])`` exactly."""
        keys = jax.random.split(key, self.n_streams)
        sub = jax.vmap(lambda k: smbgd_lib.init_state(self.easi, k))(keys)
        return BankState(B=sub.B, H_hat=sub.H_hat, step=sub.step)

    def init_slot(self, state: BankState, slot, key: jax.Array) -> BankState:
        """Reset one stream slot to a fresh session (admission path)."""
        sub = smbgd_lib.init_state(self.easi, key)
        return BankState(
            B=state.B.at[slot].set(sub.B),
            H_hat=state.H_hat.at[slot].set(sub.H_hat),
            step=state.step.at[slot].set(sub.step),
        )

    @staticmethod
    def slot_state(state: BankState, slot: int) -> SMBGDState:
        """Extract one stream's state as a single-stream ``SMBGDState``."""
        return SMBGDState(
            B=state.B[slot], H_hat=state.H_hat[slot], step=state.step[slot]
        )

    @staticmethod
    def stack_states(states) -> BankState:
        """Stack S single-stream ``SMBGDState``s into a ``BankState``."""
        return BankState(
            B=jnp.stack([s.B for s in states]),
            H_hat=jnp.stack([s.H_hat for s in states]),
            step=jnp.stack([s.step for s in states]),
        )

    # -- stepping ----------------------------------------------------------
    def step(
        self,
        state: BankState,
        X: jnp.ndarray,
        active: Optional[jnp.ndarray] = None,
    ) -> Tuple[BankState, jnp.ndarray]:
        """One fused mini-batch update for all streams.

        ``X (S, P, m)`` → ``Y (S, P, n)``.  ``active (S,)`` bool (optional)
        freezes masked-out slots: their state is returned unchanged (their Y
        rows are still computed — garbage-in/garbage-out for free slots).
        """
        new_state, Y = self._step_all(state, X)
        if active is not None:
            a3 = active[:, None, None]
            new_state = BankState(
                B=jnp.where(a3, new_state.B, state.B),
                H_hat=jnp.where(a3, new_state.H_hat, state.H_hat),
                step=jnp.where(active, new_state.step, state.step),
            )
        return new_state, Y

    def _step_all(self, state: BankState, X: jnp.ndarray):
        if self.algorithm == "smbgd_batched" and self.use_pallas:
            return self._step_pallas(state, X)
        sep = self._sep
        sub = SMBGDState(B=state.B, H_hat=state.H_hat, step=state.step)
        new_sub, Y = jax.vmap(sep.step)(sub, X)
        return BankState(B=new_sub.B, H_hat=new_sub.H_hat, step=new_sub.step), Y

    def _step_pallas(self, state: BankState, X: jnp.ndarray):
        """Closed-form SMBGD step with the gradient sum of all S streams fused
        into one (streams, P-tiles) Pallas launch."""
        from repro.kernels.easi_gradient import ops as easi_ops

        B, H_prev = state.B, state.H_hat
        Y = jnp.einsum("spm,snm->spn", X, B)  # per-stream Y = X Bᵀ
        w = self.opt.within_batch_weights(dtype=B.dtype)
        S_grad = easi_ops.easi_gradient_bank(
            Y, w, nonlinearity=self.easi.nonlinearity
        )
        H_hat, B_next = smbgd_lib.smbgd_commit(
            state.step, H_prev, S_grad, B, self.opt
        )
        return BankState(B=B_next, H_hat=H_hat, step=state.step + 1), Y

    def epoch(
        self, state: BankState, X: jnp.ndarray
    ) -> Tuple[BankState, jnp.ndarray]:
        """One pass over ``X (S, T, m)`` for every stream; returns
        ``(state, Y (S, T', n))`` with T' = K·P (SMBGD) or T (SGD)."""
        if self.algorithm == "sgd":
            sep = self._sep
            sub = SMBGDState(B=state.B, H_hat=state.H_hat, step=state.step)
            new_sub, Y = jax.vmap(sep.epoch)(sub, X)
            return BankState(new_sub.B, new_sub.H_hat, new_sub.step), Y
        S, T, m = X.shape
        P = self.opt.batch_size
        K = T // P
        Xb = X[:, : K * P].reshape(S, K, P, m).transpose(1, 0, 2, 3)  # (K, S, P, m)

        def body(st, xb):
            return self._step_all(st, xb)

        state, Yb = jax.lax.scan(body, state, Xb)  # Yb (K, S, P, n)
        return state, Yb.transpose(1, 0, 2, 3).reshape(S, K * P, -1)

    # -- deployment / diagnostics -----------------------------------------
    def transform(self, state: BankState, X: jnp.ndarray) -> jnp.ndarray:
        """Per-stream separation: ``X (S, ..., m)`` → ``Y (S, ..., n)``."""
        return jnp.einsum("s...m,snm->s...n", X, state.B)

    def performance_index(self, state: BankState, A: jnp.ndarray) -> jnp.ndarray:
        """Per-stream Amari index against mixing ``A (m, n)`` or ``(S, m, n)``."""
        if A.ndim == 2:
            A = jnp.broadcast_to(A, (self.n_streams,) + A.shape)
        gs = jax.vmap(metrics_lib.global_system)(state.B, A)
        return jax.vmap(metrics_lib.amari_index)(gs)
