"""SeparatorBank: S independent separator sessions as one batched program.

State carries a leading stream axis — ``B (S, n, m)``, ``H_hat (S, n, n)``,
``step (S,)`` — and every step is one fused array program:

  * non-Pallas paths are the single-stream step functions ``jax.vmap``-ed over
    the stream axis (op-for-op the same math, so a bank of S streams matches S
    independent runs to float tolerance),
  * the Pallas path routes the weighted gradient sum of ALL streams through
    one ``(streams, P-tiles)`` grid launch of the fused EASI-gradient kernel
    (``kernels.easi_gradient.ops.easi_gradient_bank``) — S kernel dispatches
    collapse into one,
  * ``fused=True`` goes further: the WHOLE step (``Y = X Bᵀ``, nonlinearity,
    weighted gradient sum, SMBGD commit) is one ``(streams, P-tiles)``
    megakernel launch (``ops.smbgd_step_bank``) on **persistent padded
    state**: ``init`` establishes a lane-aligned layout once (``bank.layout``)
    and every tick runs at padded shapes — pad/unpad happen only at the API
    boundary (admission, eviction, diagnostics, ``unpad_state``/``unpad_y``).
    Pair with ``make_step(donate=True)`` and steady-state serving allocates
    nothing: state buffers are donated back to the kernel's outputs and a
    block-aligned ``X`` (see ``pad_batch``/``SeparationService``) skips every
    staging copy.

Heterogeneous banks: ``hyperparams=BankHyperparams(mu, beta, gamma)`` carries
per-stream ``(S,)`` step sizes/decays/momenta (the arXiv:1710.05384 sweep) —
the fused path feeds them to the megakernel as per-stream weight rows; the
non-fused path falls back to an equivalent vmap program.

Per-stream ``step`` counters make the bank admission-friendly: a freshly
admitted stream has ``step == 0`` and its first mini-batch gates γ off (the
paper's first-batch rule) regardless of what the other streams are doing.
``step(..., active=mask)`` freezes masked-out slots entirely — the
continuous-batching hook used by ``serve.engine.SeparationService``; the
megakernel applies the mask in-register at commit time.

Convergence statistics: every step path also produces ``BankState.conv`` —
the per-stream relative update magnitude ``‖ΔB‖_F/‖B‖_F`` of the committed
tick (identical formula in the megakernel, the PR-1 Pallas path, the vmap
path and the hetero-vmap fallback, matching the ref oracle).  The fused path
computes it in-register from the commit's own ``Ĥ′B`` product, so the serving
layer's eviction policy (``serve.ConvergencePolicy``) reads an (S,)-float
side channel per tick instead of pulling ``B``/``Ĥ`` back to the host.
``probe``/``make_probe`` expose the statistic WITHOUT the commit — the
no-mutation probe mode the serving layer's batched drift watchdog runs over
transient banks of parked (frozen) separators (``stack_states`` +
``unstack_states`` are the in/out ramps).

Memory system (PR 6): ``dtype_policy="bf16"`` stores the persistent
``B``/``Ĥ`` in bf16 — the kernels (and the vmap fallbacks) still run the
gradient fold and the commit accumulation in f32, casting only at the
load/commit boundaries, so separation quality tracks the f32 oracle within
a tested tolerance while the per-session HBM footprint halves (the
capacity number: ``bank.layout.persistent_bytes_per_session``).
``prefetch=True`` double-buffers the megakernel's X tile DMA (bit-identical
on the interpret path).  Both knobs — plus ``block_p``/``block_s`` — load
from the persisted autotune cache (``stream.autotune``, ``AUTOTUNE.json``)
when left unset; ``autotune=False`` opts out.  ``dtype_policy`` is never
auto-applied from the cache (precision is a caller decision).

Checkpointing: ``BankState`` is a plain pytree of arrays (padded or not), so
``checkpoint.Checkpointer`` round-trips it unmodified — bf16 banks
checkpoint and restore at the storage dtype (tested).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import metrics as metrics_lib
from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import BankHyperparams, SMBGDConfig, SMBGDState
from repro.stream.separator import Separator


class BankState(NamedTuple):
    """Batched carry for S separator sessions (leading stream axis).

    Shapes are logical — ``B (S, n, m)``, ``H_hat (S, n, n)`` — for the vmap
    paths, or persistent-padded — ``B (S, n_pad, m_pad)``, ``H_hat (S, n_pad,
    n_pad)`` per ``SeparatorBank.layout`` — for the fused megakernel path.

    ``conv`` is the per-stream convergence statistic of the last committed
    tick — the relative update magnitude ``‖ΔB‖_F/‖B‖_F`` (see
    ``core.metrics.update_magnitude``), +inf for never-stepped streams.  It is
    produced *inside* every step path (the megakernel folds it in-register at
    commit time — no extra HBM round-trip), frozen with the rest of the slot
    under the active mask, and checkpoints/shards like any other leaf.
    ``conv=None`` (the default, for states built by legacy callers) is
    normalized to +inf on the first step.

    ``health`` is the per-stream fault bitmask of the last tick (see
    ``kernels.easi_gradient.ops.HEALTH_*``): 0 = the commit landed (or the
    slot was frozen), any set bit = the commit was REFUSED because the update
    went non-finite or blew past the static bound — the slot kept its
    pre-tick state and the serving layer decides rollback/quarantine.  It is
    a fresh per-tick verdict, not a carried statistic; ``health=None``
    (legacy states) normalizes to all-healthy zeros.

    ``moments`` is the per-stream raw [Σy², Σy⁴] fold of the last tick's Y
    (the in-kernel kurtosis telemetry; see
    ``kernels.easi_gradient.ops.MOMENT_TICK_BYTES_PER_STREAM``): zeros when
    the bank's ``moments`` flag is off, for frozen slots, and for legacy
    states (``moments=None`` normalizes like ``health``).  Like ``health``
    it is a fresh per-tick observation — the serving layer's
    ``MomentController`` turns it into an EMA kurtosis estimate and an
    adaptive μ scale; nothing in the bank ever reads it back.
    """

    B: jnp.ndarray  # (S, n, m) or (S, n_pad, m_pad)
    H_hat: jnp.ndarray  # (S, n, n) or (S, n_pad, n_pad)
    step: jnp.ndarray  # (S,) int32 — per-stream mini-batch counter
    conv: Optional[jnp.ndarray] = None  # (S,) f32 — last-tick ‖ΔB‖_F/‖B‖_F
    health: Optional[jnp.ndarray] = None  # (S,) int32 — last-tick fault bits
    moments: Optional[jnp.ndarray] = None  # (S, 2) f32 — last-tick [Σy², Σy⁴]


# -- fused row-op programs --------------------------------------------------
# Slot admission/compaction/resize each touch all six state leaves.  Run
# eagerly that is ~50 op dispatches per call (≈10 ms of pure host overhead) —
# the dominant cost of an elastic resize tick, which may activate several
# sessions at once.  Fused under jit each becomes ONE cached program.  They
# are module-level (not per-bank closures) so the jit cache keys on leaf
# shapes alone and every bank instance of the same geometry — including the
# fresh instance a resize creates via ``with_streams`` — shares the programs
# a ``prewarm`` already compiled.


@jax.jit
def _row_write_jit(B, H, step, conv, health, moments, slot, subB, subH, substep):
    """Write one logical sub-state into row ``slot``; conv/health/moments
    restart (+inf / 0 / 0).  On padded leaves the whole row is cleared and
    the logical block corner-written, so no stale junk survives."""
    if B.shape[1:] != subB.shape:  # persistent-padded bank
        rowB = (
            jnp.zeros(B.shape[1:], B.dtype)
            .at[: subB.shape[0], : subB.shape[1]]
            .set(subB.astype(B.dtype))
        )
        rowH = (
            jnp.zeros(H.shape[1:], H.dtype)
            .at[: subH.shape[0], : subH.shape[1]]
            .set(subH.astype(H.dtype))
        )
    else:
        rowB = subB.astype(B.dtype)
        rowH = subH.astype(H.dtype)
    return (
        B.at[slot].set(rowB),
        H.at[slot].set(rowH),
        step.at[slot].set(substep),
        conv.at[slot].set(jnp.inf),
        health.at[slot].set(0),
        moments.at[slot].set(0.0),
    )


@functools.partial(jax.jit, static_argnums=0)  # frozen config → hashable
def _init_state_jit(cfg: EASIConfig, key: jax.Array) -> SMBGDState:
    """Fresh-session init as one cached program (same RNG stream as the
    eager call — jit never changes values, only dispatch cost)."""
    return smbgd_lib.init_state(cfg, key)


@jax.jit
def _row_move_jit(B, H, step, conv, health, moments, dst, src):
    """Copy row ``src`` over row ``dst`` on every leaf, verbatim."""
    return (
        B.at[dst].set(B[src]),
        H.at[dst].set(H[src]),
        step.at[dst].set(step[src]),
        conv.at[dst].set(conv[src]),
        health.at[dst].set(health[src]),
        moments.at[dst].set(moments[src]),
    )


@functools.partial(jax.jit, static_argnums=0)
def _resize_rows_jit(new_S, B, H, step, conv, health, moments):
    """Prefix copy/truncate every leaf to ``new_S`` rows (grow appends blank
    slots: zero B/Ĥ, step 0, conv +inf, clean health, zero moments)."""
    old_S = B.shape[0]
    if old_S > new_S:
        return (
            B[:new_S], H[:new_S], step[:new_S],
            conv[:new_S], health[:new_S], moments[:new_S],
        )
    k = new_S - old_S
    return (
        jnp.concatenate([B, jnp.zeros((k,) + B.shape[1:], B.dtype)]),
        jnp.concatenate([H, jnp.zeros((k,) + H.shape[1:], H.dtype)]),
        jnp.concatenate([step, jnp.zeros((k,), step.dtype)]),
        jnp.concatenate([conv, jnp.full((k,), jnp.inf, jnp.float32)]),
        jnp.concatenate([health, jnp.zeros((k,), jnp.int32)]),
        jnp.concatenate([moments, jnp.zeros((k, 2), jnp.float32)]),
    )


@dataclasses.dataclass(frozen=True, eq=False)
class SeparatorBank:
    """S-stream separation engine; same ``algorithm`` knob as ``Separator``.

    ``fused=True`` selects the whole-step megakernel on persistent padded
    state (requires ``algorithm="smbgd_batched"``); ``block_p`` overrides the
    kernel's P-tile size (autotune knob; default picks ``min(512, P)``
    rounded to the sublane) and ``block_s`` the number of streams batched per
    grid cell (must divide ``n_streams``; default: largest divisor whose
    per-cell VMEM residency fits the budget — see ``ops.default_block_s``).

    ``dtype_policy`` ("f32"/"bf16") sets the persistent storage dtype of
    ``B``/``Ĥ`` (accumulation stays f32 everywhere); the default ``None``
    follows ``easi.dtype`` — the legacy contract where a bf16 config stores
    bf16 state.  ``prefetch`` toggles the megakernel's double-buffered X DMA.
    Geometry knobs left as ``None`` resolve from the persisted autotune cache
    (``AUTOTUNE.json``) unless ``autotune=False``.

    ``health_checks`` (default on) folds the per-stream health word into
    every step path (``BankState.health``) and REFUSES unhealthy commits —
    the fault-containment layer; ``blowup`` overrides the static blow-up
    bound on ``‖ΔB‖_F/‖B‖_F`` (default
    ``kernels.easi_gradient.ops.HEALTH_BLOWUP_BOUND``).

    ``moments`` (default OFF — the telemetry is opt-in, and off keeps every
    other output bit-identical to the pre-moment bank) folds the per-stream
    raw [Σy², Σy⁴] into every step/probe path (``BankState.moments``): the
    in-kernel kurtosis telemetry the serving layer's ``MomentController``
    scales μ from.  Costs 8 bytes/stream/tick of HBM (the output leaf —
    both sums fold from registers the gradient pass already holds).
    """

    easi: EASIConfig
    opt: SMBGDConfig
    n_streams: int
    algorithm: str = "smbgd_batched"
    use_pallas: bool = False
    fused: bool = False
    hyperparams: Optional[BankHyperparams] = None
    block_p: Optional[int] = None
    block_s: Optional[int] = None
    dtype_policy: Optional[str] = None  # None → follow easi.dtype
    prefetch: Optional[bool] = None
    autotune: bool = True
    health_checks: bool = True
    blowup: Optional[float] = None  # None → ops.HEALTH_BLOWUP_BOUND
    moments: bool = False  # per-stream [Σy², Σy⁴] telemetry (adaptive μ)

    def __post_init__(self) -> None:
        if self.n_streams < 1:
            raise ValueError("n_streams must be >= 1")
        from repro.kernels.easi_gradient import ops as easi_ops

        if (
            self.dtype_policy is not None
            and self.dtype_policy not in easi_ops.STORAGE_DTYPES
        ):
            raise ValueError(
                f"dtype_policy must be one of "
                f"{sorted(easi_ops.STORAGE_DTYPES)}, got {self.dtype_policy!r}"
            )
        # snapshot the caller's EXPLICIT geometry before autotune fills the
        # blanks — with_streams() re-resolves at the new width key but must
        # keep hand-set knobs winning over whatever the cache says there
        object.__setattr__(
            self,
            "_explicit_geometry",
            {
                "block_p": self.block_p,
                "block_s": self.block_s,
                "prefetch": self.prefetch,
            },
        )
        self._resolve_autotune()
        # reuse Separator's alias resolution + validation
        sep = Separator(self.easi, self.opt, self.algorithm, self.use_pallas)
        object.__setattr__(self, "algorithm", sep.algorithm)
        if self.fused and self.algorithm != "smbgd_batched":
            raise ValueError(
                f"fused=True requires algorithm='smbgd_batched', "
                f"got {self.algorithm!r}"
            )
        if self.hyperparams is not None:
            if self.algorithm != "smbgd_batched":
                raise ValueError(
                    "per-stream hyperparams require algorithm='smbgd_batched'"
                )
            for name, v in self.hyperparams._asdict().items():
                shape = jnp.shape(v)
                if shape != (self.n_streams,):
                    raise ValueError(
                        f"hyperparams.{name} must have shape "
                        f"({self.n_streams},), got {shape}"
                    )

    def _resolve_autotune(self) -> None:
        """Fill unset GEOMETRY knobs (block_p/block_s/prefetch) from the
        persisted autotune cache — best-effort, fused banks only.  The
        resolved values become the dataclass fields, so everything derived
        from this bank (sharded local banks, probe banks built by the
        serving layer) inherits the tuned geometry rather than re-resolving
        against a different shape key."""
        if not (self.fused and self.autotune):
            return
        if not (
            self.block_p is None
            or self.block_s is None
            or self.prefetch is None
        ):
            return
        try:
            from repro.stream import autotune as autotune_lib

            entry = autotune_lib.lookup(
                self.n_streams,
                self.opt.batch_size,
                self.easi.n_features,
                self.easi.n_components,
            )
        except Exception:  # corrupt cache must never break bank construction
            entry = None
        if not entry:
            return
        if self.block_p is None and entry.get("block_p"):
            object.__setattr__(self, "block_p", int(entry["block_p"]))
        if (
            self.block_s is None
            and entry.get("block_s")
            and self.n_streams % int(entry["block_s"]) == 0
        ):
            object.__setattr__(self, "block_s", int(entry["block_s"]))
        if self.prefetch is None and "prefetch" in entry:
            object.__setattr__(self, "prefetch", bool(entry["prefetch"]))

    @property
    def resolved_dtype_policy(self) -> str:
        """``dtype_policy`` with the ``None`` default resolved against
        ``easi.dtype`` (a bf16 config stores bf16 — the legacy contract)."""
        if self.dtype_policy is not None:
            return self.dtype_policy
        from repro.kernels.easi_gradient import ops as easi_ops

        for name, dt in easi_ops.STORAGE_DTYPES.items():
            if jnp.dtype(dt) == jnp.dtype(self.easi.dtype):
                return name
        return "f32"

    @property
    def storage_dtype(self):
        """Persistent B/Ĥ dtype per ``dtype_policy`` (compute stays f32)."""
        from repro.kernels.easi_gradient import ops as easi_ops

        return easi_ops.STORAGE_DTYPES[self.resolved_dtype_policy]

    @property
    def resolved_blowup(self) -> float:
        """The static blow-up bound with the ``None`` default resolved."""
        if self.blowup is not None:
            return float(self.blowup)
        from repro.kernels.easi_gradient import ops as easi_ops

        return float(easi_ops.HEALTH_BLOWUP_BOUND)

    @property
    def _sep(self) -> Separator:
        return Separator(self.easi, self.opt, self.algorithm, self.use_pallas)

    # -- persistent padded layout ------------------------------------------
    @property
    def layout(self):
        """Lane-aligned persistent layout (``kernels.easi_gradient.ops
        .BankLayout``) for this bank's (n, m, P) — the fused path's contract."""
        from repro.kernels.easi_gradient import ops as easi_ops

        return easi_ops.bank_layout(
            self.easi.n_components,
            self.easi.n_features,
            self.opt.batch_size,
            block_p=self.block_p,
            dtype_policy=self.resolved_dtype_policy,
        )

    def pad_state(self, state: BankState) -> BankState:
        """Logical → persistent-padded state in the STORAGE dtype (no-op if
        already padded and stored right) — the cast-in ramp of the dtype
        policy: logical f32 states (admission, stacked probe banks,
        checkpoints written before a policy change) enter bf16 banks here."""
        lay = self.layout
        dt = lay.storage_dtype
        if state.B.shape[-2:] == (lay.n_pad, lay.m_pad):
            if state.B.dtype == dt and state.H_hat.dtype == dt:
                return state
            return state._replace(
                B=state.B.astype(dt), H_hat=state.H_hat.astype(dt)
            )
        S = state.B.shape[0]
        B = (
            jnp.zeros((S, lay.n_pad, lay.m_pad), dt)
            .at[:, : lay.n, : lay.m]
            .set(state.B.astype(dt))
        )
        H = (
            jnp.zeros((S, lay.n_pad, lay.n_pad), dt)
            .at[:, : lay.n, : lay.n]
            .set(state.H_hat.astype(dt))
        )
        return BankState(
            B=B, H_hat=H, step=state.step, conv=state.conv,
            health=state.health, moments=state.moments,
        )

    def unpad_state(self, state: BankState) -> BankState:
        """Persistent-padded → logical state (no-op if already logical).
        ``moments`` carries through unchanged — the (S, 2) leaf is layout-
        independent (padded Y entries are zero, so padded and logical folds
        agree exactly)."""
        lay = self.layout
        if state.B.shape[-2:] == (lay.n, lay.m):
            return state
        return BankState(
            B=state.B[:, : lay.n, : lay.m],
            H_hat=state.H_hat[:, : lay.n, : lay.n],
            step=state.step,
            conv=state.conv,
            health=state.health,
            moments=state.moments,
        )

    def pad_batch(self, X: jnp.ndarray) -> jnp.ndarray:
        """``X (S, P, m)`` → ``(S, P_pad, m_pad)`` (no-op if already padded).
        Serving callers that stage into a padded buffer directly (see
        ``SeparationService``) skip this copy entirely."""
        lay = self.layout
        if X.shape[-2:] == (lay.P_pad, lay.m_pad):
            return X
        S = X.shape[0]
        return (
            jnp.zeros((S, lay.P_pad, lay.m_pad), X.dtype)
            .at[:, : lay.P, : lay.m]
            .set(X)
        )

    def unpad_y(self, Y: jnp.ndarray) -> jnp.ndarray:
        """Fused-path outputs ``Y (S, P_pad, n_pad)`` → logical ``(S, P, n)``."""
        lay = self.layout
        if Y.shape[-2:] == (lay.P, lay.n):
            return Y
        return Y[:, : lay.P, : lay.n]

    # -- state ------------------------------------------------------------
    def init(self, key: jax.Array) -> BankState:
        """Independent per-stream inits from ``jax.random.split(key, S)`` —
        stream s's state equals ``Separator.init(split_keys[s])`` exactly.
        Fused banks return the state already in the persistent padded layout.
        """
        keys = jax.random.split(key, self.n_streams)
        sub = jax.vmap(lambda k: smbgd_lib.init_state(self.easi, k))(keys)
        dt = self.storage_dtype
        state = BankState(
            B=sub.B.astype(dt),
            H_hat=sub.H_hat.astype(dt),
            step=sub.step,
            conv=jnp.full((self.n_streams,), jnp.inf, jnp.float32),
            health=jnp.zeros((self.n_streams,), jnp.int32),
            moments=jnp.zeros((self.n_streams, 2), jnp.float32),
        )
        return self.pad_state(state) if self.fused else state

    @staticmethod
    def _dyn(slot) -> jnp.ndarray:
        """Slot index as a traced int32 scalar.  A Python-int index is baked
        into the eager op as a constant, so every distinct slot pays its own
        one-off XLA compile — ruinous on the serving layer's backfill and
        compaction paths, which visit arbitrary slots.  As an array operand,
        one compiled program covers all indices (results are bit-identical
        either way)."""
        return jnp.asarray(slot, jnp.int32)

    def init_slot(self, state: BankState, slot, key: jax.Array) -> BankState:
        """Reset one stream slot to a fresh session (admission path).  On a
        padded bank the whole padded slot is cleared, so no stale accumulator
        junk from the previous occupant survives (``init_state``'s ``Ĥ`` is
        zero, so the shared row-write program's corner-write IS the clear)."""
        sub = _init_state_jit(self.easi, key)
        return self._write_row(state, slot, sub)

    def slot_state(self, state: BankState, slot: int) -> SMBGDState:
        """Extract one stream's state as a single-stream ``SMBGDState``
        (always logical shapes — unpads the eviction boundary).  Logical
        states are the bank-independent interchange format, so bf16 storage
        casts back to the config compute dtype here."""
        state = self.unpad_state(state)  # no-op on logical state
        slot = self._dyn(slot)
        dt = self.easi.dtype
        return SMBGDState(
            B=state.B[slot].astype(dt),
            H_hat=state.H_hat[slot].astype(dt),
            step=state.step[slot],
        )

    def set_slot(self, state: BankState, slot, sub: SMBGDState) -> BankState:
        """Write a single-stream ``SMBGDState`` (logical shapes) into one
        slot — the warm-start admission path: a re-admitted session resumes
        from its frozen separator (``B``, ``Ĥ``, step counter all carried, so
        the γ step-0 gate does NOT re-apply).  ``conv`` restarts at +inf —
        the statistic describes steps taken *in this slot*."""
        return self._write_row(state, slot, sub)

    def _write_row(self, state: BankState, slot, sub: SMBGDState) -> BankState:
        """One fused-program slot write (see ``_row_write_jit``): pads the
        logical sub-state to the bank's persistent layout when needed and
        restarts the slot's conv/health/moments telemetry."""
        B, H, step, conv, health, moments = _row_write_jit(
            state.B,
            state.H_hat,
            state.step,
            self._conv_or_default(state),
            self._health_or_default(state),
            self._moments_or_default(state),
            self._dyn(slot),
            sub.B,
            sub.H_hat,
            sub.step,
        )
        return BankState(
            B=B, H_hat=H, step=step, conv=conv, health=health, moments=moments
        )

    def _is_padded(self, state: BankState) -> bool:
        n, m = self.easi.n_components, self.easi.n_features
        return state.B.shape[-2:] != (n, m)

    @staticmethod
    def _conv_or_default(state: BankState) -> jnp.ndarray:
        """``state.conv``, or the +inf "never measured" init for states built
        by legacy callers that predate the convergence statistic."""
        if state.conv is not None:
            return state.conv
        return jnp.full((state.B.shape[0],), jnp.inf, jnp.float32)

    @staticmethod
    def _health_or_default(state: BankState) -> jnp.ndarray:
        """``state.health``, or all-healthy zeros for states built by legacy
        callers that predate the health word."""
        if state.health is not None:
            return state.health
        return jnp.zeros((state.B.shape[0],), jnp.int32)

    @staticmethod
    def _moments_or_default(state: BankState) -> jnp.ndarray:
        """``state.moments``, or all-zero [Σy², Σy⁴] rows for states built by
        legacy callers that predate the moment telemetry."""
        if state.moments is not None:
            return state.moments
        return jnp.zeros((state.B.shape[0], 2), jnp.float32)

    @staticmethod
    def stack_states(states, dtype=None) -> BankState:
        """Stack S single-stream ``SMBGDState``s into a (logical) ``BankState``
        — feed through ``pad_state`` to enter a fused bank.  Single-stream
        states carry no convergence statistic, so ``conv`` restarts at +inf.
        ``dtype`` (optional) casts ``B``/``Ĥ`` on the way in — handy when the
        target bank stores bf16 and the caller wants the cast before the
        stack allocates (``pad_state`` would otherwise do it after)."""
        B = jnp.stack([jnp.asarray(s.B) for s in states])
        H = jnp.stack([jnp.asarray(s.H_hat) for s in states])
        if dtype is not None:
            B, H = B.astype(dtype), H.astype(dtype)
        return BankState(
            B=B,
            H_hat=H,
            step=jnp.stack([jnp.asarray(s.step) for s in states]),
            conv=jnp.full((len(states),), jnp.inf, jnp.float32),
            health=jnp.zeros((len(states),), jnp.int32),
            moments=jnp.zeros((len(states), 2), jnp.float32),
        )

    def unstack_states(self, state: BankState) -> list:
        """Inverse of ``stack_states``: a list of per-stream single-stream
        ``SMBGDState``s (always logical shapes AND the config compute dtype
        — unpads fused-bank state and upcasts bf16 storage)."""
        state = self.unpad_state(state)
        dt = self.easi.dtype
        return [
            SMBGDState(
                B=state.B[s].astype(dt),
                H_hat=state.H_hat[s].astype(dt),
                step=state.step[s],
            )
            for s in range(state.B.shape[0])
        ]

    # -- shadow snapshots (fault containment) ------------------------------
    def update_shadow(
        self, shadow: BankState, state: BankState, mask: jnp.ndarray
    ) -> BankState:
        """Copy-on-healthy: refresh the shadow's slots from ``state`` where
        ``mask (S,)`` is set, keep the previous snapshot elsewhere.  The
        shadow is the per-slot last-known-good state the serving layer rolls
        a faulted session back to; it always carries ``health == 0`` (only
        healthy states are ever copied in).  Both states must share a layout
        (the service keeps the shadow in the bank's persistent layout)."""
        mask = jnp.asarray(mask) != 0
        m3 = mask[:, None, None]
        return BankState(
            B=jnp.where(m3, state.B, shadow.B),
            H_hat=jnp.where(m3, state.H_hat, shadow.H_hat),
            step=jnp.where(mask, state.step, shadow.step),
            conv=jnp.where(
                mask, self._conv_or_default(state), self._conv_or_default(shadow)
            ),
            health=jnp.zeros((state.B.shape[0],), jnp.int32),
            moments=jnp.zeros((state.B.shape[0], 2), jnp.float32),
        )

    def restore_slot(
        self, state: BankState, shadow: BankState, slot
    ) -> BankState:
        """Roll ONE slot back to its shadow snapshot (B/Ĥ/step/conv), and
        clear its health word — the first-offense recovery action."""
        return BankState(
            B=state.B.at[slot].set(shadow.B[slot]),
            H_hat=state.H_hat.at[slot].set(shadow.H_hat[slot]),
            step=state.step.at[slot].set(shadow.step[slot]),
            conv=self._conv_or_default(state)
            .at[slot]
            .set(self._conv_or_default(shadow)[slot]),
            health=self._health_or_default(state).at[slot].set(0),
            moments=self._moments_or_default(state).at[slot].set(0.0),
        )

    def copy_slot(self, dst: BankState, src: BankState, slot) -> BankState:
        """Copy one slot of ``src`` into ``dst`` (same layout on both sides)
        — how the serving layer seeds a freshly (re)admitted session's
        shadow so a rollback can never resurrect the slot's previous
        occupant."""
        slot = self._dyn(slot)
        return BankState(
            B=dst.B.at[slot].set(src.B[slot]),
            H_hat=dst.H_hat.at[slot].set(src.H_hat[slot]),
            step=dst.step.at[slot].set(src.step[slot]),
            conv=self._conv_or_default(dst)
            .at[slot]
            .set(self._conv_or_default(src)[slot]),
            health=self._health_or_default(dst).at[slot].set(0),
            moments=self._moments_or_default(dst).at[slot].set(0.0),
        )

    def corrupt_slot(
        self, state: BankState, slot, mode: str = "nan", scale: float = 1e30
    ) -> BankState:
        """Fault-injection hook (chaos tests): poison ONE slot's separator —
        ``"nan"``/``"inf"`` overwrite ``B[slot, 0, 0]``, ``"scale"``
        multiplies ``B[slot]`` by ``scale`` (a blow-up next tick).  The next
        step's health word must flag the slot; nothing else is touched."""
        if mode == "nan":
            B = state.B.at[slot, 0, 0].set(jnp.nan)
        elif mode == "inf":
            B = state.B.at[slot, 0, 0].set(jnp.inf)
        elif mode == "scale":
            B = state.B.at[slot].multiply(jnp.asarray(scale, state.B.dtype))
        else:
            raise ValueError(f"unknown corruption mode {mode!r}")
        return state._replace(B=B)

    # -- elasticity --------------------------------------------------------
    def with_streams(self, new_S: int) -> "SeparatorBank":
        """A bank identical to this one at width ``new_S`` — the resize
        primitive.  Geometry knobs the CALLER set explicitly carry over
        verbatim (an explicit ``block_s`` that no longer divides the new
        width is dropped back to autotune/default resolution rather than
        erroring); knobs that were autotune-resolved at the old width
        re-resolve against the new ``(S, P, m, n, backend)`` cache key, so a
        grown bank picks up the geometry tuned FOR that width.  Per-stream
        ``hyperparams`` rows are ``(S,)``-shaped and have no canonical resize
        — rebuild them at the new width and pass through ``replace``."""
        if new_S == self.n_streams:
            return self
        if self.hyperparams is not None:
            raise ValueError(
                "cannot resize a bank with explicit per-stream hyperparams "
                f"(rows are shaped ({self.n_streams},)); rebuild them at "
                f"width {new_S} and use dataclasses.replace"
            )
        explicit = getattr(
            self,
            "_explicit_geometry",
            {"block_p": self.block_p, "block_s": self.block_s,
             "prefetch": self.prefetch},
        )
        block_s = explicit["block_s"]
        if block_s is not None and new_S % block_s != 0:
            block_s = None
        return dataclasses.replace(
            self,
            n_streams=new_S,
            block_p=explicit["block_p"],
            block_s=block_s,
            prefetch=explicit["prefetch"],
        )

    def resize_state(self, state: BankState) -> BankState:
        """Adopt a ``BankState`` of ANY width into this bank's width by
        leaf-wise prefix copy — valid because the persistent padded layout's
        trailing dims (``n_pad``/``m_pad``) depend only on (n, m, dtype
        policy), never on S or ``block_p``, so resizing never re-lays-out a
        surviving slot (the bit-identity contract).  Growing appends blank
        slots (zero B/Ĥ, step 0, conv +inf, clean health, zero moments —
        exactly what ``init_slot``/``set_slot`` overwrite at activation, and
        NO RNG is consumed here, so fresh-init key sequences match a
        fixed-width run); shrinking truncates — the caller (see
        ``serve.SeparationService.shrink``) must have compacted live slots
        below ``new_S`` first."""
        new_S = self.n_streams
        old_S = state.B.shape[0]
        state = state._replace(
            conv=self._conv_or_default(state),
            health=self._health_or_default(state),
            moments=self._moments_or_default(state),
        )
        if old_S == new_S:
            return state
        B, H, step, conv, health, moments = _resize_rows_jit(
            new_S,
            state.B,
            state.H_hat,
            state.step,
            state.conv,
            state.health,
            state.moments,
        )
        return BankState(
            B=B, H_hat=H, step=step, conv=conv, health=health, moments=moments
        )

    def move_slot(self, state: BankState, dst, src) -> BankState:
        """Move one slot's FULL row (B, Ĥ, step, conv, health, moments) to
        another index of the same state — the compaction primitive.  Unlike
        ``copy_slot`` (cross-state shadow seeding, which restarts the
        per-slot verdicts) every leaf carries over verbatim, so a compacted
        session's trajectory — including its eviction-policy view — is
        bit-identical to never having moved.  The source row is left behind
        as-is; it lands on the free list and ``init_slot``/``set_slot``
        clear it at the next activation (or a shrink truncates it)."""
        B, H, step, conv, health, moments = _row_move_jit(
            state.B,
            state.H_hat,
            state.step,
            self._conv_or_default(state),
            self._health_or_default(state),
            self._moments_or_default(state),
            self._dyn(dst),
            self._dyn(src),
        )
        return BankState(
            B=B, H_hat=H, step=step, conv=conv, health=health, moments=moments
        )

    # -- stepping ----------------------------------------------------------
    def step(
        self,
        state: BankState,
        X: jnp.ndarray,
        active: Optional[jnp.ndarray] = None,
        hyperparams: Optional[BankHyperparams] = None,
    ) -> Tuple[BankState, jnp.ndarray]:
        """One fused mini-batch update for all streams.

        ``X (S, P, m)`` → ``Y (S, P, n)``.  ``active (S,)`` bool (optional)
        freezes masked-out slots: their state is returned unchanged (their Y
        rows are still computed — garbage-in/garbage-out for free slots).

        ``hyperparams`` (optional) overrides the bank's per-stream (μ, β, γ)
        for THIS step — as ``(S,)`` array operands, not closure constants, so
        a jitted step can vary them tick to tick without retracing (the
        serving layer's drift-watchdog μ boost rides this).  Overrides route
        non-fused banks through the hetero-vmap path and require
        ``algorithm="smbgd_batched"``.

        Fused banks run on padded shapes: ``X`` may be logical (padded here)
        or already ``(S, P_pad, m_pad)`` (zero-copy), and the returned state
        and ``Y (S, P_pad, n_pad)`` stay padded — ``unpad_state``/``unpad_y``
        at the boundary.
        """
        if hyperparams is not None and self.algorithm != "smbgd_batched":
            raise ValueError(
                "per-stream hyperparams require algorithm='smbgd_batched'"
            )
        if self.fused:
            return self._step_fused(state, X, active, hyperparams)
        new_state, Y = self._step_all(state, X, hyperparams)
        S = state.B.shape[0]
        act = (
            jnp.ones((S,), jnp.int32) if active is None else jnp.asarray(active)
        ) != 0
        moments = self._vmap_moments(Y, act)
        if active is None and not self.health_checks:
            return (
                new_state._replace(
                    health=jnp.zeros((S,), jnp.int32), moments=moments
                ),
                Y,
            )
        health = (
            self._vmap_health(new_state, Y, self.resolved_blowup)
            if self.health_checks
            else jnp.zeros((S,), jnp.int32)
        )
        # unhealthy streams refuse their commit exactly like frozen ones:
        # pre-tick B/Ĥ/step/conv survive, only the health word reports why
        commit = act & (health == 0)
        c3 = commit[:, None, None]
        new_state = BankState(
            B=jnp.where(c3, new_state.B, state.B),
            H_hat=jnp.where(c3, new_state.H_hat, state.H_hat),
            step=jnp.where(commit, new_state.step, state.step),
            conv=jnp.where(commit, new_state.conv, self._conv_or_default(state)),
            health=jnp.where(act, health, 0),
            moments=moments,
        )
        return new_state, Y

    @staticmethod
    def _vmap_health(new_state: BankState, Y: jnp.ndarray, blowup: float):
        """Per-stream health word on the vmap paths — same bit layout as the
        megakernel's in-register reduction (``easi_gradient.HEALTH_*``):
        1 non-finite B′, 2 non-finite Ĥ′, 4 non-finite Y, 8 update magnitude
        above ``blowup`` (``~(δ <= bound)`` so a NaN δ counts as blow-up)."""
        fin_b = jnp.all(jnp.isfinite(new_state.B), axis=(1, 2))
        fin_h = jnp.all(jnp.isfinite(new_state.H_hat), axis=(1, 2))
        fin_y = jnp.all(jnp.isfinite(Y), axis=(1, 2))
        blow = ~(new_state.conv <= blowup)
        return (
            jnp.where(fin_b, 0, 1)
            + jnp.where(fin_h, 0, 2)
            + jnp.where(fin_y, 0, 4)
            + jnp.where(blow, 8, 0)
        ).astype(jnp.int32)

    def _vmap_moments(self, Y: jnp.ndarray, act: jnp.ndarray) -> jnp.ndarray:
        """Per-stream raw [Σy², Σy⁴] on the vmap paths — the same whole-block
        reduction the megakernel folds tile-by-tile (padding-exact, so the
        two agree bit-for-bit on identical Y).  Zeros when the bank's
        ``moments`` flag is off or for masked-out streams."""
        if not self.moments:
            return jnp.zeros((Y.shape[0], 2), jnp.float32)
        y2 = Y.astype(jnp.float32) ** 2
        mom = jnp.stack(
            [jnp.sum(y2, axis=(1, 2)), jnp.sum(y2 * y2, axis=(1, 2))], axis=-1
        )
        return jnp.where(act[:, None], mom, 0.0)

    @staticmethod
    def _donate_default(donate: Optional[bool]) -> bool:
        # On accelerators donation lets the runtime alias the persistent state
        # buffers into the kernel outputs (zero steady-state allocation).  On
        # the CPU backend XLA instead inserts defensive copies for donated
        # params — measurably slower at bank sizes — so default it off there.
        if donate is None:
            return jax.default_backend() != "cpu"
        return donate

    def make_step(
        self, donate: Optional[bool] = None, with_hyperparams: bool = False
    ):
        """Jitted ``step(state, X, active) -> (state, Y)``; with donation
        (default on accelerators) the state buffers are reused for the
        outputs, so a steady-state tick allocates nothing (the serving hot
        loop).  ``with_hyperparams=True`` builds the 4-argument flavour
        ``step(state, X, active, hyperparams)`` — per-stream (μ, β, γ) as
        traced operands, the drift-watchdog's no-retrace μ-boost hook."""
        if with_hyperparams:
            fn = lambda st, X, active, hp: self.step(
                st, X, active=active, hyperparams=hp
            )
        else:
            fn = lambda st, X, active: self.step(st, X, active=active)
        donate = self._donate_default(donate)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    def make_epoch(self, donate: Optional[bool] = None):
        """Jitted ``epoch(state, X) -> (state, Y)`` with donated state
        (default on accelerators; see ``make_step``)."""
        donate = self._donate_default(donate)
        return jax.jit(self.epoch, donate_argnums=(0,) if donate else ())

    def probe(
        self,
        state: BankState,
        X: jnp.ndarray,
        active: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """No-commit probe step: the per-stream convergence statistic a
        ``step`` on ``X (S, P, m)`` WOULD commit — ``‖Ĥ′B‖_F/‖B‖_F`` from the
        virtual ``Ĥ′ = γ̂Ĥ + S`` — without mutating anything.  Returns
        ``(conv (S,), health (S,) int32, moments (S, 2) f32)``; streams
        masked out by ``active`` carry ``state.conv`` through (+inf for
        never-measured states) and report ``health == 0`` / zero moments
        (moments are also all-zero when the bank's ``moments`` flag is
        off).  The health word judges the VIRTUAL step
        (would this data blow the separator up?), so a quarantine probe can
        tell "still diverging" from "safe to resume" without committing.

        This is the out-of-band drift probe: parked (frozen) separators are
        stacked into a transient bank (``stack_states``/``pad_state``) and
        one launch answers "has any of them drifted?" for the whole batch.
        The fused path routes through the megakernel's freeze-only variant
        (``kernels.easi_gradient.ops.smbgd_probe_bank``) — no ``Y``/state
        writes reach HBM at all.
        """
        if self.fused:
            from repro.kernels.easi_gradient import ops as easi_ops

            lay = self.layout
            state = self.pad_state(state)
            X = self.pad_batch(X)
            hp = self._bank_hyperparams()
            W = (
                jnp.zeros((self.n_streams, lay.P_pad), jnp.float32)
                .at[:, : lay.P]
                .set(hp.within_batch_weights(lay.P))
            )
            if active is None:
                active = jnp.ones((self.n_streams,), dtype=jnp.int32)
            return easi_ops.smbgd_probe_bank(
                X,
                W,
                state.B,
                state.H_hat,
                state.step,
                hp.effective_momentum(lay.P),
                active,
                self._conv_or_default(state),
                nonlinearity=self.easi.nonlinearity,
                block_p=lay.block_p,
                block_s=self.block_s,
                prefetch=bool(self.prefetch),
                health=bool(self.health_checks),
                moments=bool(self.moments),
                blowup=self.resolved_blowup,
            )
        new_state, Y = self._step_all(state, X)
        act = (
            jnp.ones((state.B.shape[0],), jnp.int32)
            if active is None
            else jnp.asarray(active)
        ) != 0
        health = (
            self._vmap_health(new_state, Y, self.resolved_blowup)
            if self.health_checks
            else jnp.zeros((state.B.shape[0],), jnp.int32)
        )
        conv = jnp.where(act, new_state.conv, self._conv_or_default(state))
        return conv, jnp.where(act, health, 0), self._vmap_moments(Y, act)

    def make_probe(self):
        """Jitted ``probe(state, X, active) -> (conv (S,), health (S,),
        moments (S, 2))`` (no donation — the probe never consumes its state;
        the frozen operands stay live)."""
        return jax.jit(lambda st, X, active: self.probe(st, X, active=active))

    def _bank_hyperparams(self) -> BankHyperparams:
        if self.hyperparams is not None:
            return self.hyperparams
        return BankHyperparams.broadcast(self.opt, self.n_streams)

    def _step_fused(
        self,
        state: BankState,
        X: jnp.ndarray,
        active: Optional[jnp.ndarray],
        hyperparams: Optional[BankHyperparams] = None,
    ):
        """Whole-step megakernel tick: one (streams, P-tiles) launch computes
        Y, the weighted gradient sum AND the commit on persistent padded
        state — nothing intermediate is materialized in HBM."""
        from repro.kernels.easi_gradient import ops as easi_ops

        lay = self.layout
        state = self.pad_state(state)  # no-op on the persistent layout
        X = self.pad_batch(X)  # no-op when staged block-aligned
        hp = hyperparams if hyperparams is not None else self._bank_hyperparams()
        # weight rows at padded P: padded samples carry zero weight
        W = (
            jnp.zeros((self.n_streams, lay.P_pad), jnp.float32)
            .at[:, : lay.P]
            .set(hp.within_batch_weights(lay.P))
        )
        gamma_hat = hp.effective_momentum(lay.P)
        if active is None:
            active = jnp.ones((self.n_streams,), dtype=jnp.int32)
        Y, B_new, H_new, step_new, conv_new, health_new, mom_new = (
            easi_ops.smbgd_step_bank(
                X,
                W,
                state.B,
                state.H_hat,
                state.step,
                gamma_hat,
                active,
                self._conv_or_default(state),
                nonlinearity=self.easi.nonlinearity,
                block_p=lay.block_p,
                block_s=self.block_s,
                prefetch=bool(self.prefetch),
                health=bool(self.health_checks),
                moments=bool(self.moments),
                blowup=self.resolved_blowup,
            )
        )
        return (
            BankState(
                B=B_new,
                H_hat=H_new,
                step=step_new,
                conv=conv_new,
                health=health_new,
                moments=mom_new,
            ),
            Y,
        )

    def _step_all(
        self,
        state: BankState,
        X: jnp.ndarray,
        hyperparams: Optional[BankHyperparams] = None,
    ):
        # dtype policy on the vmap paths mirrors the megakernel's boundary
        # casts: bf16-stored banks upcast to f32, run the exact f32 step, and
        # downcast only the committed B/Ĥ — accumulation never happens at
        # storage precision.
        if state.B.dtype != jnp.float32:
            dt = state.B.dtype
            f32 = state._replace(
                B=state.B.astype(jnp.float32),
                H_hat=state.H_hat.astype(jnp.float32),
            )
            new_state, Y = self._step_all(f32, X, hyperparams)
            return (
                new_state._replace(
                    B=new_state.B.astype(dt), H_hat=new_state.H_hat.astype(dt)
                ),
                Y,
            )
        if hyperparams is not None or self.hyperparams is not None:
            return self._step_hetero(state, X, hyperparams)
        if self.algorithm == "smbgd_batched" and self.use_pallas:
            return self._step_pallas(state, X)
        sep = self._sep
        sub = SMBGDState(B=state.B, H_hat=state.H_hat, step=state.step)
        new_sub, Y = jax.vmap(sep.step)(sub, X)
        return (
            BankState(
                B=new_sub.B,
                H_hat=new_sub.H_hat,
                step=new_sub.step,
                conv=metrics_lib.update_magnitude(new_sub.B, state.B),
            ),
            Y,
        )

    def _step_hetero(
        self,
        state: BankState,
        X: jnp.ndarray,
        hyperparams: Optional[BankHyperparams] = None,
    ):
        """vmap fallback for per-stream (μ, β, γ) without the megakernel —
        the reference semantics the fused path is tested against."""
        from repro.core import easi as easi_lib

        hp = hyperparams if hyperparams is not None else self._bank_hyperparams()
        P = self.opt.batch_size
        W = hp.within_batch_weights(P)  # (S, P)
        gamma_hat = hp.effective_momentum(P)  # (S,)
        g = self.easi.g

        def one(st: SMBGDState, x, w, gh):
            Y = x @ st.B.T
            S_grad = easi_lib.batched_relative_gradient(Y, w, g)
            H_hat, B_next = smbgd_lib.smbgd_commit(
                st.step, st.H_hat, S_grad, st.B, self.opt, gamma_hat=gh
            )
            return SMBGDState(B=B_next, H_hat=H_hat, step=st.step + 1), Y

        sub = SMBGDState(B=state.B, H_hat=state.H_hat, step=state.step)
        new_sub, Y = jax.vmap(one)(sub, X, W.astype(state.B.dtype), gamma_hat)
        return (
            BankState(
                B=new_sub.B,
                H_hat=new_sub.H_hat,
                step=new_sub.step,
                conv=metrics_lib.update_magnitude(new_sub.B, state.B),
            ),
            Y,
        )

    def _step_pallas(self, state: BankState, X: jnp.ndarray):
        """Closed-form SMBGD step with the gradient sum of all S streams fused
        into one (streams, P-tiles) Pallas launch (PR-1 path: Y and the
        commit remain XLA ops around the gradient kernel)."""
        from repro.kernels.easi_gradient import ops as easi_ops

        B, H_prev = state.B, state.H_hat
        Y = jnp.einsum("spm,snm->spn", X, B)  # per-stream Y = X Bᵀ
        w = self.opt.within_batch_weights(dtype=B.dtype)
        S_grad = easi_ops.easi_gradient_bank(
            Y, w, nonlinearity=self.easi.nonlinearity
        )
        H_hat, B_next = smbgd_lib.smbgd_commit(
            state.step, H_prev, S_grad, B, self.opt
        )
        return (
            BankState(
                B=B_next,
                H_hat=H_hat,
                step=state.step + 1,
                conv=metrics_lib.update_magnitude(B_next, B),
            ),
            Y,
        )

    def epoch(
        self, state: BankState, X: jnp.ndarray
    ) -> Tuple[BankState, jnp.ndarray]:
        """One pass over ``X (S, T, m)`` for every stream; returns
        ``(state, Y (S, T', n))`` with T' = K·P (SMBGD) or T (SGD).  Fused
        banks carry padded state through the scan (and return it padded) but
        Y is returned logical.

        ``conv`` semantics: the SMBGD paths scan ``step``, so the returned
        statistic is the LAST mini-batch's ``‖ΔB‖_F/‖B‖_F`` (same scale as
        the serving tick path).  The SGD path has no mini-batch structure —
        its conv is the whole-epoch aggregate ``‖B_end−B_start‖_F/‖B_start‖_F``,
        typically far larger; don't compare it against tick-tuned thresholds.
        """
        if self.algorithm == "sgd":
            if state.B.dtype != jnp.float32:  # f32 compute (see _step_all)
                dt = state.B.dtype
                f32 = state._replace(
                    B=state.B.astype(jnp.float32),
                    H_hat=state.H_hat.astype(jnp.float32),
                )
                new_state, Y = self.epoch(f32, X)
                return (
                    new_state._replace(
                        B=new_state.B.astype(dt),
                        H_hat=new_state.H_hat.astype(dt),
                    ),
                    Y,
                )
            sep = self._sep
            sub = SMBGDState(B=state.B, H_hat=state.H_hat, step=state.step)
            new_sub, Y = jax.vmap(sep.epoch)(sub, X)
            return (
                BankState(
                    new_sub.B,
                    new_sub.H_hat,
                    new_sub.step,
                    conv=metrics_lib.update_magnitude(new_sub.B, state.B),
                ),
                Y,
            )
        S, T, m = X.shape
        P = self.opt.batch_size
        K = T // P
        Xb = X[:, : K * P].reshape(S, K, P, m).transpose(1, 0, 2, 3)  # (K, S, P, m)
        if self.fused:
            state = self.pad_state(state)
        # the scan carry must be structure-stable: normalize legacy None leaves
        state = state._replace(
            conv=self._conv_or_default(state),
            health=self._health_or_default(state),
            moments=self._moments_or_default(state),
        )

        def body(st, xb):
            st, Y = self.step(st, xb)
            return st, self.unpad_y(Y) if self.fused else Y

        state, Yb = jax.lax.scan(body, state, Xb)  # Yb (K, S, P, n)
        return state, Yb.transpose(1, 0, 2, 3).reshape(S, K * P, -1)

    # -- deployment / diagnostics -----------------------------------------
    def transform(self, state: BankState, X: jnp.ndarray) -> jnp.ndarray:
        """Per-stream separation: ``X (S, ..., m)`` → ``Y (S, ..., n)``
        (bf16-stored ``B`` upcasts to the config compute dtype first)."""
        B = self.unpad_state(state).B.astype(self.easi.dtype)
        return jnp.einsum("s...m,snm->s...n", X, B)

    def performance_index(self, state: BankState, A: jnp.ndarray) -> jnp.ndarray:
        """Per-stream Amari index against mixing ``A (m, n)`` or ``(S, m, n)``."""
        B = self.unpad_state(state).B.astype(self.easi.dtype)
        if A.ndim == 2:
            A = jnp.broadcast_to(A, (self.n_streams,) + A.shape)
        gs = jax.vmap(metrics_lib.global_system)(B, A)
        return jax.vmap(metrics_lib.amari_index)(gs)
