"""Moment-scaled adaptive μ: the serving-side controller over the bank's
in-kernel kurtosis telemetry.

The megakernel folds per-stream raw moments [Σy², Σy⁴] into the same
in-register reduction pass that produces ``conv`` and the health word
(``BankState.moments``; 8 bytes/stream/tick of extra HBM — the output leaf
is the entire cost).  This module turns that telemetry into a per-session
μ multiplier, following the theory that the learning rate should scale
inversely with high-order data moments (arXiv:2509.15127) — and that μ
mis-calibration dominates the cost of online ICA in the high-dimensional
regime (arXiv:1710.05384):

  * per tick, the raw sums collapse to a scale-invariant kurtosis statistic
    ``κ = N·Σy⁴ / (Σy²)²`` (N = the number of Y entries, logical P·n —
    padding contributes zeros to both sums, so padded and logical banks
    agree exactly),
  * two EMAs track it: a FAST one (the current output distribution) and a
    SLOW one (the converged reference).  A well-separated EASI output is a
    maximally non-Gaussian point; when the mixing drifts, Y becomes a
    mixture again and the central limit theorem drags its kurtosis toward
    the Gaussian value — the fast EMA leaves the slow reference,
  * the μ multiplier is the clamped deviation ratio between the two: 1 at
    steady state (inside the deadband), rising with the deviation, annealing
    back to 1 as re-convergence pulls the fast EMA home.  That anneal is
    what the fixed drift boost (``DriftPolicy.boost``) cannot do: a fixed
    4×-for-40-ticks pulse either overshoots after the separator has mostly
    recovered or expires before it has.

Composition with the other μ writers is pinned (and regression-tested) in
``SeparationService``: a HealthPolicy μ-cut WINS outright while it is live
(containment beats adaptation), otherwise the DriftPolicy boost and the
controller scale MULTIPLY.

The controller is pure host-side bookkeeping over an (S, 2) telemetry leaf
the tick already produced — per-session floats, no extra device work, and
the resulting μ row rides into the megakernel as a traced operand (the PR-4
``BankHyperparams`` plumbing; no retrace).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MomentPolicy:
    """Configuration of the moment-scaled adaptive μ controller.

    ``ema_fast``/``ema_slow`` are the per-tick EMA weights of the current
    and reference kurtosis trackers (fast ≫ slow; both in (0, 1]).
    ``warmup_ticks`` observed ticks must pass before the controller scales
    anything — the reference EMA needs to see the *converged* output
    distribution before deviations from it mean drift.  ``deadband`` is the
    fractional deviation treated as noise (scale exactly 1.0 inside it), so
    a converged steady state never jitters μ.  ``gain`` exponentiates the
    deviation ratio (1.0 = proportional); ``max_scale``/``min_scale`` clamp
    the multiplier.  ``symmetric=True`` (default) responds to the kurtosis
    leaving the reference in EITHER direction — sub-Gaussian sources drift
    kurtosis UP toward Gaussian, super-Gaussian sources DOWN — by always
    boosting; ``symmetric=False`` maps the signed ratio through the clamps
    instead (deviation above reference can then cut μ below 1).
    ``min_activity`` is the Σy² floor below which a tick is ignored (an
    all-zero or frozen slot's telemetry carries no information).
    """

    ema_fast: float = 0.3
    ema_slow: float = 0.02
    warmup_ticks: int = 10
    deadband: float = 0.15
    gain: float = 1.0
    min_scale: float = 1.0
    max_scale: float = 8.0
    symmetric: bool = True
    min_activity: float = 1e-12

    def __post_init__(self) -> None:
        if not (0.0 < self.ema_fast <= 1.0):
            raise ValueError("ema_fast must be in (0, 1]")
        if not (0.0 < self.ema_slow <= 1.0):
            raise ValueError("ema_slow must be in (0, 1]")
        if self.ema_slow > self.ema_fast:
            raise ValueError("ema_slow must not exceed ema_fast")
        if self.warmup_ticks < 1:
            raise ValueError("warmup_ticks must be >= 1")
        if self.deadband < 0.0:
            raise ValueError("deadband must be >= 0")
        if self.gain <= 0.0:
            raise ValueError("gain must be > 0")
        if self.min_scale <= 0.0:
            raise ValueError("min_scale must be > 0")
        if self.max_scale < self.min_scale:
            raise ValueError("max_scale must be >= min_scale")
        if not (self.min_scale <= 1.0 <= self.max_scale):
            raise ValueError(
                "the clamp range must include 1.0 (the steady-state scale)"
            )
        if self.min_activity < 0.0:
            raise ValueError("min_activity must be >= 0")


@dataclasses.dataclass
class _SessionMoments:
    """Per-session controller memory: the two kurtosis EMAs, the observed
    tick count, and the last computed scale (cached so policy sweeps can
    read it without re-observing)."""

    fast: float
    slow: float
    ticks: int = 1
    scale: float = 1.0


class MomentController:
    """Per-session EMA kurtosis → μ multiplier (see the module docstring).

    ``count`` is N, the number of entries in one stream's logical Y block
    (P·n) — the normalizer that turns the raw sums into the kurtosis
    statistic.  ``observe`` ingests one tick's [Σy², Σy⁴] telemetry for a
    session and returns the session's new μ scale; ``scale`` reads the
    cached value without observing; ``forget`` drops a session (eviction).
    State round-trips checkpoints via ``state_dict``/``load_state_dict``
    (plain JSON-able floats).
    """

    def __init__(self, policy: MomentPolicy, count: int) -> None:
        if count < 1:
            raise ValueError("count (logical P*n) must be >= 1")
        self.policy = policy
        self.count = int(count)
        self._sessions: Dict[object, _SessionMoments] = {}

    # -- telemetry ingestion ----------------------------------------------
    def kurtosis(self, s2: float, s4: float) -> Optional[float]:
        """``κ = N·Σy⁴/(Σy²)²`` or None for a tick with no usable signal
        (below the activity floor, or non-finite telemetry)."""
        s2 = float(s2)
        s4 = float(s4)
        if not (s2 > self.policy.min_activity):  # also rejects NaN
            return None
        kappa = self.count * s4 / (s2 * s2)
        if not (kappa > 0.0 and kappa == kappa and kappa != float("inf")):
            return None
        return kappa

    def observe(self, session_id, s2: float, s4: float) -> float:
        """Fold one tick's raw moments for ``session_id``; returns the
        session's μ multiplier (1.0 during warmup / without signal)."""
        kappa = self.kurtosis(s2, s4)
        mem = self._sessions.get(session_id)
        if kappa is None:
            return mem.scale if mem is not None else 1.0
        pol = self.policy
        if mem is None:
            # first usable tick seeds both EMAs — deviation starts at 0
            mem = _SessionMoments(fast=kappa, slow=kappa)
            self._sessions[session_id] = mem
            return 1.0
        mem.fast += pol.ema_fast * (kappa - mem.fast)
        mem.slow += pol.ema_slow * (kappa - mem.slow)
        mem.ticks += 1
        mem.scale = self._scale_from(mem)
        return mem.scale

    def _scale_from(self, mem: _SessionMoments) -> float:
        pol = self.policy
        if mem.ticks < pol.warmup_ticks:
            return 1.0
        if mem.fast <= 0.0 or mem.slow <= 0.0:
            return 1.0
        ratio = mem.slow / mem.fast  # >1 ⟺ kurtosis collapsed under drift
        dev = max(ratio, 1.0 / ratio) if pol.symmetric else ratio
        if abs(dev - 1.0) <= pol.deadband:
            return 1.0
        scaled = dev**pol.gain
        return min(max(scaled, pol.min_scale), pol.max_scale)

    # -- reads / lifecycle -------------------------------------------------
    def scale(self, session_id) -> float:
        mem = self._sessions.get(session_id)
        return mem.scale if mem is not None else 1.0

    def estimate(self, session_id) -> Optional[Tuple[float, float]]:
        """The session's (fast, slow) kurtosis EMAs, or None if unseen."""
        mem = self._sessions.get(session_id)
        return (mem.fast, mem.slow) if mem is not None else None

    def forget(self, session_id) -> None:
        self._sessions.pop(session_id, None)

    def reset(self, session_id) -> None:
        """Drop the session's EMAs but keep serving it: the next usable tick
        re-seeds both from scratch (used after rollback/re-admission, where
        the old reference no longer describes the restored separator)."""
        self.forget(session_id)

    def __len__(self) -> int:
        return len(self._sessions)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able snapshot (keys stringified exactly like the service's
        other per-session lifecycle maps)."""
        return {
            str(sid): {
                "fast": float(m.fast),
                "slow": float(m.slow),
                "ticks": int(m.ticks),
                "scale": float(m.scale),
            }
            for sid, m in self._sessions.items()
        }

    def load_state_dict(self, blob: dict, key_map=None) -> None:
        """Inverse of ``state_dict``.  ``key_map`` (optional) maps the
        stringified keys back to live session ids (the service resolves
        them against its roster on restore); unmapped entries are kept
        under their string key."""
        self._sessions = {}
        for key, m in (blob or {}).items():
            sid = key_map.get(key, key) if key_map else key
            self._sessions[sid] = _SessionMoments(
                fast=float(m["fast"]),
                slow=float(m["slow"]),
                ticks=int(m.get("ticks", 1)),
                scale=float(m.get("scale", 1.0)),
            )
