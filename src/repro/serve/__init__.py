"""Serving: LM decode engine + the drift-aware separation pipeline.

Public API of the separation side (the paper's "deployment in hardware"
mandate, grown into an end-to-end adaptive service):

  * ``SeparationService``   — continuous-batching front door for a
    ``stream.SeparatorBank``: admission, scheduling, convergence lifecycle,
    drift watchdog, fault containment, ``run_tick()`` pull ingestion.
  * ``ConvergencePolicy`` / ``ConvergenceMonitor`` — when is a session done.
  * ``DriftPolicy`` / ``DriftMonitor`` / ``DriftEvent`` — when has a done
    session drifted, and what to do about it (μ boost / warm re-admission).
  * ``HealthPolicy`` / ``HealthMonitor`` / ``HealthEvent`` — when has a
    session gone BAD (in-kernel health word: non-finite state / blow-up),
    and the escalation ladder: rollback-to-shadow + μ cut → quarantine →
    evict ``"diverged"``.
  * ``MomentPolicy`` / ``MomentController`` — moment-scaled adaptive μ over
    the bank's in-kernel kurtosis telemetry (``SeparatorBank(moments=True)``):
    fast/slow EMA kurtosis per session, μ × clamp(deviation^gain), annealing
    as re-convergence pulls the estimate home.  Composition with the other μ
    writers is pinned: a health μ-cut wins while live; drift boost and the
    controller multiply.
  * ``AdmissionScheduler`` (FIFO) / ``PriorityScheduler`` /
    ``DeadlineScheduler`` + ``SessionMeta`` — who waits, who activates.
  * ``AutoscalePolicy`` / ``ResizeDecision`` — telemetry-driven elastic
    capacity: the service grows/shrinks/compacts its bank from queue depth
    and deadline-miss pressure (hysteresis bands + cooldown; see
    ``serve.elastic`` and ``SeparationService.grow``/``shrink``/``compact``).
  * ``SLOPolicy`` / ``DeadlineMonitor`` / ``SLOEvent`` / ``LatencySketch`` /
    ``TickTimer`` + ``slo.replay`` — real-time budgets over TIME-TO-READY
    tick latency (p50/p99/p999, deadline misses, shed/gate load control) and
    deterministic replay of recorded loads (``data.sources.RecordingSource``
    → ``save_recording``/``load_recording``).
  * ``EvictionRecord`` / ``ParkedSession`` / ``QuarantinedSession`` — what
    leaves a slot carries.

Signal feeds (``data.sources``): bind a ``SignalSource`` at ``admit`` time
and drive the whole pipeline with ``run_tick()``.  Flaky feeds wrap in
``data.resilience.ResilientSource`` (bounded retry/backoff/stall-timeout);
``data.resilience.FaultInjector`` is the chaos-test harness.
"""
from repro.serve.drift import DriftEvent, DriftMonitor, DriftPolicy
from repro.serve.elastic import AutoscalePolicy, ResizeDecision
from repro.serve.engine import (
    ConvergenceMonitor,
    ConvergencePolicy,
    Engine,
    EvictionRecord,
    ParkedSession,
    QuarantinedSession,
    SeparationService,
    ServeConfig,
    SessionStats,
)
from repro.serve.health import HealthEvent, HealthMonitor, HealthPolicy
from repro.serve.moments import MomentController, MomentPolicy
from repro.serve.scheduling import (
    AdmissionScheduler,
    DeadlineScheduler,
    PriorityScheduler,
    SchedulerContext,
    SessionMeta,
)
from repro.serve.slo import (
    DeadlineMonitor,
    LatencySketch,
    SLOEvent,
    SLOPolicy,
    TickTimer,
    replay,
)

__all__ = [
    "AdmissionScheduler",
    "AutoscalePolicy",
    "ConvergenceMonitor",
    "ConvergencePolicy",
    "DeadlineMonitor",
    "DeadlineScheduler",
    "DriftEvent",
    "DriftMonitor",
    "DriftPolicy",
    "Engine",
    "EvictionRecord",
    "HealthEvent",
    "HealthMonitor",
    "HealthPolicy",
    "LatencySketch",
    "MomentController",
    "MomentPolicy",
    "ParkedSession",
    "PriorityScheduler",
    "QuarantinedSession",
    "ResizeDecision",
    "SLOEvent",
    "SLOPolicy",
    "SchedulerContext",
    "SeparationService",
    "ServeConfig",
    "SessionMeta",
    "SessionStats",
    "TickTimer",
    "replay",
]
