"""Serving: LM decode engine + the drift-aware separation pipeline.

Public API of the separation side (the paper's "deployment in hardware"
mandate, grown into an end-to-end adaptive service):

  * ``SeparationService``   — continuous-batching front door for a
    ``stream.SeparatorBank``: admission, scheduling, convergence lifecycle,
    drift watchdog, ``run_tick()`` pull ingestion.
  * ``ConvergencePolicy`` / ``ConvergenceMonitor`` — when is a session done.
  * ``DriftPolicy`` / ``DriftMonitor`` / ``DriftEvent`` — when has a done
    session drifted, and what to do about it (μ boost / warm re-admission).
  * ``AdmissionScheduler`` (FIFO) / ``PriorityScheduler`` /
    ``DeadlineScheduler`` + ``SessionMeta`` — who waits, who activates.
  * ``EvictionRecord`` / ``ParkedSession`` — what leaves a slot carries.

Signal feeds (``data.sources``): bind a ``SignalSource`` at ``admit`` time
and drive the whole pipeline with ``run_tick()``.
"""
from repro.serve.drift import DriftEvent, DriftMonitor, DriftPolicy
from repro.serve.engine import (
    ConvergenceMonitor,
    ConvergencePolicy,
    Engine,
    EvictionRecord,
    ParkedSession,
    SeparationService,
    ServeConfig,
    SessionStats,
)
from repro.serve.scheduling import (
    AdmissionScheduler,
    DeadlineScheduler,
    PriorityScheduler,
    SchedulerContext,
    SessionMeta,
)

__all__ = [
    "AdmissionScheduler",
    "ConvergenceMonitor",
    "ConvergencePolicy",
    "DeadlineScheduler",
    "DriftEvent",
    "DriftMonitor",
    "DriftPolicy",
    "Engine",
    "EvictionRecord",
    "ParkedSession",
    "PriorityScheduler",
    "SchedulerContext",
    "SeparationService",
    "ServeConfig",
    "SessionMeta",
    "SessionStats",
]
