"""Real-time SLO layer: time-to-ready tick timing, streaming latency
quantiles, deadline budgets, and deterministic load replay.

The paper's headline numbers are throughput and clock headroom — deployment
cares whether every tick lands inside a real-time budget (the CORTEX-style
harness of ROADMAP item 2: BCI kernels benchmarked under deadlines with
latency / jitter / deadline-miss telemetry and a recorded-stream replayer).
This module is that harness's measurement core; ``serve.SeparationService``
wires it into every tick:

  * ``TickTimer``     — the time-to-ready clock.  JAX dispatches
    asynchronously: ``perf_counter()`` around a jitted call measures enqueue
    latency, not compute.  The timer stops the clock only after a
    ``block_until_ready`` on a designated telemetry leaf (the service uses
    ``BankState.conv`` — a tiny ``(S,)`` float vector whose readiness implies
    the whole bank program retired), so tick latencies are real on any
    backend regardless of ``block_ticks``.  ``sync_every=k`` samples the sync
    1-in-k: only synced ticks are *timed* (fed to the sketch, deadline-
    checked, counted in ``mean_tick_s``); the k−1 unsynced ticks between them
    run dispatch-deep with no latency record at all — sampled mode trades
    telemetry density for zero sync overhead, never fabricates numbers.
  * ``LatencySketch`` — streaming p50/p99/p999 over tick latencies, two
    horizons at once: an exact sliding window (last ``window`` timed ticks,
    ``np.quantile`` on demand) and a bounded-memory lifetime histogram with
    log-spaced bins (HDR-style: relative error ≤ one bin width, ~2.6% at the
    default 90 bins/decade — tails keep their resolution however long the
    service runs).
  * ``SLOPolicy``     — the budget + escalation config: a per-tick
    ``deadline_budget_s`` (timed ticks over budget increment
    ``n_deadline_misses``), per-session miss tracking (``DeadlineMonitor``,
    the ``HealthMonitor``-style sliding window), and two load-control levers
    over the windowed miss rate: ``shed`` preempts the worst-missing session
    (reason ``"shed"``), ``gate_admissions`` holds backfills/direct
    admissions while the service is over its miss-rate ceiling.
  * ``SLOEvent``      — the observability record for shed/gate actions
    (``SeparationService.slo_events``; per-tick misses are counters + sketch
    entries, not events — a sustained overload must not grow a list).
  * ``replay``        — drives a service through a ``data.sources``
    ``Recording`` (admit each session at its recorded tick with its recorded
    scheduling metadata, ``run_tick`` until every recorded feed drains):
    the load test that turns a captured production stream into a
    reproducible SLO measurement (``benchmarks/stream_throughput.py --slo``).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Dict, Hashable, List, Optional, Tuple

import numpy as np


class LatencySketch:
    """Streaming quantiles over a latency series, windowed + lifetime.

    ``add`` is O(1): append to a bounded deque (the exact sliding window) and
    increment one bin of a log-spaced lifetime histogram covering
    ``[lo, hi)`` seconds with ``bins_per_decade`` bins per decade.  Lifetime
    quantiles return the geometric midpoint of the selected bin, so their
    relative error is bounded by the bin width (``10**(1/bins_per_decade) −
    1``, ~2.6% at the default 90) — memory stays a few KB forever, unlike
    keeping every sample.  Windowed quantiles are exact ``np.quantile`` over
    the retained samples.  Samples outside ``[lo, hi)`` clamp to the edge
    bins (their windowed quantiles stay exact)."""

    def __init__(
        self,
        window: int = 256,
        lo: float = 1e-6,
        hi: float = 1e3,
        bins_per_decade: int = 90,
    ):
        if window < 1:
            raise ValueError("window must be >= 1")
        if not (0.0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
        if bins_per_decade < 1:
            raise ValueError("bins_per_decade must be >= 1")
        self.window = int(window)
        self.lo = float(lo)
        self.hi = float(hi)
        self.bins_per_decade = int(bins_per_decade)
        n_decades = math.log10(hi / lo)
        self._n_bins = max(1, int(math.ceil(n_decades * bins_per_decade)))
        self._counts = np.zeros((self._n_bins,), dtype=np.int64)
        self._recent: collections.deque = collections.deque(maxlen=window)
        self._n = 0

    def _bin_of(self, x: float) -> int:
        if x <= self.lo:
            return 0
        idx = int(math.log10(x / self.lo) * self.bins_per_decade)
        return min(max(idx, 0), self._n_bins - 1)

    def add(self, x: float) -> None:
        x = float(x)
        if math.isnan(x):
            return  # a clock anomaly must not poison the tail quantiles
        self._recent.append(x)
        self._counts[self._bin_of(x)] += 1
        self._n += 1

    @property
    def count(self) -> int:
        """Lifetime samples folded in."""
        return self._n

    @property
    def window_count(self) -> int:
        return len(self._recent)

    def quantile(self, q: float) -> float:
        """Lifetime quantile (log-binned; relative error ≤ one bin width)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self._n == 0:
            return float("nan")
        # rank of the q-th sample (nearest-rank), found by cumulative count
        rank = min(max(int(math.ceil(q * self._n)), 1), self._n)
        cum = np.cumsum(self._counts)
        b = int(np.searchsorted(cum, rank))
        edge_lo = self.lo * 10.0 ** (b / self.bins_per_decade)
        edge_hi = self.lo * 10.0 ** ((b + 1) / self.bins_per_decade)
        return math.sqrt(edge_lo * edge_hi)

    def window_quantile(self, q: float) -> float:
        """Exact quantile over the last ``window`` samples."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if not self._recent:
            return float("nan")
        return float(np.quantile(np.asarray(self._recent), q))

    def summary(self) -> Dict[str, float]:
        """The metrics-surface view: windowed p50/p99/p999 (exact) plus their
        lifetime twins (``*_life``, log-binned)."""
        return {
            "p50_tick_s": self.window_quantile(0.50),
            "p99_tick_s": self.window_quantile(0.99),
            "p999_tick_s": self.window_quantile(0.999),
            "p50_tick_s_life": self.quantile(0.50),
            "p99_tick_s_life": self.quantile(0.99),
            "p999_tick_s_life": self.quantile(0.999),
        }

    def reset(self) -> None:
        self._counts[:] = 0
        self._recent.clear()
        self._n = 0


class TickTimer:
    """Time-to-ready tick clock with 1-in-k sampled sync.

    ``start()`` stamps the dispatch; ``stop(sync_leaf=...)`` blocks on the
    designated telemetry leaf when this tick is *due* for a sync (every tick
    at the default ``sync_every=1``; every k-th tick otherwise) and returns
    ``(dt, timed)``.  ``timed=False`` means the clock stopped at dispatch —
    the caller must NOT record ``dt`` as a latency (sampled-out ticks carry
    no latency information, by design).  A caller that already synchronized
    (``block_ticks=True``) passes ``already_synced=True``: the tick is timed
    without a second block, and the sampling cadence still advances."""

    def __init__(self, sync_every: int = 1):
        if sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        self.sync_every = int(sync_every)
        self._n = 0  # ticks observed (drives the 1-in-k cadence)
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, sync_leaf=None, already_synced: bool = False) -> Tuple[float, bool]:
        if self._t0 is None:
            raise RuntimeError("stop() without start()")
        due = already_synced or (self._n % self.sync_every == 0)
        self._n += 1
        timed = already_synced
        if due and not already_synced and sync_leaf is not None:
            import jax  # deferred: the sketch/policy side stays jax-free

            jax.block_until_ready(sync_leaf)
            timed = True
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return dt, timed


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Latency-SLO configuration for ``SeparationService``.

    Telemetry (the sketch + time-to-ready sync) is always on; a policy is
    attached by default.  ``deadline_budget_s`` arms the deadline machinery:
    every *timed* tick over budget increments ``n_deadline_misses``, stamps
    each served session's ``DeadlineMonitor``, and feeds the windowed miss
    rate (last ``miss_window`` timed ticks).  Load control is opt-in:

      * ``shed=True`` — when a miss lands while the windowed miss rate
        exceeds ``max_miss_rate``, preempt the active session with the most
        window-resident misses (reason ``"shed"``; ties broken toward lower
        priority then younger admission), at most once per ``shed_cooldown``
        ticks.  Shed sessions land in ``finished`` with their state — the
        caller decides whether to re-admit when load subsides.
      * ``gate_admissions=True`` — while the rate is over the ceiling, free
        slots are NOT backfilled and direct admissions queue instead of
        activating: capacity drains until the window recovers.

    Both levers need a budget (they act on misses); arming them without one
    raises.  ``sync_every`` samples the time-to-ready sync 1-in-k (see
    ``TickTimer``); with k > 1 the deadline check inherits the sampling —
    only timed ticks can miss."""

    deadline_budget_s: Optional[float] = None
    sync_every: int = 1
    window: int = 256  # latency-sketch sliding window (timed ticks)
    miss_window: int = 64  # miss-rate window (timed ticks)
    max_miss_rate: float = 0.5  # shed/gate ceiling on the windowed rate
    shed: bool = False
    gate_admissions: bool = False
    shed_cooldown: int = 32  # min ticks between sheds (let the window react)

    def __post_init__(self) -> None:
        if self.deadline_budget_s is not None and self.deadline_budget_s <= 0:
            raise ValueError("deadline_budget_s must be > 0")
        if self.sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        if self.window < 1:
            raise ValueError("window must be >= 1")
        if self.miss_window < 1:
            raise ValueError("miss_window must be >= 1")
        if not (0.0 < self.max_miss_rate <= 1.0):
            raise ValueError("max_miss_rate must be in (0, 1]")
        if self.shed_cooldown < 1:
            raise ValueError("shed_cooldown must be >= 1")
        if (self.shed or self.gate_admissions) and self.deadline_budget_s is None:
            raise ValueError(
                "shed/gate_admissions act on deadline misses: set "
                "deadline_budget_s to arm them"
            )


@dataclasses.dataclass
class DeadlineMonitor:
    """Per-session streaming deadline record (host-side,
    ``dataclasses.asdict``-serializable — the ``HealthMonitor`` idiom).

    ``recent`` holds the service-tick stamps of misses still inside the
    policy's ``miss_window``; ``served``/``misses`` are lifetime counters.
    The windowed count returned by ``record`` is what the shed victim
    selection ranks on — the session present during the most recent misses
    is the one whose work is (probabilistically) blowing the budget."""

    served: int = 0
    misses: int = 0
    recent: List[int] = dataclasses.field(default_factory=list)

    def record(self, tick: int, missed: bool, policy: SLOPolicy) -> int:
        """Fold one timed tick in; returns the window-resident miss count."""
        self.served += 1
        self.recent = [
            t for t in self.recent if tick - t < policy.miss_window
        ]
        if missed:
            self.misses += 1
            self.recent.append(int(tick))
        return len(self.recent)


@dataclasses.dataclass
class SLOEvent:
    """One load-control action: who (``None`` = service-wide), when, the
    latency/budget that triggered it, what we did (``"shed"`` — a session
    preempted; ``"gate"`` — backfill held while over the ceiling), and the
    windowed miss rate at the time."""

    session_id: Optional[Hashable]
    tick: int
    tick_s: float
    budget_s: float
    action: str
    miss_rate: float = 0.0


def replay(
    svc,
    recording,
    extra_ticks: int = 0,
    max_ticks: int = 100_000,
) -> List[Dict]:
    """Drive ``svc`` through a recorded load, deterministically.

    ``recording`` is a ``data.sources.Recording`` (``load_recording``): each
    session is admitted at its recorded admit tick with its recorded
    scheduling metadata, bound to its ``RecordedSource``, and served via
    ``run_tick`` until every recorded feed drains (drained feeds evict with
    reason ``"exhausted"``, exactly like the live run) — plus ``extra_ticks``
    trailing ticks for probe/queue settling.  Returns the per-tick output
    dicts, so a replay is comparable block-for-block against the live run it
    was captured from.  Recordings without admit events admit every session
    before the first tick."""
    events = [
        dict(e) for e in (recording.events or []) if e.get("action") == "admit"
    ]
    if not events:
        events = [{"sid": sid, "tick": 0} for sid in recording.sources]
    pending = sorted(
        events, key=lambda e: (int(e.get("tick", 0)), e.get("order", 0))
    )
    missing = [e["sid"] for e in pending if e["sid"] not in recording.sources]
    if missing:
        raise ValueError(f"admit events for unrecorded sessions: {missing}")
    outputs: List[Dict] = []
    settle = 0
    for tick in range(max_ticks):
        while pending and int(pending[0].get("tick", 0)) <= tick:
            e = pending.pop(0)
            svc.admit(
                e["sid"],
                source=recording.sources[e["sid"]],
                tenant=e.get("tenant"),
                priority=float(e.get("priority", 0.0)),
                deadline=e.get("deadline"),
            )
        outputs.append(svc.run_tick())
        done = (
            not pending
            and svc.n_active == 0
            and svc.n_queued == 0
            and not svc.parked
            and not svc.quarantined
        )
        if done:
            settle += 1
            if settle > extra_ticks:
                break
        else:
            settle = 0
    return outputs
