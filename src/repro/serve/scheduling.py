"""Pluggable admission scheduling for ``serve.SeparationService``.

PR-3's bounded FIFO queue treated every waiting session identically; real
multi-tenant serving wants *policy* between "bank is full" and "who activates
next".  This module factors the queue into an ``AdmissionScheduler`` object
the service delegates to:

  * ``AdmissionScheduler``  — the base class IS the FIFO policy (insertion
    order, unconditional activation) — exactly PR-3's behavior, so a service
    built with ``max_queue=`` alone is unchanged.
  * ``PriorityScheduler``   — strict priority (higher first; FIFO within a
    priority level) with optional per-tenant quotas on ACTIVE sessions: a
    tenant at quota is skipped at pop time *and* blocked from direct
    admission into a free slot (its sessions queue until an own slot frees).
  * ``DeadlineScheduler``   — earliest-deadline-first over the ``deadline``
    field of ``SessionMeta`` (deadline-less sessions sort last, FIFO among
    themselves).

The scheduler owns ONLY the waiting room.  The service asks two questions:
``can_activate(meta, ctx)`` ("may this session take a free slot right now?")
and ``pop(ctx)`` ("who activates into the slot that just freed?").  ``ctx``
carries the live view (tick counter + active sessions' metadata) so policies
can reason about occupancy without reaching into the service.

Scheduler state is JSON-able (``snapshot``/``load``) and rides the service's
``lifecycle`` checkpoint snapshot, so a restored service resumes the same
queue — order, priorities, deadlines and all.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Hashable, List, Optional, Tuple


@dataclasses.dataclass
class SessionMeta:
    """Scheduling metadata carried per session (active or queued).

    ``order`` is the service-assigned admission sequence number — the FIFO
    tiebreak every policy falls back to, so scheduling is deterministic.
    """

    tenant: Optional[str] = None
    priority: float = 0.0
    deadline: Optional[float] = None
    order: int = 0

    def asdict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SchedulerContext:
    """Live view handed to scheduling decisions: the service tick counter,
    the metadata of currently ACTIVE sessions (slot holders), and the
    service's windowed deadline-miss rate (0.0 when no ``SLOPolicy`` budget
    is armed — see ``serve.slo``; custom policies can use it to hold or
    reorder admissions under latency pressure, the way the built-in SLO
    admission gate holds backfills)."""

    tick: int
    active: Dict[Hashable, SessionMeta]
    deadline_miss_rate: float = 0.0

    def active_per_tenant(self) -> Dict[Optional[str], int]:
        counts: Dict[Optional[str], int] = collections.Counter()
        for meta in self.active.values():
            counts[meta.tenant] += 1
        return counts


class AdmissionScheduler:
    """Bounded FIFO waiting room — the base class is the default policy.

    Subclasses override ``_rank`` (pop order) and/or ``can_activate``
    (admission gating); the bookkeeping (bounded capacity, membership,
    snapshots) is shared.
    """

    def __init__(self, max_queue: int = 0):
        if max_queue < 0:
            raise ValueError("max_queue must be >= 0")
        self.max_queue = max_queue
        self._entries: "collections.OrderedDict[Hashable, SessionMeta]" = (
            collections.OrderedDict()
        )

    # -- policy hooks ------------------------------------------------------
    def _rank(self, sid: Hashable, meta: SessionMeta) -> Tuple:
        """Sort key: the LOWEST-ranked eligible entry pops first."""
        return (meta.order,)

    def can_activate(self, meta: SessionMeta, ctx: SchedulerContext) -> bool:
        """May a session with ``meta`` take a free slot right now?  Applies
        both to direct admissions and to queue pops."""
        return True

    # -- waiting-room bookkeeping -----------------------------------------
    @property
    def full(self) -> bool:
        return len(self._entries) >= self.max_queue

    def push(self, sid: Hashable, meta: SessionMeta) -> None:
        if sid in self._entries:
            raise ValueError(f"session {sid!r} already queued")
        if self.full:
            raise RuntimeError(
                f"admission queue full ({len(self._entries)}/{self.max_queue})"
            )
        self._entries[sid] = meta

    def pop(self, ctx: SchedulerContext) -> Optional[Tuple[Hashable, SessionMeta]]:
        """Best eligible waiting ``(sid, meta)`` (or ``None`` — e.g. every
        queued tenant is at quota; the slot stays free and the service
        retries at the next release/tick)."""
        best = None
        for sid, meta in self._entries.items():
            if not self.can_activate(meta, ctx):
                continue
            if best is None or self._rank(sid, meta) < self._rank(*best):
                best = (sid, meta)
        if best is None:
            return None
        del self._entries[best[0]]
        return best

    def has_eligible(self, ctx: SchedulerContext) -> bool:
        """Would ``pop`` return a session right now?  (Used by the service
        to decide whether a waiting admission justifies evicting a hot
        session — a fully gated queue does not.)"""
        return any(
            self.can_activate(meta, ctx) for meta in self._entries.values()
        )

    def remove(self, sid: Hashable) -> bool:
        return self._entries.pop(sid, None) is not None

    def meta_of(self, sid: Hashable) -> SessionMeta:
        return self._entries[sid]

    def ids(self) -> Tuple[Hashable, ...]:
        """Queued ids in pop order (ignoring eligibility gates)."""
        ranked = sorted(self._entries.items(), key=lambda kv: self._rank(*kv))
        return tuple(sid for sid, _ in ranked)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, sid: Hashable) -> bool:
        return sid in self._entries

    # -- persistence -------------------------------------------------------
    def snapshot(self) -> List:
        """JSON-able queue contents in insertion order: ``[[sid, meta], ...]``
        (hashable sids must themselves be JSON-able, as in PR-3)."""
        return [[sid, meta.asdict()] for sid, meta in self._entries.items()]

    def load(self, entries: List) -> None:
        """Restore queue contents from ``snapshot()`` output — also accepts
        the PR-3 plain-sid list (metadata defaults).  Replaces the current
        contents; capacity is NOT re-checked (the snapshot was legal when
        taken, and restores must not drop sessions)."""
        self._entries.clear()
        for entry in entries:
            if isinstance(entry, (list, tuple)) and len(entry) == 2 and isinstance(entry[1], dict):
                sid, meta = entry[0], SessionMeta(**entry[1])
            else:
                sid, meta = entry, SessionMeta()
            self._entries[sid] = meta


class PriorityScheduler(AdmissionScheduler):
    """Strict priority with per-tenant quotas on active sessions.

    ``quotas`` maps tenant → max simultaneously ACTIVE sessions; ``default
    _quota`` applies to tenants not listed (``None`` = unlimited).  A session
    whose tenant is at quota neither takes a free slot at admission nor pops
    from the queue — it waits for one of its own tenant's slots, however many
    bank slots are free (the noisy-neighbour fence)."""

    def __init__(
        self,
        max_queue: int = 0,
        quotas: Optional[Dict[Optional[str], int]] = None,
        default_quota: Optional[int] = None,
    ):
        super().__init__(max_queue)
        self.quotas = dict(quotas or {})
        self.default_quota = default_quota

    def _rank(self, sid: Hashable, meta: SessionMeta) -> Tuple:
        return (-meta.priority, meta.order)

    def can_activate(self, meta: SessionMeta, ctx: SchedulerContext) -> bool:
        quota = self.quotas.get(meta.tenant, self.default_quota)
        if quota is None:
            return True
        return ctx.active_per_tenant().get(meta.tenant, 0) < quota


class DeadlineScheduler(AdmissionScheduler):
    """Earliest-deadline-first: the queued session with the smallest
    ``deadline`` (service-tick units by convention) pops first; sessions
    without a deadline rank after every dated one, FIFO among themselves.

    Pairs with the per-tick latency budget (``SLOPolicy.deadline_budget_s``):
    EDF orders WHO activates while the budget judges whether ticks are
    landing on time — under sustained misses the service sheds/gates
    (``serve.slo``) and re-admissions flow back through this ranking, so the
    tightest-deadline work reclaims capacity first."""

    def _rank(self, sid: Hashable, meta: SessionMeta) -> Tuple:
        dated = meta.deadline is not None
        return (0 if dated else 1, meta.deadline if dated else 0.0, meta.order)
