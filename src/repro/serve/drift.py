"""Drift watchdog: automatic re-adaptation on distribution shift.

The paper's whole case for *adaptive* ICA is tracking non-stationary mixing —
yet a convergence-aware service (PR-3) converges a session once, evicts it,
and happily serves the stale separator forever after.  The other tail of the
``BankState.conv`` statistic flags exactly this: a separator whose relative
update magnitude ``‖ΔB‖_F/‖B‖_F`` *rises* again after convergence is seeing
its mixing drift (arXiv:2509.15127 motivates the response: scale the
effective step size up when the input statistics shift).

``DriftPolicy`` configures the watchdog ``SeparationService`` runs over that
statistic; ``DriftMonitor`` is the per-session streaming state (EMA + rise
counter — the mirror image of ``ConvergenceMonitor``); ``DriftEvent`` is the
observability record handed to ``on_drift`` callbacks and kept in
``SeparationService.drift_events``.

Two response modes:
  * ``mode="boost"``   — converged sessions stay HOT: they keep their bank
    slot (status ``"converged"``), keep being served, and the watchdog reads
    their live conv statistic.  On re-trigger the session returns to ACTIVE
    with its per-stream μ multiplied by ``boost`` for ``boost_ticks`` ticks
    (through the megakernel's per-stream ``BankHyperparams`` rows — no
    retrace, the hyperparams are a traced operand).  Hot sessions are
    preemptible: a waiting admission evicts the most-converged hot session,
    so keeping sessions warm never starves the queue.
  * ``mode="readmit"`` — converged sessions evict normally (the slot frees
    for the queue) but sessions with a bound ``SignalSource`` are PARKED:
    every ``probe_every`` ``run_tick``s the watchdog pulls one block from the
    parked source and computes the *virtual* conv statistic — the update
    magnitude a bank step WOULD have committed from the frozen state (same
    formula, out of band, no slot occupied).  On re-trigger the session is
    re-admitted through the scheduler, warm-started from its frozen state.

Probe execution: the due parked sessions are probed in BATCHES — their frozen
states are stacked into a transient probe bank and all virtual conv
statistics of one batch come out of a single launch (``probe_batch`` sessions
per launch; ragged tails are padded and masked inactive), so the watchdog
costs O(parked / probe_batch) dispatches per probe tick instead of O(parked).
``probe_batch=0`` selects the legacy PR-4 per-session loop — one jitted
dispatch per parked session — kept as the reference the batched engine is
differentially property-tested against (tests/test_probe.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Hashable, Optional


@dataclasses.dataclass(frozen=True)
class DriftPolicy:
    """When has a converged separator drifted, and what do we do about it?

    The watchdog fires for a session at the first observation where ALL of:
      * at least ``cooldown`` observations have passed since the watch began
        (the statistic needs a few ticks to settle at its converged floor),
      * the (EMA-smoothed when ``ema > 0``) statistic has been ABOVE
        ``retrigger`` for ``patience`` consecutive observations.

    ``retrigger`` must sit above the converged jitter floor (the statistic
    never reaches 0 under stochastic mini-batches) — calibrate it a few ×
    above the ``ConvergencePolicy.threshold`` that declared convergence.
    """

    retrigger: float = 0.05  # EMA conv must RISE past this ...
    patience: int = 2  # ... for this many consecutive observations
    ema: float = 0.0  # smoothing: s' = ema·s + (1−ema)·x (0 → raw)
    cooldown: int = 3  # observations ignored right after the watch starts
    mode: str = "boost"  # "boost" (keep hot, μ boost) | "readmit" (park+probe)
    boost: float = 4.0  # μ multiplier applied on re-trigger (boost mode)
    boost_ticks: int = 50  # ticks the boost lasts before μ returns to base
    probe_every: int = 10  # run_tick period of parked-session probes (readmit)
    probe_batch: int = 64  # parked sessions per probe launch (0 = sequential)
    # Probe-phase staggering: parked sessions hash (stably, by session id)
    # into ``probe_phases`` buckets and only ONE bucket is due per probe
    # tick, rotating round-robin — a large parked population amortizes its
    # probe cost over ``probe_phases`` ticks instead of stalling one tick
    # with the whole sweep.  Each session is still probed with the same
    # PERIOD in run_ticks (``probe_every * probe_phases``) and the seek-past
    # skip accounts for it, so the probe still measures the present.
    # ``probe_phases=1`` (default) is exactly the legacy everyone-at-once
    # behavior.
    probe_phases: int = 1  # stagger buckets (1 = probe all parked at once)

    def __post_init__(self) -> None:
        if self.mode not in ("boost", "readmit"):
            raise ValueError(f"mode must be 'boost' or 'readmit', got {self.mode!r}")
        if self.patience < 1:
            raise ValueError("patience must be >= 1")
        if not (0.0 <= self.ema < 1.0):
            raise ValueError("ema must be in [0, 1)")
        if self.retrigger <= 0:
            raise ValueError("retrigger must be > 0")
        if self.boost <= 0:
            raise ValueError("boost must be > 0")
        if self.probe_every < 1:
            raise ValueError("probe_every must be >= 1")
        if self.probe_batch < 0:
            raise ValueError("probe_batch must be >= 0 (0 = sequential probes)")
        if self.probe_phases < 1:
            raise ValueError("probe_phases must be >= 1")


@dataclasses.dataclass
class DriftMonitor:
    """Per-session streaming state of the drift decision (host-side,
    ``dataclasses.asdict``-serializable — rides ``lifecycle`` snapshots).

    The EMA recurrence is the same inf-aware update as
    ``ConvergenceMonitor``/``core.metrics.ema_update``: the +inf "unmeasured"
    init is replaced by the first observation instead of poisoning the
    average."""

    stat: float = float("inf")  # EMA-smoothed statistic (raw when ema == 0)
    above: int = 0  # consecutive observations with stat > retrigger
    seen: int = 0  # observations since the watch started (cooldown floor)
    skipped: int = 0  # NaN samples dropped (faulted probes never poison)

    def update(self, x: float, policy: DriftPolicy) -> bool:
        """Fold one observation in; returns True when the watchdog fires.
        NaN observations (a faulted probe block) are skipped-and-counted —
        they neither advance the cooldown nor reset the rise streak."""
        if math.isnan(x):
            self.skipped += 1
            return False
        if policy.ema and math.isfinite(self.stat):
            self.stat = policy.ema * self.stat + (1.0 - policy.ema) * x
        else:
            self.stat = x
        self.seen += 1
        if self.seen <= policy.cooldown:
            self.above = 0
            return False
        self.above = self.above + 1 if self.stat > policy.retrigger else 0
        return self.above >= policy.patience


@dataclasses.dataclass
class DriftEvent:
    """One watchdog firing: who drifted, when, how hard, and the response.

    ``action`` is ``"boost"`` (kept hot, μ boosted in place) or ``"readmit"``
    (parked session re-admitted through the scheduler, warm-started).
    ``slot`` is the bank slot for in-place actions, ``None`` for re-admissions
    that landed on the queue."""

    session_id: Hashable
    tick: int
    stat: float
    action: str
    slot: Optional[int] = None
