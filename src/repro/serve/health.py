"""Fault containment: health-word policy, escalation ladder, observability.

The megakernel folds a per-stream *health word* into every tick (one more
in-register reduction next to ``conv`` — see ``kernels.easi_gradient``):
non-finite ``B′``/``Ĥ′``/``Y`` bits plus a relative-update blow-up bit.  An
unhealthy stream's commit is already REFUSED in-kernel (the slot keeps its
pre-tick state, exactly like the active-mask freeze), so by the time the host
reads the word nothing is corrupted — containment is about what happens
*next*.  ``HealthPolicy`` configures the service's escalation ladder over
repeat offenders; ``HealthMonitor`` is the per-session streaming state;
``HealthEvent`` the observability record (``on_health`` callbacks,
``SeparationService.health_events``).

The escalation ladder (``SeparationService._apply_health``):

  1. **rollback** — first offense(s): the slot is rolled back to its
     last-known-good shadow snapshot (``SeparatorBank.restore_slot``; the
     shadow refreshes copy-on-healthy every ``shadow_every`` ticks) and the
     session's μ is cut by ``mu_cut`` for ``cut_ticks`` ticks through the
     same per-stream ``BankHyperparams`` traced-operand rows the drift
     watchdog's boost rides — no retrace.
  2. **quarantine** — more than ``max_rollbacks`` offenses inside a
     ``window``-tick sliding window: the session leaves its slot (freed for
     the queue) but is PARKED under health watch, probed out of band like
     drift-parked sessions (``probe_every`` run_ticks; the no-commit probe
     returns the VIRTUAL health word, so "still diverging" and "safe to
     resume" are distinguishable without committing anything).  After
     ``probation`` consecutive healthy probes it re-admits warm from its
     last-known-good state.
  3. **evict "diverged"** — more than ``max_quarantines`` quarantines: the
     session is evicted for good with an ``EvictionRecord`` carrying the
     provenance (reason ``"diverged"``; the final health word rides
     ``HealthMonitor.last_word`` in the lifecycle snapshot).

Input-side containment lives in ``data.resilience`` (``ResilientSource``
retry/backoff/stall-timeout wrapper, ``FaultInjector`` chaos harness); the
service isolates any per-session source failure to that session via the
active mask (degraded tick, not a failed launch).
"""
from __future__ import annotations

import dataclasses
from typing import Hashable, List, Optional


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Escalation policy over the per-stream health word.

    A non-zero health word on a served tick is one *offense*.  Offense tick
    stamps live in a sliding ``window``; while the count stays at or under
    ``max_rollbacks`` each offense costs a rollback + μ cut, past that the
    session is quarantined, and past ``max_quarantines`` quarantines it is
    evicted with reason ``"diverged"``.
    """

    max_rollbacks: int = 2  # offenses tolerated per window before quarantine
    window: int = 50  # ticks — how long an offense stays on the record
    mu_cut: float = 0.25  # μ multiplier applied after a rollback ...
    cut_ticks: int = 20  # ... for this many served ticks
    max_quarantines: int = 2  # quarantines tolerated before "diverged"
    probation: int = 3  # consecutive healthy probes to leave quarantine
    probe_every: int = 10  # run_tick period of quarantine probes
    shadow_every: int = 8  # ticks between copy-on-healthy shadow refreshes

    def __post_init__(self) -> None:
        if self.max_rollbacks < 0:
            raise ValueError("max_rollbacks must be >= 0")
        if self.window < 1:
            raise ValueError("window must be >= 1")
        if not (0.0 < self.mu_cut <= 1.0):
            raise ValueError("mu_cut must be in (0, 1]")
        if self.cut_ticks < 1:
            raise ValueError("cut_ticks must be >= 1")
        if self.max_quarantines < 0:
            raise ValueError("max_quarantines must be >= 0")
        if self.probation < 1:
            raise ValueError("probation must be >= 1")
        if self.probe_every < 1:
            raise ValueError("probe_every must be >= 1")
        if self.shadow_every < 1:
            raise ValueError("shadow_every must be >= 1")


@dataclasses.dataclass
class HealthMonitor:
    """Per-session streaming state of the escalation ladder (host-side,
    ``dataclasses.asdict``-serializable — rides ``lifecycle`` snapshots).

    ``offenses`` holds the service-tick stamps of rollbacks still inside the
    policy window; ``quarantines`` never resets (the ladder only escalates);
    ``healthy_streak`` counts consecutive healthy quarantine probes toward
    probation; ``last_word`` is the most recent non-zero health word (the
    provenance an eviction record points at)."""

    offenses: List[int] = dataclasses.field(default_factory=list)
    quarantines: int = 0
    healthy_streak: int = 0
    last_word: int = 0

    def record_offense(self, tick: int, word: int, policy: HealthPolicy) -> bool:
        """Fold one offense in; returns True when the ladder escalates past
        rollback (i.e. this offense overflows the window budget)."""
        self.last_word = int(word)
        self.healthy_streak = 0
        self.offenses = [
            t for t in self.offenses if tick - t < policy.window
        ]
        self.offenses.append(int(tick))
        return len(self.offenses) > policy.max_rollbacks


@dataclasses.dataclass
class HealthEvent:
    """One containment action: who, when, what the kernel saw, what we did.

    ``action`` is ``"rollback"`` (shadow restore + μ cut, in place),
    ``"quarantine"`` (slot freed, session parked under health probe),
    ``"release"`` (probation served, re-admitted warm) or ``"diverge"``
    (evicted for good, reason ``"diverged"``).  ``word`` is the health word
    that triggered it (``kernels.easi_gradient.ops.describe_health`` renders
    it); ``slot`` the bank slot for in-place actions."""

    session_id: Hashable
    tick: int
    word: int
    action: str
    slot: Optional[int] = None
