"""Batched serving engines: LM decode + multi-stream separation service.

Deployment counterpart of the trainer (the paper's "model creation, training
AND deployment in hardware" mandate).  Two engines share the
continuous-batching idiom (slot free-list; new sessions drop into freed slots
between steps):
  * ``Engine`` — LM serving: batched requests with per-request lengths,
    chunked prefill through ``decode_step`` semantics, greedy / temperature
    sampling,
  * ``SeparationService`` — ICA serving: admits/evicts separation *sessions*
    into the slots of a ``repro.stream.SeparatorBank``; every tick steps all
    live sessions with one fused bank program (the multi-stream analogue of
    the paper's single always-on FPGA datapath).

Session lifecycle state machine (``SeparationService``)::

        admit()                 admit() [no free slot]
           │                        │
           ▼                        ▼
        ACTIVE ◄── backfill ──── QUEUED ──── evict() ──► (dequeued, None)
           │                        ▲
           │  step(): conv stat     │ bounded by max_queue — a full queue
           │  < threshold for       │ raises (backpressure: the caller
           │  `patience` ticks      │ must retry / shed load)
           ▼                        │
        CONVERGED (auto-evict) ─────┘ freed slot backfilled from the queue
           │                          head IN THE SAME TICK
           ▼
        EVICTED — final ``SMBGDState`` + serving stats retained in
        ``finished`` (drain with ``pop_finished()``); manual ``evict()``
        takes the ACTIVE→EVICTED edge directly and returns the state.

Backpressure semantics: ``admit`` NEVER silently drops a session.  With a
free slot it activates immediately (returns the slot index); otherwise it
enqueues FIFO up to ``max_queue`` deep (returns ``None``) and past that
raises ``RuntimeError``.  Queued sessions hold no device state — their
separator is initialized at activation time, so the γ step-0 gate applies at
the tick they actually start, and a queued session cancelled via ``evict``
costs nothing.

Convergence detection rides the bank's in-kernel statistic
(``BankState.conv`` — relative update magnitude ``‖ΔB‖_F/‖B‖_F``, computed at
commit time inside the megakernel, so detection costs one (S,)-float host
read per tick, not a state round-trip).  ``ConvergencePolicy`` turns the raw
statistic into an eviction decision: optional EMA smoothing, a threshold the
smoothed statistic must stay under for ``patience`` consecutive data ticks,
a ``min_ticks`` floor, and an optional Amari-index confirmation for sessions
whose true mixing matrix was registered via ``set_mixing`` (the blind
statistic can dip early; the Amari check vetoes eviction until the separator
actually separates).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Callable, Deque, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import metrics as metrics_lib
from repro.core.smbgd import SMBGDState
from repro.models import model as M
from repro.stream.bank import BankState, SeparatorBank

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0  # 0 → greedy
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params: PyTree, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, s, b: M.decode_step(p, s, b, cfg)
        )
        self.state = M.init_serve_state(cfg, scfg.max_batch, scfg.max_len)
        self.key = jax.random.PRNGKey(scfg.seed)

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        logits = logits[:, -1]  # last position: (B, V), or (B, K, V) w/ codebooks
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits / self.scfg.temperature, axis=-1)

    def prefill_and_generate(
        self, prompts: jnp.ndarray, n_new: int
    ) -> Tuple[jnp.ndarray, List[float]]:
        """prompts: (B, T_prompt[, K]); returns (B, n_new[, K]) generated
        tokens (greedy/temperature).  Prefill is token-streamed through the
        recurrent state machinery — one code path for all families."""
        B, T = prompts.shape[0], prompts.shape[1]
        assert B == self.scfg.max_batch
        state = M.init_serve_state(self.cfg, B, self.scfg.max_len)
        logits = None
        for t in range(T):  # chunked prefill (chunk = 1 keeps it family-agnostic)
            tok = prompts[:, t : t + 1]
            logits, state = self._decode(self.params, state, {"tokens": tok})
        out = []
        tok = self._sample(logits)[:, None] if not self.cfg.n_codebooks else self._sample(logits)[:, None, :]
        for _ in range(n_new):
            out.append(tok)
            logits, state = self._decode(self.params, state, {"tokens": tok})
            tok = self._sample(logits)[:, None] if not self.cfg.n_codebooks else self._sample(logits)[:, None, :]
        self.state = state
        return jnp.concatenate(out, axis=1), []


@dataclasses.dataclass
class SessionStats:
    """Per-session serving counters (host-side bookkeeping)."""

    admitted_at: float  # time.perf_counter() at admission
    ticks: int = 0
    samples: int = 0

    def samples_per_s(self, now: Optional[float] = None) -> float:
        """Throughput since admission (wall-clock)."""
        now = time.perf_counter() if now is None else now
        return self.samples / max(now - self.admitted_at, 1e-9)


@dataclasses.dataclass(frozen=True)
class ConvergencePolicy:
    """When is a session done?  Threshold + patience + floor over the bank's
    in-step convergence statistic (``BankState.conv``), with optional EMA
    smoothing and an optional ground-truth Amari confirmation.

    A session auto-evicts at the first data tick where ALL of:
      * it has received at least ``min_ticks`` mini-batches,
      * its (EMA-smoothed when ``ema > 0``) update magnitude has been below
        ``threshold`` for ``patience`` consecutive data ticks,
      * if ``amari_threshold`` is set AND the session's mixing matrix was
        registered via ``SeparationService.set_mixing``: the Amari index of
        ``B·A`` is below ``amari_threshold`` (unknown mixing → the blind
        statistic alone decides).
    """

    threshold: float = 1e-3  # conv stat must stay under this ...
    patience: int = 3  # ... for this many consecutive data ticks
    min_ticks: int = 8  # never evict younger sessions (γ warm-up)
    ema: float = 0.0  # smoothing: s' = ema·s + (1−ema)·x (0 → raw)
    amari_threshold: Optional[float] = None  # optional ground-truth gate

    def __post_init__(self) -> None:
        if self.patience < 1:
            raise ValueError("patience must be >= 1")
        if not (0.0 <= self.ema < 1.0):
            raise ValueError("ema must be in [0, 1)")


@dataclasses.dataclass
class ConvergenceMonitor:
    """Per-session streaming state of the convergence decision (host-side;
    serializable via ``dataclasses.asdict`` for checkpoint round-trips).

    Carries its own data-tick counter so the ``min_ticks`` floor survives a
    checkpoint round-trip exactly (``SessionStats`` deliberately restarts its
    counters at restore — observability describes the restored epoch, the
    convergence decision must not).  The EMA recurrence is the host-side
    twin of ``core.metrics.ema_update`` (kept in plain Python floats — this
    runs per served session per tick; a parity test pins the two)."""

    stat: float = float("inf")  # EMA-smoothed statistic (raw when ema == 0)
    below: int = 0  # consecutive data ticks with stat < threshold
    ticks: int = 0  # data ticks observed (min_ticks floor)

    def update(self, x: float, policy: ConvergencePolicy) -> None:
        if policy.ema and math.isfinite(self.stat):
            self.stat = policy.ema * self.stat + (1.0 - policy.ema) * x
        else:
            self.stat = x
        self.below = self.below + 1 if self.stat < policy.threshold else 0
        self.ticks += 1


@dataclasses.dataclass
class EvictionRecord:
    """What the service hands back (or retains) when a session leaves a slot.

    The evicted ``SMBGDState`` is sliced out of the bank *before* the slot is
    re-initialized for a backfill, so ``state`` is exactly the session's state
    at eviction time; ``stats``/``monitor`` preserve the per-session serving
    counters across the eviction (the churn observability surface).
    """

    state: SMBGDState
    stats: SessionStats
    monitor: Optional[ConvergenceMonitor]
    reason: str  # "converged" (auto) or "evicted" (manual)
    tick: int  # service tick counter at eviction


class SeparationService:
    """Continuous-batching front door for a ``SeparatorBank``.

    Sessions (independent separation problems — one user's sensor stream, one
    channel of an EEG array, ...) are admitted into free bank slots and
    evicted when done; ``step`` advances every live session with ONE fused
    bank program per tick.  Slots without fresh data this tick are frozen via
    the bank's active mask, so intermittent streams don't corrupt their state.

        svc = SeparationService(SeparatorBank(ecfg, ocfg, n_streams=64))
        svc.admit("user-a"); svc.admit("user-b")
        outs = svc.step({"user-a": xa, "user-b": xb})   # one fused launch
        final = svc.evict("user-a")                     # SMBGDState handed back

    The tick is zero-copy on a fused bank (``SeparatorBank(fused=True)``):
    mini-batches are staged host-side into ONE preallocated block-aligned
    buffer (``bank.layout``; reused every tick — stale slots are masked
    inactive and the padding region is never written, so no re-zeroing), the
    jitted step donates the persistent padded state back to the kernel
    outputs (accelerator backends), and per-session slices are cut from the
    padded Y at return — steady-state serving allocates no device state per
    tick (the host→device transfer of the staging buffer remains).

    Metrics (the backpressure/observability hook): ``metrics`` reports
    per-tick latency (last/mean) and aggregate samples/sec; ``session_stats``
    reports per-session tick/sample counters and samples/sec since admission.
    ``block_ticks=True`` synchronizes on the device result before stopping the
    tick clock, so latencies measure compute, not dispatch.

    Lifecycle (see the module docstring for the full state machine): with
    ``max_queue > 0`` a full bank enqueues admissions instead of raising
    (bounded backpressure), and with a ``ConvergencePolicy`` the service
    watches each active session's in-bank convergence statistic and
    auto-evicts converged sessions at the end of the tick — their final
    ``SMBGDState`` (+ stats) lands in ``finished`` / ``pop_finished()`` and
    the freed slot is backfilled from the queue within the same tick.
    ``on_admit(sid, slot)`` / ``on_evict(sid, record)`` callbacks observe
    both transitions (backfills and auto-evictions included).
    """

    def __init__(
        self,
        bank: SeparatorBank,
        seed: int = 0,
        block_ticks: bool = False,
        policy: Optional[ConvergencePolicy] = None,
        max_queue: int = 0,
        on_admit: Optional[Callable[[Hashable, int], None]] = None,
        on_evict: Optional[Callable[[Hashable, EvictionRecord], None]] = None,
    ):
        self.bank = bank
        self.key = jax.random.PRNGKey(seed)
        self.state: BankState = bank.init(self.key)
        self.policy = policy
        self.max_queue = max_queue
        self.on_admit = on_admit
        self.on_evict = on_evict
        self._free: List[int] = list(range(bank.n_streams - 1, -1, -1))  # pop() → slot 0 first
        self._slot_of: Dict[Hashable, int] = {}
        self._queue: Deque[Hashable] = collections.deque()
        self._monitors: Dict[Hashable, ConvergenceMonitor] = {}
        self._mixing: Dict[Hashable, jnp.ndarray] = {}
        self._finished: Dict[Hashable, EvictionRecord] = {}
        self._n_evicted = 0
        self._n_auto_evicted = 0
        # donated state on accelerators: the runtime reuses the old state
        # buffers for the new state — the steady-state tick performs no state
        # allocation (CPU backend opts out; see SeparatorBank.make_step)
        self._step = bank.make_step()
        # one staging buffer for every tick: jnp.asarray copies host→device,
        # so the numpy side is free to be overwritten next tick
        if bank.fused:
            lay = bank.layout
            stage_shape = (bank.n_streams, lay.P_pad, lay.m_pad)
        else:
            stage_shape = (bank.n_streams, bank.opt.batch_size, bank.easi.n_features)
        self._stage = np.zeros(stage_shape, dtype=np.float32)
        self.block_ticks = block_ticks
        self._stats: Dict[Hashable, SessionStats] = {}
        self._n_ticks = 0
        self._total_samples = 0
        self._total_tick_s = 0.0
        self._last_tick_s = float("nan")

    @property
    def n_active(self) -> int:
        return len(self._slot_of)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def queued(self) -> Tuple[Hashable, ...]:
        """FIFO snapshot of the admission queue (head first)."""
        return tuple(self._queue)

    @property
    def finished(self) -> Dict[Hashable, EvictionRecord]:
        """Retained eviction records (read-only view; drain with
        ``pop_finished``)."""
        return dict(self._finished)

    def pop_finished(self) -> Dict[Hashable, EvictionRecord]:
        """Drain and return the eviction records accumulated so far."""
        out, self._finished = self._finished, {}
        return out

    def status(self, session_id: Hashable) -> str:
        """Lifecycle state: ``"active" | "queued" | "finished" | "unknown"``."""
        if session_id in self._slot_of:
            return "active"
        if session_id in self._queue:
            return "queued"
        if session_id in self._finished:
            return "finished"
        return "unknown"

    def set_mixing(self, session_id: Hashable, A: jnp.ndarray) -> None:
        """Register the session's ground-truth mixing matrix ``A (m, n)`` so
        ``ConvergencePolicy.amari_threshold`` can confirm convergence on the
        global system ``B·A`` (benchmarks / synthetic workloads; production
        sessions without ground truth simply never register one)."""
        if session_id not in self._slot_of and session_id not in self._queue:
            raise KeyError(f"session {session_id!r} is neither active nor queued")
        self._mixing[session_id] = jnp.asarray(A)

    # -- metrics -----------------------------------------------------------
    @property
    def metrics(self) -> Dict[str, float]:
        """Service-level serving counters (one dict, cheap to scrape)."""
        return {
            "n_active": float(self.n_active),
            "n_free": float(self.n_free),
            "n_queued": float(self.n_queued),
            "n_evicted": float(self._n_evicted),
            "n_auto_evicted": float(self._n_auto_evicted),
            "n_ticks": float(self._n_ticks),
            "total_samples": float(self._total_samples),
            "last_tick_s": self._last_tick_s,
            "mean_tick_s": self._total_tick_s / self._n_ticks
            if self._n_ticks
            else float("nan"),
            "samples_per_s": self._total_samples / self._total_tick_s
            if self._total_tick_s > 0
            else float("nan"),
        }

    def session_stats(self, session_id: Hashable) -> Dict[str, float]:
        """Per-session counters: ticks, samples, samples/sec since admit —
        plus the convergence monitor (smoothed stat, consecutive below-count)
        when a policy is attached."""
        st = self._stats[session_id]
        out = {
            "ticks": float(st.ticks),
            "samples": float(st.samples),
            "samples_per_s": st.samples_per_s(),
        }
        mon = self._monitors.get(session_id)
        if mon is not None:
            out["conv_stat"] = mon.stat
            out["conv_below"] = float(mon.below)
        return out

    def admit(self, session_id: Hashable) -> Optional[int]:
        """Admit ``session_id``: into a free slot (returns the slot index), or
        — when the bank is full and ``max_queue`` allows — onto the FIFO
        admission queue (returns ``None``; the session activates when a slot
        frees).  Raises ``ValueError`` for duplicate ids and ``RuntimeError``
        when bank AND queue are full (backpressure: the caller must shed
        load or retry later)."""
        if session_id in self._slot_of or session_id in self._queue:
            raise ValueError(f"session {session_id!r} already admitted")
        if not self._free:
            if len(self._queue) < self.max_queue:
                self._queue.append(session_id)
                return None
            raise RuntimeError(
                f"bank full ({self.bank.n_streams} slots, "
                f"{len(self._queue)}/{self.max_queue} queued); evict before "
                f"admitting"
            )
        return self._activate(session_id)

    def _activate(self, session_id: Hashable) -> int:
        """QUEUED/new → ACTIVE: claim a free slot and initialize it (the
        session's device state is born here, so the γ step-0 gate applies at
        its first *served* tick)."""
        slot = self._free.pop()
        self.key, k = jax.random.split(self.key)
        self.state = self.bank.init_slot(self.state, slot, k)
        self._slot_of[session_id] = slot
        self._stats[session_id] = SessionStats(admitted_at=time.perf_counter())
        self._monitors[session_id] = ConvergenceMonitor()
        if self.on_admit is not None:
            self.on_admit(session_id, slot)
        return slot

    def evict(self, session_id: Hashable) -> Optional[SMBGDState]:
        """ACTIVE → EVICTED: release the slot and return the session's final
        single-stream state (B is its learned separation matrix), backfilling
        the freed slot from the admission queue.  A QUEUED session is simply
        dequeued (returns ``None`` — it never had device state).  An unknown
        id raises ``KeyError`` without touching the free list."""
        if session_id not in self._slot_of:
            try:
                self._queue.remove(session_id)  # cancellation of a queued session
            except ValueError:
                raise KeyError(
                    f"session {session_id!r} is neither active nor queued"
                ) from None
            self._mixing.pop(session_id, None)
            return None
        return self._release(session_id, reason="evicted").state

    def _release(self, session_id: Hashable, reason: str) -> EvictionRecord:
        """ACTIVE → EVICTED edge shared by manual ``evict`` and the policy's
        auto-eviction: slice the final state out of the bank, free the slot,
        record the eviction, and backfill from the queue head — all before
        the next tick touches the bank."""
        slot = self._slot_of.pop(session_id)
        record = EvictionRecord(
            state=self.bank.slot_state(self.state, slot),
            stats=self._stats.pop(session_id),
            monitor=self._monitors.pop(session_id, None),
            reason=reason,
            tick=self._n_ticks,
        )
        self._mixing.pop(session_id, None)
        self._free.append(slot)
        self._n_evicted += 1
        if reason == "converged":
            self._n_auto_evicted += 1
        self._finished[session_id] = record
        if self.on_evict is not None:
            self.on_evict(session_id, record)
        # same-tick backfill: the freed slot was appended last, so the queue
        # head lands exactly in the slot that just opened
        if self._queue:
            self._activate(self._queue.popleft())
        return record

    def step(self, batches: Dict[Hashable, jnp.ndarray]) -> Dict[Hashable, jnp.ndarray]:
        """Advance every session that sent data this tick.

        ``batches`` maps session_id → ``(P, m)`` mini-batch.  Sessions without
        data (and free slots) are masked inactive — state untouched.  Returns
        session_id → separated ``(P, n)`` outputs from one fused bank step.

        On a fused bank the staging buffer is allocated block-aligned
        (``(S, P_pad, m_pad)``) so the jitted step consumes it with no
        re-padding copy; outputs are sliced back to ``(P, n)`` per session.
        """
        if not batches:
            return {}
        unknown = set(batches) - set(self._slot_of)
        if unknown:
            raise KeyError(f"sessions not admitted: {sorted(map(str, unknown))}")
        S = self.bank.n_streams
        P = self.bank.opt.batch_size
        m = self.bank.easi.n_features
        n = self.bank.easi.n_components
        # reused staging buffer (block-aligned on fused banks): stale data in
        # slots not written this tick only feeds masked-out streams, and the
        # padding region is never written, so it stays zero from __init__
        X = self._stage
        active = np.zeros((S,), dtype=bool)
        for sid, xb in batches.items():
            xb = np.asarray(xb, dtype=np.float32)
            if xb.shape != (P, m):  # don't let numpy broadcast a wrong batch
                raise ValueError(
                    f"session {sid!r}: batch shape {xb.shape} != required "
                    f"(P={P}, m={m})"
                )
            slot = self._slot_of[sid]
            X[slot, :P, :m] = xb
            active[slot] = True
        t0 = time.perf_counter()
        self.state, Y = self._step(self.state, jnp.asarray(X), jnp.asarray(active))
        if self.block_ticks:
            jax.block_until_ready((self.state, Y))
        dt = time.perf_counter() - t0
        self._n_ticks += 1
        self._last_tick_s = dt
        self._total_tick_s += dt
        self._total_samples += P * len(batches)
        for sid in batches:
            st = self._stats[sid]
            st.ticks += 1
            st.samples += P
        # slice outputs BEFORE any auto-eviction mutates the slot map: evicted
        # sessions still receive this tick's separated output
        out = {sid: Y[self._slot_of[sid], :P, :n] for sid in batches}
        if self.policy is not None:
            self._apply_policy(batches.keys())
        return out

    def _apply_policy(self, served) -> None:
        """End-of-tick convergence sweep: update each served session's monitor
        from the bank's in-step statistic, auto-evict the converged ones and
        backfill their slots from the queue (same tick).

        One (S,)-float device read per tick — the statistic itself was folded
        inside the bank step (in-register on the fused path)."""
        pol = self.policy
        conv = np.asarray(self.state.conv)  # (S,) f32
        evict_now: List[Hashable] = []
        for sid in served:
            mon = self._monitors[sid]
            mon.update(float(conv[self._slot_of[sid]]), pol)
            if mon.ticks < pol.min_ticks or mon.below < pol.patience:
                continue
            if pol.amari_threshold is not None and sid in self._mixing:
                B = self.bank.slot_state(self.state, self._slot_of[sid]).B
                pi = float(
                    metrics_lib.amari_index(
                        metrics_lib.global_system(B, self._mixing[sid])
                    )
                )
                if pi > pol.amari_threshold:
                    continue  # blind stat dipped early — not separated yet
            evict_now.append(sid)
        for sid in evict_now:
            self._release(sid, reason="converged")

    # -- persistence -------------------------------------------------------
    # The bank state is a plain pytree, so the array side round-trips through
    # any Checkpointer.  The session→slot map, admission queue and monitor
    # counters are host bookkeeping (arbitrary hashable ids — not arrays):
    # callers persist them via ``sessions``/``lifecycle`` and hand them back
    # to ``restore`` to resume live sessions and queued admissions.

    @property
    def sessions(self) -> Dict[Hashable, int]:
        """Snapshot of the live session→slot map (save alongside the arrays)."""
        return dict(self._slot_of)

    @property
    def lifecycle(self) -> Dict[str, Any]:
        """JSON-friendly snapshot of the full host-side lifecycle state:
        session→slot map, FIFO admission queue, and per-session convergence
        monitors.  Save alongside the arrays; hand back to ``restore`` to
        resume sessions, queue AND convergence progress in place.  Mixing
        matrices registered via ``set_mixing`` are arrays and deliberately
        excluded — re-register them after restore (see ``restore``)."""
        return {
            "sessions": dict(self._slot_of),
            "queue": list(self._queue),
            "monitors": {
                sid: dataclasses.asdict(mon)
                for sid, mon in self._monitors.items()
            },
        }

    def save(self, checkpointer, step: int) -> None:
        # rng_key rides along so post-restore admissions continue the key
        # sequence instead of replaying pre-save inits
        checkpointer.save(step, dict(self.state._asdict(), rng_key=self.key))

    def restore(
        self,
        checkpointer,
        step: Optional[int] = None,
        sessions: Optional[Dict[Hashable, int]] = None,
        lifecycle: Optional[Dict[str, Any]] = None,
    ) -> int:
        """Restore bank arrays and (optionally) re-attach host lifecycle state.

        Without ``sessions``/``lifecycle`` every slot is considered free:
        restored separator matrices are still in the arrays but will be
        overwritten as slots are re-admitted.  Pass the ``sessions`` map (or
        the richer ``lifecycle`` snapshot, which also carries the admission
        queue and the per-session convergence monitors) captured at save time
        to resume in place.

        Ground-truth mixing matrices are NOT part of the snapshot (they are
        arrays, not host bookkeeping, and the snapshot stays JSON-able):
        callers using ``ConvergencePolicy.amari_threshold`` must re-register
        them via ``set_mixing`` after restore, or the Amari confirmation is
        skipped and the blind statistic decides alone.
        """
        lifecycle = lifecycle or {}
        if sessions is None:
            sessions = lifecycle.get("sessions") or {}
        queue = list(lifecycle.get("queue") or [])
        monitors = lifecycle.get("monitors") or {}
        bad = {
            s: slot
            for s, slot in sessions.items()
            if not 0 <= slot < self.bank.n_streams
        }
        if bad:
            raise ValueError(f"session slots out of range: {bad}")
        if len(set(sessions.values())) != len(sessions):
            raise ValueError(f"duplicate slots in session map: {sessions}")
        overlap = set(queue) & set(sessions)
        if overlap or len(set(queue)) != len(queue):
            raise ValueError(f"queue/session overlap or duplicates: {queue}")
        # validate BEFORE mutating: a rejected map must leave the live
        # service untouched
        target = dict(self.state._asdict(), rng_key=self.key)
        tree, got = checkpointer.restore(target, step=step)
        self.key = tree.pop("rng_key")
        self.state = BankState(**tree)
        self._slot_of = dict(sessions)
        self._queue = collections.deque(queue)
        # convergence progress resumes exactly; sessions without a saved
        # monitor restart their decision state (but not their separator)
        self._monitors = {
            sid: ConvergenceMonitor(**monitors[sid])
            if sid in monitors
            else ConvergenceMonitor()
            for sid in sessions
        }
        self._mixing = {}
        self._finished = {}
        # serving counters restart at restore time — per-session AND aggregate
        # (metrics must describe the restored epoch, not blend the old run)
        now = time.perf_counter()
        self._stats = {sid: SessionStats(admitted_at=now) for sid in sessions}
        self._n_ticks = 0
        self._total_samples = 0
        self._total_tick_s = 0.0
        self._last_tick_s = float("nan")
        self._n_evicted = 0
        self._n_auto_evicted = 0
        taken = set(sessions.values())
        self._free = [s for s in range(self.bank.n_streams - 1, -1, -1) if s not in taken]
        return got
