"""Batched serving engines: LM decode + multi-stream separation service.

Deployment counterpart of the trainer (the paper's "model creation, training
AND deployment in hardware" mandate).  Two engines share the
continuous-batching idiom (slot free-list; new sessions drop into freed slots
between steps):
  * ``Engine`` — LM serving: batched requests with per-request lengths,
    chunked prefill through ``decode_step`` semantics, greedy / temperature
    sampling,
  * ``SeparationService`` — ICA serving: admits/evicts separation *sessions*
    into the slots of a ``repro.stream.SeparatorBank``; every tick steps all
    live sessions with one fused bank program (the multi-stream analogue of
    the paper's single always-on FPGA datapath).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.smbgd import SMBGDState
from repro.models import model as M
from repro.stream.bank import BankState, SeparatorBank

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0  # 0 → greedy
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params: PyTree, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, s, b: M.decode_step(p, s, b, cfg)
        )
        self.state = M.init_serve_state(cfg, scfg.max_batch, scfg.max_len)
        self.key = jax.random.PRNGKey(scfg.seed)

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        logits = logits[:, -1]  # last position: (B, V), or (B, K, V) w/ codebooks
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits / self.scfg.temperature, axis=-1)

    def prefill_and_generate(
        self, prompts: jnp.ndarray, n_new: int
    ) -> Tuple[jnp.ndarray, List[float]]:
        """prompts: (B, T_prompt[, K]); returns (B, n_new[, K]) generated
        tokens (greedy/temperature).  Prefill is token-streamed through the
        recurrent state machinery — one code path for all families."""
        B, T = prompts.shape[0], prompts.shape[1]
        assert B == self.scfg.max_batch
        state = M.init_serve_state(self.cfg, B, self.scfg.max_len)
        logits = None
        for t in range(T):  # chunked prefill (chunk = 1 keeps it family-agnostic)
            tok = prompts[:, t : t + 1]
            logits, state = self._decode(self.params, state, {"tokens": tok})
        out = []
        tok = self._sample(logits)[:, None] if not self.cfg.n_codebooks else self._sample(logits)[:, None, :]
        for _ in range(n_new):
            out.append(tok)
            logits, state = self._decode(self.params, state, {"tokens": tok})
            tok = self._sample(logits)[:, None] if not self.cfg.n_codebooks else self._sample(logits)[:, None, :]
        self.state = state
        return jnp.concatenate(out, axis=1), []


@dataclasses.dataclass
class SessionStats:
    """Per-session serving counters (host-side bookkeeping)."""

    admitted_at: float  # time.perf_counter() at admission
    ticks: int = 0
    samples: int = 0

    def samples_per_s(self, now: Optional[float] = None) -> float:
        """Throughput since admission (wall-clock)."""
        now = time.perf_counter() if now is None else now
        return self.samples / max(now - self.admitted_at, 1e-9)


class SeparationService:
    """Continuous-batching front door for a ``SeparatorBank``.

    Sessions (independent separation problems — one user's sensor stream, one
    channel of an EEG array, ...) are admitted into free bank slots and
    evicted when done; ``step`` advances every live session with ONE fused
    bank program per tick.  Slots without fresh data this tick are frozen via
    the bank's active mask, so intermittent streams don't corrupt their state.

        svc = SeparationService(SeparatorBank(ecfg, ocfg, n_streams=64))
        svc.admit("user-a"); svc.admit("user-b")
        outs = svc.step({"user-a": xa, "user-b": xb})   # one fused launch
        final = svc.evict("user-a")                     # SMBGDState handed back

    The tick is zero-copy on a fused bank (``SeparatorBank(fused=True)``):
    mini-batches are staged host-side into ONE preallocated block-aligned
    buffer (``bank.layout``; reused every tick — stale slots are masked
    inactive and the padding region is never written, so no re-zeroing), the
    jitted step donates the persistent padded state back to the kernel
    outputs (accelerator backends), and per-session slices are cut from the
    padded Y at return — steady-state serving allocates no device state per
    tick (the host→device transfer of the staging buffer remains).

    Metrics (the backpressure/observability hook): ``metrics`` reports
    per-tick latency (last/mean) and aggregate samples/sec; ``session_stats``
    reports per-session tick/sample counters and samples/sec since admission.
    ``block_ticks=True`` synchronizes on the device result before stopping the
    tick clock, so latencies measure compute, not dispatch.
    """

    def __init__(
        self, bank: SeparatorBank, seed: int = 0, block_ticks: bool = False
    ):
        self.bank = bank
        self.key = jax.random.PRNGKey(seed)
        self.state: BankState = bank.init(self.key)
        self._free: List[int] = list(range(bank.n_streams - 1, -1, -1))  # pop() → slot 0 first
        self._slot_of: Dict[Hashable, int] = {}
        # donated state on accelerators: the runtime reuses the old state
        # buffers for the new state — the steady-state tick performs no state
        # allocation (CPU backend opts out; see SeparatorBank.make_step)
        self._step = bank.make_step()
        # one staging buffer for every tick: jnp.asarray copies host→device,
        # so the numpy side is free to be overwritten next tick
        if bank.fused:
            lay = bank.layout
            stage_shape = (bank.n_streams, lay.P_pad, lay.m_pad)
        else:
            stage_shape = (bank.n_streams, bank.opt.batch_size, bank.easi.n_features)
        self._stage = np.zeros(stage_shape, dtype=np.float32)
        self.block_ticks = block_ticks
        self._stats: Dict[Hashable, SessionStats] = {}
        self._n_ticks = 0
        self._total_samples = 0
        self._total_tick_s = 0.0
        self._last_tick_s = float("nan")

    @property
    def n_active(self) -> int:
        return len(self._slot_of)

    @property
    def n_free(self) -> int:
        return len(self._free)

    # -- metrics -----------------------------------------------------------
    @property
    def metrics(self) -> Dict[str, float]:
        """Service-level serving counters (one dict, cheap to scrape)."""
        return {
            "n_active": float(self.n_active),
            "n_free": float(self.n_free),
            "n_ticks": float(self._n_ticks),
            "total_samples": float(self._total_samples),
            "last_tick_s": self._last_tick_s,
            "mean_tick_s": self._total_tick_s / self._n_ticks
            if self._n_ticks
            else float("nan"),
            "samples_per_s": self._total_samples / self._total_tick_s
            if self._total_tick_s > 0
            else float("nan"),
        }

    def session_stats(self, session_id: Hashable) -> Dict[str, float]:
        """Per-session counters: ticks, samples, samples/sec since admit."""
        st = self._stats[session_id]
        return {
            "ticks": float(st.ticks),
            "samples": float(st.samples),
            "samples_per_s": st.samples_per_s(),
        }

    def admit(self, session_id: Hashable) -> int:
        """Assign ``session_id`` a fresh separator in a free slot; returns the
        slot index.  Raises when the bank is full or the id is already live."""
        if session_id in self._slot_of:
            raise ValueError(f"session {session_id!r} already admitted")
        if not self._free:
            raise RuntimeError(
                f"bank full ({self.bank.n_streams} slots); evict before admitting"
            )
        slot = self._free.pop()
        self.key, k = jax.random.split(self.key)
        self.state = self.bank.init_slot(self.state, slot, k)
        self._slot_of[session_id] = slot
        self._stats[session_id] = SessionStats(admitted_at=time.perf_counter())
        return slot

    def evict(self, session_id: Hashable) -> SMBGDState:
        """Release the session's slot back to the free list; returns its final
        single-stream state (B is the session's learned separation matrix)."""
        slot = self._slot_of.pop(session_id)
        self._stats.pop(session_id, None)
        final = self.bank.slot_state(self.state, slot)
        self._free.append(slot)
        return final

    def step(self, batches: Dict[Hashable, jnp.ndarray]) -> Dict[Hashable, jnp.ndarray]:
        """Advance every session that sent data this tick.

        ``batches`` maps session_id → ``(P, m)`` mini-batch.  Sessions without
        data (and free slots) are masked inactive — state untouched.  Returns
        session_id → separated ``(P, n)`` outputs from one fused bank step.

        On a fused bank the staging buffer is allocated block-aligned
        (``(S, P_pad, m_pad)``) so the jitted step consumes it with no
        re-padding copy; outputs are sliced back to ``(P, n)`` per session.
        """
        if not batches:
            return {}
        unknown = set(batches) - set(self._slot_of)
        if unknown:
            raise KeyError(f"sessions not admitted: {sorted(map(str, unknown))}")
        S = self.bank.n_streams
        P = self.bank.opt.batch_size
        m = self.bank.easi.n_features
        n = self.bank.easi.n_components
        # reused staging buffer (block-aligned on fused banks): stale data in
        # slots not written this tick only feeds masked-out streams, and the
        # padding region is never written, so it stays zero from __init__
        X = self._stage
        active = np.zeros((S,), dtype=bool)
        for sid, xb in batches.items():
            xb = np.asarray(xb, dtype=np.float32)
            if xb.shape != (P, m):  # don't let numpy broadcast a wrong batch
                raise ValueError(
                    f"session {sid!r}: batch shape {xb.shape} != required "
                    f"(P={P}, m={m})"
                )
            slot = self._slot_of[sid]
            X[slot, :P, :m] = xb
            active[slot] = True
        t0 = time.perf_counter()
        self.state, Y = self._step(self.state, jnp.asarray(X), jnp.asarray(active))
        if self.block_ticks:
            jax.block_until_ready((self.state, Y))
        dt = time.perf_counter() - t0
        self._n_ticks += 1
        self._last_tick_s = dt
        self._total_tick_s += dt
        self._total_samples += P * len(batches)
        for sid in batches:
            st = self._stats[sid]
            st.ticks += 1
            st.samples += P
        return {sid: Y[self._slot_of[sid], :P, :n] for sid in batches}

    # -- persistence -------------------------------------------------------
    # The bank state is a plain pytree, so the array side round-trips through
    # any Checkpointer.  The session→slot map is host bookkeeping (arbitrary
    # hashable ids — not arrays): callers persist it themselves via
    # ``sessions`` and hand it back to ``restore`` to resume live sessions.

    @property
    def sessions(self) -> Dict[Hashable, int]:
        """Snapshot of the live session→slot map (save alongside the arrays)."""
        return dict(self._slot_of)

    def save(self, checkpointer, step: int) -> None:
        # rng_key rides along so post-restore admissions continue the key
        # sequence instead of replaying pre-save inits
        checkpointer.save(step, dict(self.state._asdict(), rng_key=self.key))

    def restore(
        self,
        checkpointer,
        step: Optional[int] = None,
        sessions: Optional[Dict[Hashable, int]] = None,
    ) -> int:
        """Restore bank arrays and (optionally) re-attach live sessions.

        Without ``sessions`` every slot is considered free: restored separator
        matrices are still in the arrays but will be overwritten as slots are
        re-admitted.  Pass the ``sessions`` map captured at save time to
        resume those sessions in place.
        """
        sessions = sessions or {}
        bad = {
            s: slot
            for s, slot in sessions.items()
            if not 0 <= slot < self.bank.n_streams
        }
        if bad:
            raise ValueError(f"session slots out of range: {bad}")
        if len(set(sessions.values())) != len(sessions):
            raise ValueError(f"duplicate slots in session map: {sessions}")
        # validate BEFORE mutating: a rejected map must leave the live
        # service untouched
        target = dict(self.state._asdict(), rng_key=self.key)
        tree, got = checkpointer.restore(target, step=step)
        self.key = tree.pop("rng_key")
        self.state = BankState(**tree)
        self._slot_of = dict(sessions)
        # serving counters restart at restore time — per-session AND aggregate
        # (metrics must describe the restored epoch, not blend the old run)
        now = time.perf_counter()
        self._stats = {sid: SessionStats(admitted_at=now) for sid in sessions}
        self._n_ticks = 0
        self._total_samples = 0
        self._total_tick_s = 0.0
        self._last_tick_s = float("nan")
        taken = set(sessions.values())
        self._free = [s for s in range(self.bank.n_streams - 1, -1, -1) if s not in taken]
        return got
