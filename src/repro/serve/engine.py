"""Batched serving engine: chunked prefill + decode over the model zoo.

Deployment counterpart of the trainer (the paper's "model creation, training
AND deployment in hardware" mandate).  Supports:
  * batched requests with per-request lengths (right-padded, masked loss-free),
  * chunked prefill through ``decode_step`` semantics for the recurrent
    families / one-shot ``forward`` prefill for attention families,
  * greedy / temperature sampling,
  * continuous-batching bookkeeping (slot free-list; new requests drop into
    finished slots between decode steps).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0  # 0 → greedy
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params: PyTree, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, s, b: M.decode_step(p, s, b, cfg)
        )
        self.state = M.init_serve_state(cfg, scfg.max_batch, scfg.max_len)
        self.key = jax.random.PRNGKey(scfg.seed)

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.cfg.n_codebooks:
            logits = logits[:, -1]  # (B, K, V)
        else:
            logits = logits[:, -1]  # (B, V)
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits / self.scfg.temperature, axis=-1)

    def prefill_and_generate(
        self, prompts: jnp.ndarray, n_new: int
    ) -> Tuple[jnp.ndarray, List[float]]:
        """prompts: (B, T_prompt[, K]); returns (B, n_new[, K]) generated
        tokens (greedy/temperature).  Prefill is token-streamed through the
        recurrent state machinery — one code path for all families."""
        B, T = prompts.shape[0], prompts.shape[1]
        assert B == self.scfg.max_batch
        state = M.init_serve_state(self.cfg, B, self.scfg.max_len)
        logits = None
        for t in range(T):  # chunked prefill (chunk = 1 keeps it family-agnostic)
            tok = prompts[:, t : t + 1]
            logits, state = self._decode(self.params, state, {"tokens": tok})
        out = []
        tok = self._sample(logits)[:, None] if not self.cfg.n_codebooks else self._sample(logits)[:, None, :]
        for _ in range(n_new):
            out.append(tok)
            logits, state = self._decode(self.params, state, {"tokens": tok})
            tok = self._sample(logits)[:, None] if not self.cfg.n_codebooks else self._sample(logits)[:, None, :]
        self.state = state
        return jnp.concatenate(out, axis=1), []
