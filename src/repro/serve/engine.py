"""Batched serving engines: LM decode + multi-stream separation service.

Deployment counterpart of the trainer (the paper's "model creation, training
AND deployment in hardware" mandate).  Two engines share the
continuous-batching idiom (slot free-list; new sessions drop into freed slots
between steps):
  * ``Engine`` — LM serving: batched requests with per-request lengths,
    chunked prefill through ``decode_step`` semantics, greedy / temperature
    sampling,
  * ``SeparationService`` — ICA serving: admits/evicts separation *sessions*
    into the slots of a ``repro.stream.SeparatorBank``; every tick steps all
    live sessions with one fused bank program (the multi-stream analogue of
    the paper's single always-on FPGA datapath).

Session lifecycle state machine (``SeparationService``)::

        admit()                 admit() [no free slot]
           │                        │
           ▼                        ▼
        ACTIVE ◄── backfill ──── QUEUED ──── evict() ──► (dequeued, None)
           │                        ▲
           │  step(): conv stat     │ waiting room is a pluggable
           │  < threshold for       │ ``AdmissionScheduler`` (FIFO default;
           │  `patience` ticks      │ priority + per-tenant quotas; EDF) —
           ▼                        │ a full queue raises (backpressure)
        CONVERGED ──────────────────┘ freed slot backfilled from the
           │                          scheduler IN THE SAME TICK
           │
           ├─ no DriftPolicy ──────────────────────► EVICTED — final
           │                                         ``SMBGDState`` + stats
           │                                         retained in ``finished``
           │
           ├─ DriftPolicy(mode="boost"), source bound, nobody queued:
           │    stay HOT in the slot (status ``"converged"``), still served
           │    every tick; live conv EMA > ``retrigger`` ──► ``DriftEvent``:
           │    μ × ``boost`` for ``boost_ticks`` ticks (per-stream
           │    ``BankHyperparams`` row, no retrace) and back to ACTIVE
           │    (re-adapting).  Waiting admissions PREEMPT the most-converged
           │    hot session (──► EVICTED, reason ``"preempted"``), so keeping
           │    sessions warm never starves the queue.
           │
           └─ DriftPolicy(mode="readmit"), source bound: slot evicts as
                usual but the session PARKS (frozen state + its source);
                every ``probe_every`` ``run_tick``s the watchdog probes ALL
                parked sessions in BATCHES: the due sessions' frozen states
                are stacked into a transient probe bank (``probe_batch``
                sessions per launch, ragged tails padded + masked inactive)
                and one no-commit bank launch computes every VIRTUAL conv
                statistic (same ‖ΔB‖/‖B‖ formula, out of band, no slot,
                frozen separators never mutated) — O(parked / probe_batch)
                dispatches per probe tick, not O(parked).  A parked source
                that drains mid-probe EVICTS the session (reason
                ``"exhausted"``).  EMA > ``retrigger`` ──► ``DriftEvent``:
                re-admitted through the scheduler, warm-started from the
                frozen state (ACTIVE, or back to PARKED under contention).
                ``probe_batch=0`` selects the legacy one-dispatch-per-session
                loop (the batched engine's differential-test oracle).

Fault containment (``HealthPolicy`` — orthogonal to the drift watchdog;
see ``serve.health``)::

        ACTIVE ── health word ≠ 0 (kernel refused the commit) ──┐
           ▲                                                    ▼
           │  rollback to shadow + μ × ``mu_cut``          [escalation]
           ◄── ≤ ``max_rollbacks`` offenses / ``window`` ───────┤
           ▲                                                    ▼
           │  probation: ``probation`` healthy probes      QUARANTINED
           ◄── (warm re-admission, ladder memory kept) ◄────────┤
                                                                ▼
                              > ``max_quarantines`` quarantines │
                 EVICTED, reason ``"diverged"`` (+ provenance) ◄┘

    Detection is free: the megakernel folds a per-stream health word
    (non-finite B′/Ĥ′/Y bits + an update-magnitude blow-up bit) into the
    same in-register reduction as ``conv``, and REFUSES the offender's
    commit in-kernel — the slot keeps its pre-tick state like a frozen one.
    The service keeps a per-slot last-known-good SHADOW snapshot
    (copy-on-healthy every ``shadow_every`` ticks, re-seeded per slot at
    activation) to roll offenders back to; μ cuts ride the same per-stream
    ``BankHyperparams`` traced-operand rows as the drift boost (no retrace).
    Quarantined sessions are probed out of band like parked ones, but the
    probe's VIRTUAL health word (not conv) decides release.  Source-side
    faults never reach the ladder: ``run_tick`` isolates a raising/stalling
    source to its own session (degraded tick via the active mask; wrap
    flaky feeds in ``data.resilience.ResilientSource`` for bounded
    retry/backoff/stall-timeout first).

Latency SLOs (``SLOPolicy`` — see ``serve.slo``; telemetry always on)::

        every tick ── TickTimer: block_until_ready(state.conv) ──► timed dt
           │          (1-in-k under sync_every>1; block_ticks syncs harder)
           ▼
        LatencySketch: p50/p99/p999, exact window + log-binned lifetime
           │
           ├─ no deadline_budget_s ────────────► telemetry only
           │
           └─ dt > deadline_budget_s: MISS ──► n_deadline_misses++, the
                windowed miss rate and every served session's
                ``DeadlineMonitor`` advance; over ``max_miss_rate``:
                  * ``shed=True`` — the worst-missing active session is
                    preempted (reason ``"shed"``, lands in ``finished``)
                  * ``gate_admissions=True`` — backfills and direct
                    admissions HOLD until the miss window recovers

    The tick clock measures TIME-TO-READY regardless of ``block_ticks``:
    the dispatch-only latencies the old clock reported on asynchronous
    backends never enter the books.  ``run_tick`` bills its whole duration
    (pull + step + drain + out-of-band probes) as the tick's latency;
    run_ticks with no data batch count as *empty ticks* (distinct counter,
    still sketched and budget-checked, ``n_ticks`` untouched).  Recorded
    loads replay deterministically: wrap sources in
    ``data.sources.RecordingSource``, persist with ``save_recording``, and
    drive any service through the trace with ``serve.slo.replay`` — the
    ``--slo`` benchmark row gates p99/miss-rate regressions in CI.

Adaptive μ (``MomentPolicy`` — see ``serve.moments``; needs a bank with
``moments=True`` telemetry)::

        every tick ── kernel folds [Σy², Σy⁴] into the conv reduction ──┐
           ▲              (8 bytes/stream of extra HBM — output only)   ▼
           │                                     κ = N·Σy⁴/(Σy²)²  (host-side)
           │                                                            ▼
           │                    MomentController: fast EMA (current output
           │                    distribution) vs slow EMA (converged reference)
           │                                                            ▼
           │    ┌─ warmup (< warmup_ticks) or |dev − 1| ≤ deadband ─► scale 1.0
           │    │
           └────┴─ deviation (drift re-mixed Y; CLT drags kurtosis toward
                   Gaussian) ─► μ × clamp(dev^gain) — ANNEALS back to 1 as
                   re-convergence pulls the fast EMA home (what a fixed
                   ``DriftPolicy.boost`` pulse cannot do)

    Composition of the three μ writers is pinned (and regression-tested):
    a HealthPolicy μ-cut WINS outright while it is live (containment beats
    adaptation — never boost a separator you just rolled back), otherwise
    the DriftPolicy boost and the controller scale MULTIPLY::

        μ_eff = μ_base · (cut_on ? cut_scale : boost_scale · ctrl_scale)

    Rollback, quarantine, eviction and (re-)activation RESET the session's
    controller memory — the old kurtosis reference no longer describes the
    restored/new separator, so the EMAs re-seed from the next usable tick.

Elastic capacity (``AutoscalePolicy`` — see ``serve.elastic``; the bank's
width S is no longer fixed at construction)::

        run_tick ── after the probe phase: autoscaler reads (width, active,
           │        queue depth, windowed deadline_miss_rate, cooldown)
           ▼
        ┌─ queue ≥ grow_queue_depth, or miss rate > grow_miss_rate ──► GROW
        │     width × factor (≤ max_streams): state grows by leaf-wise
        │     prefix copy (new slots blank — NO RNG consumed), free list
        │     gains the new high slots, the queue backfills into them the
        │     same tick; the step function re-resolves autotune geometry at
        │     the new (S, P, m, n, backend) key and is cached per width
        │
        ├─ queue EMPTY + no miss pressure + utilization < shrink band ──►
        │     COMPACT then SHRINK: live slots migrate to the low end
        │     (``SeparatorBank.move_slot`` — every leaf carried verbatim,
        │     μ ladders and the shadow move with them), then the high
        │     half truncates to the smallest ladder width holding
        │     utilization ≤ hold_utilization
        │
        └─ otherwise (or within cooldown_ticks of the last resize) ──► HOLD

    The two bands cannot flap (validated: ``shrink_utilization ≤
    hold_utilization / factor``, so a just-shrunk bank sits above the shrink
    band; growth needs queue/deadline pressure, which growing relieves).
    Resizes are INVISIBLE to co-tenants: surviving sessions' (B, Ĥ, step,
    conv) trajectories are bit-identical to a fixed-width run on both the
    vmap and megakernel paths (property-pinned in tests/test_elastic.py) —
    the persistent layout's trailing dims depend only on (n, m, dtype
    policy), so a resize is always a prefix copy, never a re-layout.
    ``grow``/``shrink``/``compact`` are also direct public methods (manual
    capacity ops need no policy); resize cost lands in the resizing tick's
    recorded latency, and the resize history (tick, action, widths, reason)
    rides ``lifecycle`` snapshots through ``save``/``restore``.  Restores
    accept a checkpoint saved at a DIFFERENT width: live sessions re-place
    into the new free list (prefix-packed, slot map remapped), failing
    loudly only when they exceed the new capacity.

Ingestion: ``run_tick()`` is the scheduler-driven pull loop — sessions bind
a ``data.sources.SignalSource`` at admit time; each tick backfills free
slots, pulls one channel-major ``(m, P)`` block per bound source, advances
every pulling session with ONE fused bank step, evicts drained sources
(reason ``"exhausted"``) and probes parked sessions.  Push-mode ``step()``
remains for callers that assemble their own batches (both can be mixed:
sessions without a source are simply never pulled).

Backpressure semantics: ``admit`` NEVER silently drops a session.  With a
free slot (and an admission the scheduler allows — per-tenant quotas gate
here too) it activates immediately (returns the slot index); otherwise it
enqueues up to ``max_queue`` deep (returns ``None``) and past that raises
``RuntimeError``.  Queued sessions hold no device state — their separator is
initialized at activation time, so the γ step-0 gate applies at the tick
they actually start, and a queued session cancelled via ``evict`` costs
nothing.  (Re-admitted drifters are the exception: they warm-start from
their frozen separator, step counter and all — no γ re-gate.)

Convergence detection rides the bank's in-kernel statistic
(``BankState.conv`` — relative update magnitude ``‖ΔB‖_F/‖B‖_F``, computed at
commit time inside the megakernel, so detection costs one (S,)-float host
read per tick, not a state round-trip).  ``ConvergencePolicy`` turns the raw
statistic into an eviction decision: optional EMA smoothing, a threshold the
smoothed statistic must stay under for ``patience`` consecutive data ticks,
a ``min_ticks`` floor, and an optional Amari-index confirmation for sessions
whose true mixing matrix was registered via ``set_mixing`` (the blind
statistic can dip early; the Amari check vetoes eviction until the separator
actually separates).

Memory-system knobs (PR 6) — all set on the ``SeparatorBank`` the service
wraps; the engine threads them to every bank it derives (probe banks pin the
serving bank's resolved geometry with ``autotune=False``):

  * ``dtype_policy="bf16"`` halves the persistent per-session HBM footprint
    (``bank.layout.persistent_bytes_per_session``) — the capacity lever for
    "how many sessions fit per device".  Gradient fold and commit
    accumulation stay f32 in VMEM; only stored ``B``/``Ĥ`` shrink.  The
    per-stream hyperparameter rows (μ boost) and the conv statistic remain
    f32 operands regardless of policy — they are compute-side, not
    persistent state.  Worth it on real TPU at scale; on CPU interpret it
    only changes bytes, not speed.
  * ``prefetch=True`` double-buffers the megakernel's X-tile DMA so the next
    tile streams in during the current tile's gradient fold.  Turn it on for
    real TPU deployments (it is where the bandwidth overlap pays); on the
    interpret path it is bit-identical to the sync path and slightly slower
    (extra copies), so leave it off for CPU smoke runs.
  * tile geometry (``block_p``/``block_s``) and ``prefetch`` resolve from the
    persisted autotune cache (``AUTOTUNE.json``, see ``stream.autotune``)
    when left unset — run ``benchmarks/stream_throughput.py --autotune`` on
    the target backend once per deployment shape.  ``dtype_policy`` is never
    auto-applied: precision is a caller decision.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import math
import time
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import metrics as metrics_lib
from repro.core import smbgd as smbgd_lib
from repro.core.smbgd import BankHyperparams, SMBGDState
from repro.data import sources as sources_lib
from repro.models import model as M
from repro.serve.drift import DriftEvent, DriftMonitor, DriftPolicy
from repro.serve.elastic import AutoscalePolicy, ResizeDecision
from repro.serve.health import HealthEvent, HealthMonitor, HealthPolicy
from repro.serve.moments import MomentController, MomentPolicy
from repro.serve.scheduling import (
    AdmissionScheduler,
    SchedulerContext,
    SessionMeta,
)
from repro.serve.slo import (
    DeadlineMonitor,
    LatencySketch,
    SLOEvent,
    SLOPolicy,
    TickTimer,
)
from repro.stream.bank import BankState, SeparatorBank

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0  # 0 → greedy
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params: PyTree, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, s, b: M.decode_step(p, s, b, cfg)
        )
        self.state = M.init_serve_state(cfg, scfg.max_batch, scfg.max_len)
        self.key = jax.random.PRNGKey(scfg.seed)

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        logits = logits[:, -1]  # last position: (B, V), or (B, K, V) w/ codebooks
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits / self.scfg.temperature, axis=-1)

    def prefill_and_generate(
        self, prompts: jnp.ndarray, n_new: int
    ) -> Tuple[jnp.ndarray, List[float]]:
        """prompts: (B, T_prompt[, K]); returns (B, n_new[, K]) generated
        tokens (greedy/temperature).  Prefill is token-streamed through the
        recurrent state machinery — one code path for all families."""
        B, T = prompts.shape[0], prompts.shape[1]
        assert B == self.scfg.max_batch
        state = M.init_serve_state(self.cfg, B, self.scfg.max_len)
        logits = None
        for t in range(T):  # chunked prefill (chunk = 1 keeps it family-agnostic)
            tok = prompts[:, t : t + 1]
            logits, state = self._decode(self.params, state, {"tokens": tok})
        out = []
        tok = self._sample(logits)[:, None] if not self.cfg.n_codebooks else self._sample(logits)[:, None, :]
        for _ in range(n_new):
            out.append(tok)
            logits, state = self._decode(self.params, state, {"tokens": tok})
            tok = self._sample(logits)[:, None] if not self.cfg.n_codebooks else self._sample(logits)[:, None, :]
        self.state = state
        return jnp.concatenate(out, axis=1), []


@dataclasses.dataclass
class SessionStats:
    """Per-session serving counters (host-side bookkeeping).

    ``admitted_at`` stamps ``admit()`` (queue entry); ``activated_at`` stamps
    the slot claim (``_activate``) — the gap is ``queue_wait_s``.  Throughput
    divides by SERVICE time (since activation), never by queue wait: a
    session that sat out a full waiting room is not slow, it was waiting."""

    admitted_at: float  # time.perf_counter() at admission (queue entry)
    activated_at: Optional[float] = None  # slot claimed (None = not yet)
    ticks: int = 0
    samples: int = 0

    def queue_wait_s(self) -> float:
        """Seconds between admission and slot activation (0 until active)."""
        if self.activated_at is None:
            return 0.0
        return max(self.activated_at - self.admitted_at, 0.0)

    def samples_per_s(self, now: Optional[float] = None) -> float:
        """Service-time throughput: samples over wall-clock since ACTIVATION
        (falls back to admission time for stats born before activation)."""
        now = time.perf_counter() if now is None else now
        start = (
            self.activated_at
            if self.activated_at is not None
            else self.admitted_at
        )
        return self.samples / max(now - start, 1e-9)


class MetricsView(dict):
    """The service's metrics surface: a plain dict of counters that is ALSO
    callable — ``svc.metrics()`` returns the same mapping as ``svc.metrics``,
    so scrape code written against either the property convention (this
    repo's benchmarks) or the method convention (harness front-ends) reads
    one surface."""

    def __call__(self) -> "MetricsView":
        return self


@dataclasses.dataclass(frozen=True)
class ConvergencePolicy:
    """When is a session done?  Threshold + patience + floor over the bank's
    in-step convergence statistic (``BankState.conv``), with optional EMA
    smoothing and an optional ground-truth Amari confirmation.

    A session auto-evicts at the first data tick where ALL of:
      * it has received at least ``min_ticks`` mini-batches,
      * its (EMA-smoothed when ``ema > 0``) update magnitude has been below
        ``threshold`` for ``patience`` consecutive data ticks,
      * if ``amari_threshold`` is set AND the session's mixing matrix was
        registered via ``SeparationService.set_mixing``: the Amari index of
        ``B·A`` is below ``amari_threshold`` (unknown mixing → the blind
        statistic alone decides).
    """

    threshold: float = 1e-3  # conv stat must stay under this ...
    patience: int = 3  # ... for this many consecutive data ticks
    min_ticks: int = 8  # never evict younger sessions (γ warm-up)
    ema: float = 0.0  # smoothing: s' = ema·s + (1−ema)·x (0 → raw)
    amari_threshold: Optional[float] = None  # optional ground-truth gate

    def __post_init__(self) -> None:
        if self.patience < 1:
            raise ValueError("patience must be >= 1")
        if not (0.0 <= self.ema < 1.0):
            raise ValueError("ema must be in [0, 1)")


@dataclasses.dataclass
class ConvergenceMonitor:
    """Per-session streaming state of the convergence decision (host-side;
    serializable via ``dataclasses.asdict`` for checkpoint round-trips).

    Carries its own data-tick counter so the ``min_ticks`` floor survives a
    checkpoint round-trip exactly (``SessionStats`` deliberately restarts its
    counters at restore — observability describes the restored epoch, the
    convergence decision must not).  The EMA recurrence is the host-side
    twin of ``core.metrics.ema_update`` (kept in plain Python floats — this
    runs per served session per tick; a parity test pins the two)."""

    stat: float = float("inf")  # EMA-smoothed statistic (raw when ema == 0)
    below: int = 0  # consecutive data ticks with stat < threshold
    ticks: int = 0  # data ticks observed (min_ticks floor)
    skipped: int = 0  # NaN samples dropped (faulted ticks never poison)

    def update(self, x: float, policy: ConvergencePolicy) -> None:
        if math.isnan(x):
            # a faulted tick's statistic: skip the sample, count it — the
            # EMA and the below-streak must survive a NaN unharmed (the
            # host-side twin of ``core.metrics.ema_update``'s NaN guard)
            self.skipped += 1
            return
        if policy.ema and math.isfinite(self.stat):
            self.stat = policy.ema * self.stat + (1.0 - policy.ema) * x
        else:
            self.stat = x
        self.below = self.below + 1 if self.stat < policy.threshold else 0
        self.ticks += 1


@dataclasses.dataclass
class EvictionRecord:
    """What the service hands back (or retains) when a session leaves a slot.

    The evicted ``SMBGDState`` is sliced out of the bank *before* the slot is
    re-initialized for a backfill, so ``state`` is exactly the session's state
    at eviction time; ``stats``/``monitor`` preserve the per-session serving
    counters across the eviction (the churn observability surface).
    """

    state: SMBGDState
    stats: SessionStats
    monitor: Optional[ConvergenceMonitor]
    reason: str  # "converged" | "evicted" | "exhausted" | "preempted" |
    #              "diverged" | "quarantined" | "shed"
    tick: int  # service tick counter at eviction
    # divergence provenance: the health-escalation ladder state at eviction
    # (offense stamps, quarantine count, last non-zero health word) — set for
    # reason == "diverged" records, None otherwise
    health: Optional[HealthMonitor] = None


@dataclasses.dataclass
class ParkedSession:
    """A converged-and-evicted session kept under drift watch
    (``DriftPolicy(mode="readmit")``): its eviction record (frozen separator
    state + stats), its still-bound signal source (``None`` right after a
    checkpoint restore, until ``bind_source`` re-attaches one — unbound
    sessions skip probes), the probe monitor, and the scheduling metadata it
    re-admits with."""

    record: EvictionRecord
    source: Any
    monitor: DriftMonitor
    meta: SessionMeta
    # service-assigned park stamp (unique per park): the batched probe engine
    # keys its stacked-state cache on it, so an id re-parked with a NEW
    # frozen state can never alias a stale stack
    park_seq: int = -1


@dataclasses.dataclass
class QuarantinedSession:
    """A session pulled from its slot by the health-escalation ladder: its
    last-known-good state (the shadow snapshot it was rolled back to — the
    corrupted state never leaves the kernel), its still-bound source, the
    escalation monitor (offense history + probation streak), and the
    scheduling metadata it re-admits with after probation.  Probed out of
    band like drift-parked sessions, but the probe's HEALTH word (not its
    conv statistic) decides release."""

    record: EvictionRecord
    source: Any
    monitor: HealthMonitor
    meta: SessionMeta


class SeparationService:
    """Continuous-batching front door for a ``SeparatorBank``.

    Sessions (independent separation problems — one user's sensor stream, one
    channel of an EEG array, ...) are admitted into free bank slots and
    evicted when done; ``step`` advances every live session with ONE fused
    bank program per tick.  Slots without fresh data this tick are frozen via
    the bank's active mask, so intermittent streams don't corrupt their state.

        svc = SeparationService(SeparatorBank(ecfg, ocfg, n_streams=64))
        svc.admit("user-a"); svc.admit("user-b")
        outs = svc.step({"user-a": xa, "user-b": xb})   # one fused launch
        final = svc.evict("user-a")                     # SMBGDState handed back

    The tick is zero-copy on a fused bank (``SeparatorBank(fused=True)``):
    mini-batches are staged host-side into ONE preallocated block-aligned
    buffer (``bank.layout``; reused every tick — stale slots are masked
    inactive and the padding region is never written, so no re-zeroing), the
    jitted step donates the persistent padded state back to the kernel
    outputs (accelerator backends), and per-session slices are cut from the
    padded Y at return — steady-state serving allocates no device state per
    tick (the host→device transfer of the staging buffer remains).

    Metrics (the backpressure/observability hook): ``metrics`` (a dict, also
    callable as ``svc.metrics()``) reports per-tick TIME-TO-READY latency
    (last/mean + p50/p99/p999 windowed and lifetime — the tick clock blocks
    on the bank's conv leaf every tick, so the numbers are honest under
    asynchronous dispatch; ``SLOPolicy.sync_every`` samples the sync 1-in-k)
    plus deadline-miss counters; ``session_stats`` reports per-session
    tick/sample counters, queue wait, and SERVICE-TIME samples/sec (queue
    wait excluded).  ``block_ticks=True`` additionally synchronizes on the
    full device result before returning — a stronger guarantee than the
    telemetry sync, kept for lockstep callers.

    Lifecycle (see the module docstring for the full state machine): with
    ``max_queue > 0`` a full bank enqueues admissions instead of raising
    (bounded backpressure) — the waiting room is a pluggable
    ``AdmissionScheduler`` (FIFO by default; ``PriorityScheduler`` adds
    strict priorities + per-tenant quotas, ``DeadlineScheduler`` EDF) — and
    with a ``ConvergencePolicy`` the service watches each active session's
    in-bank convergence statistic and auto-evicts converged sessions at the
    end of the tick — their final ``SMBGDState`` (+ stats) lands in
    ``finished`` / ``pop_finished()`` and the freed slot is backfilled from
    the scheduler within the same tick.  ``on_admit(sid, slot)`` /
    ``on_evict(sid, record)`` / ``on_drift(sid, event)`` callbacks observe
    the transitions (backfills, auto-evictions and watchdog firings
    included).

    Drift (``DriftPolicy``): sessions admitted with a bound ``SignalSource``
    get the re-adaptation lifecycle — converged separators are kept hot with
    a μ boost on re-trigger (``mode="boost"``) or parked and probed
    out-of-band, re-admitted warm when their mixing drifts
    (``mode="readmit"``).  ``run_tick()`` is the pull loop that drives it.
    """

    def __init__(
        self,
        bank: SeparatorBank,
        seed: int = 0,
        block_ticks: bool = False,
        policy: Optional[ConvergencePolicy] = None,
        max_queue: int = 0,
        on_admit: Optional[Callable[[Hashable, int], None]] = None,
        on_evict: Optional[Callable[[Hashable, EvictionRecord], None]] = None,
        scheduler: Optional[AdmissionScheduler] = None,
        drift_policy: Optional[DriftPolicy] = None,
        on_drift: Optional[Callable[[Hashable, DriftEvent], None]] = None,
        health_policy: Optional[HealthPolicy] = None,
        on_health: Optional[Callable[[Hashable, HealthEvent], None]] = None,
        slo: Optional[SLOPolicy] = None,
        moment_policy: Optional[MomentPolicy] = None,
        autoscale: Optional[AutoscalePolicy] = None,
    ):
        self.bank = bank
        if autoscale is not None and bank.hyperparams is not None:
            raise ValueError(
                "autoscale needs a resizable bank: explicit per-stream "
                "hyperparams are (S,)-shaped and cannot follow a resize"
            )
        self.autoscale = autoscale
        self.key = jax.random.PRNGKey(seed)
        self.state: BankState = bank.init(self.key)
        self.policy = policy
        if drift_policy is not None and policy is None:
            raise ValueError(
                "drift_policy needs a ConvergencePolicy: the watchdog only "
                "watches sessions that first converged"
            )
        self.drift_policy = drift_policy
        if health_policy is not None and not bank.health_checks:
            raise ValueError(
                "health_policy needs a bank with health_checks=True: the "
                "escalation ladder consumes the in-kernel health word"
            )
        self.health_policy = health_policy
        self.on_health = on_health
        if moment_policy is not None and not bank.moments:
            raise ValueError(
                "moment_policy needs a bank with moments=True: the adaptive-μ "
                "controller consumes the in-kernel [Σy², Σy⁴] telemetry"
            )
        self.moment_policy = moment_policy
        # per-session kurtosis EMAs over the (S, 2) telemetry leaf; N is the
        # LOGICAL Y entry count P·n (padding contributes zeros to both sums)
        self._moments: Optional[MomentController] = (
            MomentController(
                moment_policy,
                count=bank.opt.batch_size * bank.easi.n_components,
            )
            if moment_policy is not None
            else None
        )
        self.scheduler = (
            scheduler if scheduler is not None else AdmissionScheduler(max_queue)
        )
        self.max_queue = self.scheduler.max_queue
        self.on_admit = on_admit
        self.on_evict = on_evict
        self.on_drift = on_drift
        self._free: List[int] = list(range(bank.n_streams - 1, -1, -1))  # pop() → slot 0 first
        self._slot_of: Dict[Hashable, int] = {}
        self._monitors: Dict[Hashable, ConvergenceMonitor] = {}
        self._mixing: Dict[Hashable, jnp.ndarray] = {}
        self._finished: Dict[Hashable, EvictionRecord] = {}
        self._n_evicted = 0
        self._n_auto_evicted = 0
        # scheduling + drift bookkeeping (all host-side)
        self._meta: Dict[Hashable, SessionMeta] = {}  # ACTIVE sessions only
        self._seq = 0  # admission sequence counter (SessionMeta.order)
        self._sources: Dict[Hashable, Any] = {}  # sid → SignalSource
        self._warm: Dict[Hashable, SMBGDState] = {}  # warm-start states pending activation
        self._hot: Dict[Hashable, DriftMonitor] = {}  # converged-hot drift watches
        self._boost_left: Dict[Hashable, int] = {}  # remaining boosted ticks
        # the three μ ladders write DISJOINT per-slot arrays; composition is
        # pinned in _effective_mu_scale (cut WINS while live, boost and the
        # moment controller MULTIPLY) — one ladder expiring can never clobber
        # another's live multiplier (the PR-9 composition bugfix)
        self._boost_scale = np.ones((bank.n_streams,), dtype=np.float32)
        self._cut_scale = np.ones((bank.n_streams,), dtype=np.float32)
        self._ctrl_scale = np.ones((bank.n_streams,), dtype=np.float32)
        self._cut_on = np.zeros((bank.n_streams,), dtype=bool)
        self._parked: Dict[Hashable, ParkedSession] = {}
        self._drift_events: List[DriftEvent] = []
        self._n_drift_events = 0
        self._probe_ticks = 0  # run_tick counter driving parked probes
        self._probe_fn = None  # lazily-jitted virtual-conv probe (sequential)
        self._probe_banks: Dict[int, Tuple[SeparatorBank, Any]] = {}  # width → (bank, jitted probe)
        self._probe_stacks: Dict[Tuple, BankState] = {}  # chunk stamp → stacked frozen states
        self._park_seq = 0  # monotone park stamp (probe stack-cache keys)
        self._n_probes = 0  # parked sessions probed (any engine)
        self._n_probe_launches = 0  # probe dispatches (the O(parked/batch) win)
        self._restored_positions: Dict[Hashable, int] = {}  # from lifecycle snapshots
        # fault containment (HealthPolicy): escalation monitors, μ-cut
        # countdowns, the quarantine pool, and the per-slot last-known-good
        # shadow snapshot the rollback path restores from
        self._health_mon: Dict[Hashable, HealthMonitor] = {}
        self._cut_left: Dict[Hashable, int] = {}  # remaining μ-cut ticks
        self._quarantined: Dict[Hashable, QuarantinedSession] = {}
        self._shadow: Optional[BankState] = (
            self.state if health_policy is not None else None
        )
        self._health_events: List[HealthEvent] = []
        self._n_health_events = 0
        self._n_rollbacks = 0
        self._n_diverged = 0
        self._n_degraded_ticks = 0  # session-ticks lost to source faults
        self._n_source_retries = 0  # ResilientSource retries folded per tick
        self._last_fault: Dict[Hashable, str] = {}  # sid → last source error
        self._quar_ticks = 0  # run_tick counter driving quarantine probes
        # μ boost (drift), μ cut (health) and the moment controller ride
        # per-stream hyperparameter rows as TRACED operands — only those
        # modes pay for the 4-argument step flavour
        self._hp_step = (
            (drift_policy is not None and drift_policy.mode == "boost")
            or health_policy is not None
            or moment_policy is not None
        )
        if self._hp_step and bank.algorithm != "smbgd_batched":
            raise ValueError(
                "DriftPolicy(mode='boost'), HealthPolicy and MomentPolicy "
                "need per-stream hyperparams, which require "
                "algorithm='smbgd_batched'"
            )
        self._base_hp: Optional[BankHyperparams] = (
            bank._bank_hyperparams() if self._hp_step else None
        )
        # donated state on accelerators: the runtime reuses the old state
        # buffers for the new state — the steady-state tick performs no state
        # allocation (CPU backend opts out; see SeparatorBank.make_step)
        self._step = bank.make_step(with_hyperparams=self._hp_step)
        # elastic machinery: the jitted step is cached per (width, geometry)
        # so an oscillating autoscaler compiles each ladder width once (see
        # prewarm to take even the first compile off the serving path)
        self._step_cache: Dict[Tuple, Any] = {self._step_key(bank): self._step}
        self._n_grows = 0
        self._n_shrinks = 0
        self._n_compactions = 0
        self._resize_history: List[Dict[str, Any]] = []
        self._elastic_ticks = 0  # run_tick counter driving the cooldown
        self._last_resize_tick: Optional[int] = None
        # one staging buffer for every tick: jnp.asarray copies host→device,
        # so the numpy side is free to be overwritten next tick
        if bank.fused:
            lay = bank.layout
            stage_shape = (bank.n_streams, lay.P_pad, lay.m_pad)
        else:
            stage_shape = (bank.n_streams, bank.opt.batch_size, bank.easi.n_features)
        self._stage = np.zeros(stage_shape, dtype=np.float32)
        self.block_ticks = block_ticks
        self._stats: Dict[Hashable, SessionStats] = {}
        self._admit_time: Dict[Hashable, float] = {}  # queue-wait stamps
        self._n_ticks = 0
        self._total_samples = 0
        self._total_tick_s = 0.0
        self._last_tick_s = float("nan")
        # latency SLO machinery (serve.slo): telemetry is always on — the
        # default policy has no deadline budget, so only the time-to-ready
        # sketch runs; a budgeted policy arms misses / shedding / gating
        self.slo = slo if slo is not None else SLOPolicy()
        self._reset_slo()

    def _reset_slo(self) -> None:
        """(Re-)arm the SLO telemetry state — shared by ``__init__`` and
        ``restore`` (serving metrics describe the current epoch only)."""
        pol = self.slo
        self._sketch = LatencySketch(window=pol.window)
        self._timer = TickTimer(sync_every=pol.sync_every)
        self._deadline_mon: Dict[Hashable, DeadlineMonitor] = {}
        self._recent_misses: collections.deque = collections.deque(
            maxlen=pol.miss_window
        )
        self._n_deadline_misses = 0
        self._n_timed_ticks = 0  # ticks with a time-to-ready measurement
        self._timed_samples = 0  # samples served on timed ticks
        self._n_empty_ticks = 0  # run_ticks with no data batch (probe-only)
        self._n_shed = 0
        self._slo_events: List[SLOEvent] = []
        self._n_slo_events = 0
        self._last_shed_tick = -(10**9)
        self._last_probe_s = float("nan")
        # run_tick defers the tick's latency record past the probe phase so
        # probe work is billed to the tick that ran it (see _finish_tick)
        self._pending_tick: Optional[Tuple[List[Hashable], bool, int]] = None
        self._defer_slo = False

    @property
    def n_active(self) -> int:
        return len(self._slot_of)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_queued(self) -> int:
        return len(self.scheduler)

    @property
    def queued(self) -> Tuple[Hashable, ...]:
        """Waiting sessions in the scheduler's pop order (head first)."""
        return self.scheduler.ids()

    @property
    def finished(self) -> Dict[Hashable, EvictionRecord]:
        """Retained eviction records (read-only view; drain with
        ``pop_finished``)."""
        return dict(self._finished)

    def pop_finished(self) -> Dict[Hashable, EvictionRecord]:
        """Drain and return the eviction records accumulated so far."""
        out, self._finished = self._finished, {}
        return out

    @property
    def parked(self) -> Dict[Hashable, ParkedSession]:
        """Sessions under out-of-band drift watch (``mode="readmit"``)."""
        return dict(self._parked)

    @property
    def drift_events(self) -> List[DriftEvent]:
        """Watchdog firings so far (read-only view; drain with
        ``pop_drift_events``)."""
        return list(self._drift_events)

    def pop_drift_events(self) -> List[DriftEvent]:
        out, self._drift_events = self._drift_events, []
        return out

    @property
    def quarantined(self) -> Dict[Hashable, QuarantinedSession]:
        """Sessions pulled from their slots by the health-escalation ladder,
        probed out of band until probation clears (or they diverge)."""
        return dict(self._quarantined)

    @property
    def health_events(self) -> List[HealthEvent]:
        """Containment actions so far (rollback / quarantine / release /
        diverge; read-only view; drain with ``pop_health_events``)."""
        return list(self._health_events)

    def pop_health_events(self) -> List[HealthEvent]:
        out, self._health_events = self._health_events, []
        return out

    def status(self, session_id: Hashable) -> str:
        """Lifecycle state: ``"active" | "converged" | "queued" | "parked" |
        "quarantined" | "finished" | "unknown"`` (``"converged"`` = hot in
        its slot under drift watch; ``"quarantined"`` = pulled from its slot
        by the health ladder, probed out of band until probation clears)."""
        if session_id in self._slot_of:
            return "converged" if session_id in self._hot else "active"
        if session_id in self.scheduler:
            return "queued"
        if session_id in self._parked:
            return "parked"
        if session_id in self._quarantined:
            return "quarantined"
        if session_id in self._finished:
            return "finished"
        return "unknown"

    def set_mixing(self, session_id: Hashable, A: jnp.ndarray) -> None:
        """Register the session's ground-truth mixing matrix ``A (m, n)`` so
        ``ConvergencePolicy.amari_threshold`` can confirm convergence on the
        global system ``B·A`` (benchmarks / synthetic workloads; production
        sessions without ground truth simply never register one).  Sessions
        whose bound source exposes ``true_mixing()`` need no registration —
        the confirmation tracks the source's live mixing instead."""
        if session_id not in self._slot_of and session_id not in self.scheduler:
            raise KeyError(f"session {session_id!r} is neither active nor queued")
        self._mixing[session_id] = jnp.asarray(A)

    def bind_source(self, session_id: Hashable, source, seek: bool = True) -> None:
        """Attach (or replace) a session's ``SignalSource`` — the feed
        ``run_tick`` pulls from (or, for a PARKED session, the feed the drift
        watchdog probes).  After ``restore``, re-bind sources here: the
        cursor positions recorded in the lifecycle snapshot are re-applied
        (``seek=True``, sources exposing ``seek``) so the feed resumes exactly
        where the checkpointed one stopped — restored parked sessions stay
        parked (and un-probeable) until their source is re-bound."""
        if (
            session_id not in self._slot_of
            and session_id not in self.scheduler
            and session_id not in self._parked
            and session_id not in self._quarantined
        ):
            raise KeyError(
                f"session {session_id!r} is neither active nor queued nor "
                f"parked nor quarantined"
            )
        pos = self._restored_positions.pop(session_id, None) if seek else None
        if pos is not None and hasattr(source, "seek"):
            source.seek(pos)
        if session_id in self._parked:
            self._parked[session_id].source = source
            return
        if session_id in self._quarantined:
            self._quarantined[session_id].source = source
            return
        self._sources[session_id] = source

    # -- metrics -----------------------------------------------------------
    @property
    def deadline_miss_rate(self) -> float:
        """Windowed deadline-miss rate: misses over the last ``miss_window``
        timed ticks (0.0 until a budgeted tick has been timed)."""
        if not self._recent_misses:
            return 0.0
        return sum(self._recent_misses) / len(self._recent_misses)

    @property
    def metrics(self) -> "MetricsView":
        """Service-level serving counters (one dict, cheap to scrape; also
        callable — ``svc.metrics()`` works identically).

        Latency keys measure TIME-TO-READY (the tick clock stops after a
        ``block_until_ready`` on the bank's conv leaf — see ``serve.slo``),
        so they are honest on asynchronous backends regardless of
        ``block_ticks``.  ``p50/p99/p999_tick_s`` are exact over the sketch
        window; the ``*_life`` twins are bounded-memory lifetime quantiles.
        ``mean_tick_s``/``samples_per_s`` cover timed DATA ticks;
        probe-only run_ticks count in ``n_empty_ticks`` and land in the
        quantile sketch (they spend wall-clock against the deadline budget
        like any tick) but not in the data-tick means."""
        sk = self._sketch
        return MetricsView({
            "n_active": float(self.n_active),
            "n_free": float(self.n_free),
            "n_queued": float(self.n_queued),
            "n_streams": float(self.bank.n_streams),
            "n_grows": float(self._n_grows),
            "n_shrinks": float(self._n_shrinks),
            "n_compactions": float(self._n_compactions),
            "bank_utilization": self.n_active / self.bank.n_streams,
            "n_hot": float(len(self._hot)),
            "n_parked": float(len(self._parked)),
            "n_drift_events": float(self._n_drift_events),
            "n_probes": float(self._n_probes),
            "n_probe_launches": float(self._n_probe_launches),
            "n_evicted": float(self._n_evicted),
            "n_auto_evicted": float(self._n_auto_evicted),
            "n_quarantined": float(len(self._quarantined)),
            "n_rollbacks": float(self._n_rollbacks),
            "n_diverged": float(self._n_diverged),
            "n_degraded_ticks": float(self._n_degraded_ticks),
            "n_source_retries": float(self._n_source_retries),
            "n_health_events": float(self._n_health_events),
            "n_ticks": float(self._n_ticks),
            "n_empty_ticks": float(self._n_empty_ticks),
            "n_timed_ticks": float(self._n_timed_ticks),
            "total_samples": float(self._total_samples),
            "last_tick_s": self._last_tick_s,
            "last_probe_s": self._last_probe_s,
            "mean_tick_s": self._total_tick_s / self._n_timed_ticks
            if self._n_timed_ticks
            else float("nan"),
            "samples_per_s": self._timed_samples / self._total_tick_s
            if self._total_tick_s > 0
            else float("nan"),
            "n_deadline_misses": float(self._n_deadline_misses),
            "deadline_miss_rate": self.deadline_miss_rate,
            "n_shed": float(self._n_shed),
            "n_slo_events": float(self._n_slo_events),
            **sk.summary(),
        })

    def session_stats(self, session_id: Hashable) -> Dict[str, float]:
        """Per-session counters: ticks, samples, service-time samples/sec,
        seconds spent waiting in the admission queue — plus the convergence
        monitor (smoothed stat, consecutive below-count) when a policy is
        attached and the deadline record (lifetime misses, window-resident
        misses) once the session has seen a budgeted tick."""
        st = self._stats[session_id]
        out = {
            "ticks": float(st.ticks),
            "samples": float(st.samples),
            "samples_per_s": st.samples_per_s(),
            "queue_wait_s": st.queue_wait_s(),
        }
        mon = self._monitors.get(session_id)
        if mon is not None:
            out["conv_stat"] = mon.stat
            out["conv_below"] = float(mon.below)
        dmon = self._deadline_mon.get(session_id)
        if dmon is not None:
            out["deadline_misses"] = float(dmon.misses)
            out["deadline_misses_recent"] = float(len(dmon.recent))
        if self._moments is not None:
            out["mu_ctrl"] = float(self._moments.scale(session_id))
            est = self._moments.estimate(session_id)
            if est is not None:
                out["kurtosis_fast"], out["kurtosis_slow"] = est
        return out

    @property
    def slo_events(self) -> List[SLOEvent]:
        """Load-control actions so far (shed/gate; read-only view — drain
        with ``pop_slo_events``).  Per-tick misses are counters, not events."""
        return list(self._slo_events)

    def pop_slo_events(self) -> List[SLOEvent]:
        out, self._slo_events = self._slo_events, []
        return out

    def _sched_ctx(self) -> SchedulerContext:
        return SchedulerContext(
            tick=self._n_ticks,
            active=dict(self._meta),
            deadline_miss_rate=self.deadline_miss_rate,
        )

    def admit(
        self,
        session_id: Hashable,
        source=None,
        state: Optional[SMBGDState] = None,
        tenant: Optional[str] = None,
        priority: float = 0.0,
        deadline: Optional[float] = None,
    ) -> Optional[int]:
        """Admit ``session_id``: into a free slot (returns the slot index), or
        — when the bank is full and ``max_queue`` allows — into the
        scheduler's waiting room (returns ``None``; the session activates
        when a slot frees and the scheduler picks it).  Raises ``ValueError``
        for duplicate ids and ``RuntimeError`` when bank AND queue are full
        (backpressure: the caller must shed load or retry later).

        ``source`` binds a ``SignalSource`` for ``run_tick`` ingestion (and
        the drift watchdog).  ``state`` warm-starts the session from an
        existing ``SMBGDState`` instead of a fresh init (the re-admission
        path).  ``tenant``/``priority``/``deadline`` are scheduling metadata
        (``SessionMeta``) consumed by the configured ``AdmissionScheduler``.

        When every slot is held but some by HOT (converged, drift-watched)
        sessions, the least-drifted hot session is preempted to make room —
        keeping separators warm never starves new work."""
        if session_id in self._slot_of or session_id in self.scheduler:
            raise ValueError(f"session {session_id!r} already admitted")
        if session_id in self._parked:
            raise ValueError(
                f"session {session_id!r} is parked under drift watch; "
                f"evict it first to force a fresh admission"
            )
        if session_id in self._quarantined:
            raise ValueError(
                f"session {session_id!r} is quarantined under health watch; "
                f"evict it first to force a fresh admission"
            )
        meta = SessionMeta(
            tenant=tenant, priority=float(priority), deadline=deadline,
            order=self._seq,
        )
        self._seq += 1
        # queue-wait clock starts NOW — _activate stamps the other end
        self._admit_time[session_id] = time.perf_counter()
        if source is not None:
            self._sources[session_id] = source
        if state is not None:
            self._warm[session_id] = state
        if not self._free and self._hot:
            ctx = self._sched_ctx()
            # preempt a warm separator only for work that can actually take
            # the slot — a quota-gated admission must not cost anyone warmth
            if self.scheduler.can_activate(meta, ctx) or self.scheduler.has_eligible(ctx):
                self._preempt_hot()
        try:
            if (
                self._free
                and not len(self.scheduler)
                and not self._slo_gated()
                and self.scheduler.can_activate(meta, self._sched_ctx())
            ):
                self._meta[session_id] = meta
                return self._activate(session_id)
            if not self._free and self.scheduler.full:
                raise RuntimeError(
                    f"bank full ({self.bank.n_streams} slots, "
                    f"{len(self.scheduler)}/{self.max_queue} queued); evict "
                    f"before admitting"
                )
            # free slots may exist while sessions wait (tenant at quota /
            # non-empty queue / SLO admission gate): enqueue and let the
            # scheduler pick when the gate reopens
            self.scheduler.push(session_id, meta)
        except (RuntimeError, ValueError):
            self._sources.pop(session_id, None)
            self._warm.pop(session_id, None)
            self._admit_time.pop(session_id, None)
            raise
        self._backfill()
        return self._slot_of.get(session_id)

    def _activate(self, session_id: Hashable) -> int:
        """QUEUED/new → ACTIVE: claim a free slot and initialize it (the
        session's device state is born here, so the γ step-0 gate applies at
        its first *served* tick).  Warm-start admissions instead write their
        carried ``SMBGDState`` into the slot (step counter and all)."""
        slot = self._free.pop()
        warm = self._warm.pop(session_id, None)
        if warm is not None:
            self.state = self.bank.set_slot(self.state, slot, warm)
        else:
            self.key, k = jax.random.split(self.key)
            self.state = self.bank.init_slot(self.state, slot, k)
        self._slot_of[session_id] = slot
        self._meta.setdefault(session_id, SessionMeta(order=self._seq))
        self._reset_mu(slot)
        if self._moments is not None:
            # a slot's new occupant (fresh OR warm re-admission) starts with
            # no kurtosis reference — the EMAs re-seed on its first tick
            self._moments.reset(session_id)
        now = time.perf_counter()
        self._stats[session_id] = SessionStats(
            admitted_at=self._admit_time.pop(session_id, now),
            activated_at=now,
        )
        self._monitors[session_id] = ConvergenceMonitor()
        if self._shadow is not None:
            # seed the slot's shadow from the state it was just born with —
            # a first-offense rollback must restore THIS session's state,
            # never the slot's previous occupant's
            self._shadow = self.bank.copy_slot(self._shadow, self.state, slot)
        if self.health_policy is not None:
            # quarantine releases re-enter with their ladder memory intact
            # (setdefault keeps the monitor _release_quarantine pre-seeded)
            self._health_mon.setdefault(session_id, HealthMonitor())
        if self.on_admit is not None:
            self.on_admit(session_id, slot)
        return slot

    def _slo_gated(self) -> bool:
        """Is the SLO admission gate closed?  True while
        ``SLOPolicy(gate_admissions=True)`` and the windowed deadline-miss
        rate is over ``max_miss_rate`` — free slots stay free (and direct
        admissions queue) until the window recovers, so shedding/gating can
        actually reduce load instead of instantly re-filling it."""
        return (
            self.slo.gate_admissions
            and self.slo.deadline_budget_s is not None
            and self.deadline_miss_rate > self.slo.max_miss_rate
        )

    def _backfill(self) -> None:
        """Fill free slots from the scheduler until it runs out of eligible
        sessions (``pop`` returning ``None`` = everyone gated, e.g. tenants
        at quota — the slot stays free and we retry at the next release or
        ``run_tick``).  The SLO admission gate holds backfills entirely
        while the service is over its deadline-miss ceiling (one ``"gate"``
        event per closed-gate attempt with waiting work)."""
        if self._slo_gated():
            if self._free and len(self.scheduler):
                self._record_slo(
                    SLOEvent(
                        session_id=None,
                        tick=self._n_ticks,
                        tick_s=self._last_tick_s,
                        budget_s=float(self.slo.deadline_budget_s),
                        action="gate",
                        miss_rate=self.deadline_miss_rate,
                    )
                )
            return
        while self._free and len(self.scheduler):
            popped = self.scheduler.pop(self._sched_ctx())
            if popped is None:
                return
            sid, meta = popped
            self._meta[sid] = meta
            self._activate(sid)

    def _preempt_hot(self) -> None:
        """Evict the least-drifted HOT session to free a slot for waiting
        work (reason ``"preempted"`` — its record lands in ``finished``)."""
        conv = np.asarray(self.state.conv)
        victim = min(
            self._hot, key=lambda sid: float(conv[self._slot_of[sid]])
        )
        self._release(victim, reason="preempted")

    def evict(self, session_id: Hashable) -> Optional[SMBGDState]:
        """ACTIVE → EVICTED: release the slot and return the session's final
        single-stream state (B is its learned separation matrix), backfilling
        the freed slot from the scheduler.  A QUEUED session is simply
        dequeued (returns ``None`` — it never had device state); a PARKED
        session is taken off drift watch (its frozen state is returned and
        its record moves to ``finished``).  An unknown id raises ``KeyError``
        without touching the free list."""
        if session_id in self._slot_of:
            return self._release(session_id, reason="evicted").state
        if self.scheduler.remove(session_id):  # cancellation of a queued session
            self._mixing.pop(session_id, None)
            self._sources.pop(session_id, None)
            self._warm.pop(session_id, None)
            self._admit_time.pop(session_id, None)
            return None
        if session_id in self._parked:
            ps = self._parked.pop(session_id)
            self._finished[session_id] = ps.record
            return ps.record.state
        if session_id in self._quarantined:
            qs = self._quarantined.pop(session_id)
            self._health_mon.pop(session_id, None)
            self._finished[session_id] = qs.record
            return qs.record.state
        raise KeyError(
            f"session {session_id!r} is neither active nor queued (nor "
            f"parked nor quarantined)"
        )

    def _release(
        self,
        session_id: Hashable,
        reason: str,
        health: Optional[HealthMonitor] = None,
    ) -> EvictionRecord:
        """ACTIVE → EVICTED edge shared by manual ``evict``, the policy's
        auto-eviction, hot-session preemption, source exhaustion, the
        readmit-mode park and the health ladder's divergence eviction: slice
        the final state out of the bank, free the slot, record the eviction,
        and backfill from the scheduler — all before the next tick touches
        the bank."""
        slot = self._slot_of.pop(session_id)
        record = EvictionRecord(
            state=self.bank.slot_state(self.state, slot),
            stats=self._stats.pop(session_id),
            monitor=self._monitors.pop(session_id, None),
            reason=reason,
            tick=self._n_ticks,
            health=health,
        )
        self._mixing.pop(session_id, None)
        meta = self._meta.pop(session_id, None)
        self._hot.pop(session_id, None)
        self._boost_left.pop(session_id, None)
        self._cut_left.pop(session_id, None)
        self._health_mon.pop(session_id, None)
        self._deadline_mon.pop(session_id, None)
        self._admit_time.pop(session_id, None)
        self._reset_mu(slot)
        if self._moments is not None:
            self._moments.forget(session_id)
        self._free.append(slot)
        self._n_evicted += 1
        if reason == "converged":
            self._n_auto_evicted += 1
        source = self._sources.pop(session_id, None)
        if (
            reason == "converged"
            and source is not None
            and self.drift_policy is not None
            and self.drift_policy.mode == "readmit"
        ):
            # PARK instead of finishing: the frozen separator + its source
            # stay under out-of-band drift watch (see _probe_parked)
            self._parked[session_id] = ParkedSession(
                record=record,
                source=source,
                monitor=DriftMonitor(),
                meta=meta if meta is not None else SessionMeta(),
            )
        else:
            self._finished[session_id] = record
        if self.on_evict is not None:
            self.on_evict(session_id, record)
        # same-tick backfill: the freed slot was appended last, so the
        # scheduler's pick lands exactly in the slot that just opened
        self._backfill()
        return record

    def step(self, batches: Dict[Hashable, jnp.ndarray]) -> Dict[Hashable, jnp.ndarray]:
        """Advance every session that sent data this tick.

        ``batches`` maps session_id → ``(P, m)`` mini-batch.  Sessions without
        data (and free slots) are masked inactive — state untouched.  Returns
        session_id → separated ``(P, n)`` outputs from one fused bank step.

        On a fused bank the staging buffer is allocated block-aligned
        (``(S, P_pad, m_pad)``) so the jitted step consumes it with no
        re-padding copy; outputs are sliced back to ``(P, n)`` per session.
        """
        if not batches:
            return {}
        unknown = set(batches) - set(self._slot_of)
        if unknown:
            # never silently drop data: queued/parked sessions hold no slot
            # (their batch would corrupt nothing but vanish), unknown ids are
            # caller bugs — name each class so the fix is obvious
            queued = sorted(str(s) for s in unknown if s in self.scheduler)
            parked = sorted(str(s) for s in unknown if s in self._parked)
            quar = sorted(str(s) for s in unknown if s in self._quarantined)
            msg = f"sessions not active: {sorted(map(str, unknown))}"
            if queued:
                msg += (
                    f"; queued with no slot yet (wait for activation or raise "
                    f"capacity): {queued}"
                )
            if parked:
                msg += f"; parked under drift watch (evict to detach): {parked}"
            if quar:
                msg += (
                    f"; quarantined under health watch (awaiting probation): "
                    f"{quar}"
                )
            raise KeyError(msg)
        S = self.bank.n_streams
        P = self.bank.opt.batch_size
        m = self.bank.easi.n_features
        n = self.bank.easi.n_components
        # reused staging buffer (block-aligned on fused banks): stale data in
        # slots not written this tick only feeds masked-out streams, and the
        # padding region is never written, so it stays zero from __init__
        X = self._stage
        active = np.zeros((S,), dtype=bool)
        for sid, xb in batches.items():
            xb = np.asarray(xb, dtype=np.float32)
            if xb.shape != (P, m):  # don't let numpy broadcast a wrong batch
                raise ValueError(
                    f"session {sid!r}: batch shape {xb.shape} != required "
                    f"(P={P}, m={m})"
                )
            slot = self._slot_of[sid]
            X[slot, :P, :m] = xb
            active[slot] = True
        # time-to-ready tick clock (PR-8 fix): JAX dispatches asynchronously,
        # so stopping at dispatch measured nothing on a real accelerator.
        # The timer blocks on the bank's conv leaf — a tiny (S,) vector whose
        # readiness implies the whole bank program retired — every tick (or
        # 1-in-k under SLOPolicy.sync_every); block_ticks=True keeps its
        # stronger full-result sync and is timed as-is.
        timer = self._timer
        timer.start()
        if self._hp_step:
            self.state, Y = self._step(
                self.state, jnp.asarray(X), jnp.asarray(active), self._current_hp()
            )
        else:
            self.state, Y = self._step(self.state, jnp.asarray(X), jnp.asarray(active))
        if self.block_ticks:
            jax.block_until_ready((self.state, Y))
            dt, timed = timer.stop(already_synced=True)
        else:
            dt, timed = timer.stop(sync_leaf=self.state.conv)
        self._n_ticks += 1
        self._total_samples += P * len(batches)
        for sid in batches:
            st = self._stats[sid]
            st.ticks += 1
            st.samples += P
        # slice outputs BEFORE any auto-eviction mutates the slot map: evicted
        # sessions still receive this tick's separated output.  Slot index as
        # a traced operand (bank._dyn), not a Python-int constant: a baked
        # index compiles a separate eager slice program per (slot, width) —
        # a per-slot compile storm on the first tick at every new width
        out = {
            sid: Y[self.bank._dyn(self._slot_of[sid]), :P, :n]
            for sid in batches
        }
        served = list(batches.keys())
        if self._moments is not None:
            # one (S, 2) host read per tick: fold this tick's raw moments
            # into each served session's kurtosis EMAs and refresh its μ
            # multiplier (consumed by _current_hp next tick — traced operand,
            # no retrace)
            mom = np.asarray(self.state.moments)
            for sid in served:
                slot = self._slot_of[sid]
                self._ctrl_scale[slot] = self._moments.observe(
                    sid, float(mom[slot, 0]), float(mom[slot, 1])
                )
        if self._defer_slo:
            # called from run_tick: the tick's latency record is finished
            # AFTER the probe phase, so probe time is billed to this tick
            self._pending_tick = (served, timed, P * len(batches))
        else:
            self._finish_tick(dt, served, timed, P * len(batches))
        if self.health_policy is not None:
            # containment first: offenders are rolled back / quarantined /
            # diverged and drop out of this tick's convergence sweep (their
            # conv statistic was never committed anyway)
            served = self._apply_health(served)
        if self.policy is not None:
            self._apply_policy(served)
        return out

    def _finish_tick(
        self, dt: float, served: List[Hashable], timed: bool, samples: int
    ) -> None:
        """Close out one data tick's latency record.  Sampled-out ticks
        (``timed=False`` — SLOPolicy.sync_every > 1) stopped the clock at
        dispatch: they carry no latency information and are dropped entirely
        rather than recorded as fiction."""
        if not timed:
            return
        self._last_tick_s = dt
        self._total_tick_s += dt
        self._n_timed_ticks += 1
        self._timed_samples += samples
        self._record_latency(dt, served)

    def _record_slo(self, event: SLOEvent) -> None:
        self._slo_events.append(event)
        self._n_slo_events += 1

    def _record_latency(self, dt: float, served: List[Hashable]) -> None:
        """Fold one timed latency into the sketch and — under a budget —
        the deadline machinery: the service miss window, every served
        session's ``DeadlineMonitor``, and (opted in) the shed decision.
        The shed victim is the still-active session with the most
        window-resident misses (ties → lower priority, younger admission):
        the session most consistently present when the budget blows is the
        best guess at the expensive one."""
        self._sketch.add(dt)
        pol = self.slo
        budget = pol.deadline_budget_s
        if budget is None:
            return
        missed = dt > budget
        if missed:
            self._n_deadline_misses += 1
        self._recent_misses.append(1 if missed else 0)
        victim, victim_rank = None, None
        for sid in served:
            mon = self._deadline_mon.setdefault(sid, DeadlineMonitor())
            count = mon.record(self._n_ticks, missed, pol)
            if sid not in self._slot_of:
                continue  # evicted/parked by this tick's sweeps
            meta = self._meta.get(sid) or SessionMeta()
            rank = (-count, meta.priority, -meta.order)
            if victim_rank is None or rank < victim_rank:
                victim, victim_rank = sid, rank
        if not missed:
            return
        rate = self.deadline_miss_rate
        if (
            pol.shed
            and rate > pol.max_miss_rate
            and victim is not None
            and self.n_active > 1
            and self._n_ticks - self._last_shed_tick >= pol.shed_cooldown
        ):
            self._last_shed_tick = self._n_ticks
            self._n_shed += 1
            self._release(victim, reason="shed")
            self._record_slo(
                SLOEvent(
                    session_id=victim,
                    tick=self._n_ticks,
                    tick_s=dt,
                    budget_s=float(budget),
                    action="shed",
                    miss_rate=rate,
                )
            )

    def _apply_policy(self, served) -> None:
        """End-of-tick convergence + drift sweep: update each served session's
        monitor from the bank's in-step statistic, auto-evict (or park / keep
        hot) the converged ones, fire the drift watchdog for hot sessions,
        and backfill freed slots from the scheduler (same tick).

        One (S,)-float device read per tick — the statistic itself was folded
        inside the bank step (in-register on the fused path)."""
        pol = self.policy
        dpol = self.drift_policy
        conv = np.asarray(self.state.conv)  # (S,) f32
        evict_now: List[Hashable] = []
        for sid in served:
            slot = self._slot_of[sid]
            x = float(conv[slot])
            if sid in self._hot:
                # converged-hot: the DRIFT watchdog owns this session now
                if self._hot[sid].update(x, dpol):
                    self._fire_boost(sid, slot)
                continue
            if sid in self._boost_left:
                # re-adapting under μ boost: count the boost down (expiry
                # releases only the BOOST ladder — a live μ-cut or controller
                # scale on the same slot is untouched)
                self._boost_left[sid] -= 1
                if self._boost_left[sid] <= 0:
                    del self._boost_left[sid]
                    self._boost_scale[slot] = 1.0
            mon = self._monitors[sid]
            mon.update(x, pol)
            if mon.ticks < pol.min_ticks or mon.below < pol.patience:
                continue
            if pol.amari_threshold is not None:
                A = self._mixing.get(sid)
                if A is None and sid in self._sources:
                    # drifting synthetic sources report their live mixing
                    A = sources_lib.true_mixing_of(self._sources[sid])
                if A is not None:
                    B = self.bank.slot_state(self.state, slot).B
                    pi = float(
                        metrics_lib.amari_index(
                            metrics_lib.global_system(B, jnp.asarray(A))
                        )
                    )
                    if pi > pol.amari_threshold:
                        continue  # blind stat dipped early — not separated yet
            if (
                dpol is not None
                and dpol.mode == "boost"
                and sid in self._sources
                and not self.scheduler.has_eligible(self._sched_ctx())
            ):
                # keep HOT: hold the slot, keep serving, watch for drift
                # (capacity pressure wins over warmth — but only a waiting
                # session that could actually take the slot counts)
                self._hot[sid] = DriftMonitor()
                if sid in self._boost_left:
                    # re-converged before the boost ran out: the boost did
                    # its job — μ returns to base for the hot watch
                    del self._boost_left[sid]
                    self._boost_scale[slot] = 1.0
                continue
            evict_now.append(sid)
        for sid in evict_now:
            self._release(sid, reason="converged")

    # -- drift watchdog ----------------------------------------------------
    def _record_drift(self, event: DriftEvent) -> None:
        self._drift_events.append(event)
        self._n_drift_events += 1
        if self.on_drift is not None:
            self.on_drift(event.session_id, event)

    def _fire_boost(self, session_id: Hashable, slot: int) -> None:
        """HOT → ACTIVE: the watchdog saw the conv statistic rise — boost the
        session's per-stream μ and make it re-earn convergence."""
        mon = self._hot.pop(session_id)
        dpol = self.drift_policy
        self._monitors[session_id] = ConvergenceMonitor()
        if dpol.boost != 1.0:
            self._boost_scale[slot] = dpol.boost
            self._boost_left[session_id] = dpol.boost_ticks
        self._record_drift(
            DriftEvent(
                session_id=session_id,
                tick=self._n_ticks,
                stat=mon.stat,
                action="boost",
                slot=slot,
            )
        )

    def _reset_mu(self, slot: int) -> None:
        """Clear every μ ladder's multiplier for ``slot`` (slot turnover:
        activation, release, quarantine)."""
        self._boost_scale[slot] = 1.0
        self._cut_scale[slot] = 1.0
        self._ctrl_scale[slot] = 1.0
        self._cut_on[slot] = False

    def _effective_mu_scale(self) -> np.ndarray:
        """The pinned composition of the three μ writers, per slot: a live
        HealthPolicy cut WINS outright (containment beats adaptation — never
        boost a separator that just rolled back), otherwise the DriftPolicy
        boost and the moment controller MULTIPLY."""
        return np.where(
            self._cut_on, self._cut_scale, self._boost_scale * self._ctrl_scale
        ).astype(np.float32)

    def _current_hp(self) -> BankHyperparams:
        """Per-stream hyperparameter rows for THIS tick: the bank's base
        (μ, β, γ) with the composed μ multipliers folded in
        (``_effective_mu_scale`` — cut wins, boost × controller multiply).
        Traced operands — varying them tick to tick costs no retrace."""
        hp = self._base_hp
        if self._boost_left or self._cut_left or self._moments is not None:
            return BankHyperparams(
                mu=hp.mu * jnp.asarray(self._effective_mu_scale()),
                beta=hp.beta,
                gamma=hp.gamma,
            )
        return hp

    # -- fault containment (HealthPolicy) ----------------------------------
    def _record_health(self, event: HealthEvent) -> None:
        self._health_events.append(event)
        self._n_health_events += 1
        if self.on_health is not None:
            self.on_health(event.session_id, event)

    def _apply_health(self, served: List[Hashable]) -> List[Hashable]:
        """End-of-tick containment sweep: read the (S,) health words the
        kernel folded into this tick, walk the escalation ladder for every
        offender (rollback + μ cut → quarantine → evict ``"diverged"``), and
        refresh the copy-on-healthy shadow every ``shadow_every`` ticks.
        Returns the served sessions still active and healthy — the set the
        convergence sweep may judge this tick.

        The kernel already refused the offenders' commits (pre-tick state in
        the slot), so the rollback's job is rewinding the *trajectory*: the
        pre-tick state may itself be mid-divergence, and the shadow is the
        last state that survived ``shadow_every`` ticks of health checks."""
        hpol = self.health_policy
        words = np.asarray(self.state.health)  # (S,) int32, this tick's verdict
        healthy: List[Hashable] = []
        for sid in served:
            slot = self._slot_of.get(sid)
            if slot is None:
                continue
            word = int(words[slot])
            mon = self._health_mon.setdefault(sid, HealthMonitor())
            if word == 0:
                mon.healthy_streak += 1
                if sid in self._cut_left:
                    self._cut_left[sid] -= 1
                    if self._cut_left[sid] <= 0:
                        del self._cut_left[sid]
                        # the cut expiring hands μ BACK to boost × controller
                        # (their multipliers kept ticking underneath)
                        self._cut_scale[slot] = 1.0
                        self._cut_on[slot] = False
                healthy.append(sid)
                continue
            escalate = mon.record_offense(self._n_ticks, word, hpol)
            # roll the slot back to its last-known-good shadow regardless of
            # what happens next: the quarantine/diverged record must carry
            # the recoverable state, not the one that was drifting apart
            self.state = self.bank.restore_slot(self.state, self._shadow, slot)
            if self._moments is not None:
                # the rolled-back separator invalidates the kurtosis
                # reference: drop the EMAs, re-seed from the next clean tick
                self._moments.reset(sid)
                self._ctrl_scale[slot] = 1.0
            if not escalate:
                self._n_rollbacks += 1
                self._cut_scale[slot] = hpol.mu_cut
                self._cut_on[slot] = True
                self._cut_left[sid] = hpol.cut_ticks
                self._record_health(
                    HealthEvent(sid, self._n_ticks, word, "rollback", slot)
                )
            elif mon.quarantines >= hpol.max_quarantines:
                self._health_mon.pop(sid, None)
                self._release(sid, reason="diverged", health=mon)
                self._n_diverged += 1
                self._record_health(
                    HealthEvent(sid, self._n_ticks, word, "diverge", slot)
                )
            else:
                self._quarantine(sid, word)
        if self._n_ticks % hpol.shadow_every == 0:
            # copy-on-healthy: only slots that PASSED this tick's checks may
            # refresh their shadow (offenders were just rolled back — copying
            # them would be a no-op, but masking keeps the invariant obvious)
            mask = np.zeros((self.bank.n_streams,), dtype=bool)
            for sid in healthy:
                mask[self._slot_of[sid]] = True
            self._shadow = self.bank.update_shadow(
                self._shadow, self.state, jnp.asarray(mask)
            )
        return healthy

    def _quarantine(self, session_id: Hashable, word: int) -> None:
        """ACTIVE → QUARANTINED: the session used up its rollback budget —
        free the slot (the record carries the just-rolled-back last-known-good
        state) and park it under out-of-band health probes until probation
        clears or the ladder tops out."""
        slot = self._slot_of.pop(session_id)
        mon = self._health_mon.pop(session_id, None) or HealthMonitor()
        mon.quarantines += 1
        mon.healthy_streak = 0
        record = EvictionRecord(
            state=self.bank.slot_state(self.state, slot),
            stats=self._stats.pop(session_id),
            monitor=self._monitors.pop(session_id, None),
            reason="quarantined",
            tick=self._n_ticks,
        )
        self._mixing.pop(session_id, None)
        meta = self._meta.pop(session_id, None)
        self._hot.pop(session_id, None)
        self._boost_left.pop(session_id, None)
        self._cut_left.pop(session_id, None)
        self._deadline_mon.pop(session_id, None)
        self._reset_mu(slot)
        if self._moments is not None:
            self._moments.forget(session_id)
        self._free.append(slot)
        self._quarantined[session_id] = QuarantinedSession(
            record=record,
            source=self._sources.pop(session_id, None),
            monitor=mon,
            meta=meta if meta is not None else SessionMeta(),
        )
        self._record_health(
            HealthEvent(session_id, self._n_ticks, word, "quarantine", slot)
        )
        self._backfill()

    def _probe_quarantined(self) -> None:
        """Every ``probe_every`` run_ticks, probe every sourced quarantined
        session out of band: stack the last-known-good states into transient
        pow-2 probe banks (the same machinery as the drift watchdog's parked
        probes) and read the VIRTUAL health word a step on fresh data would
        produce.  A healthy probe advances the probation streak; ``probation``
        consecutive healthy probes re-admit the session warm (through the
        scheduler).  An unhealthy probe resets the streak and counts as an
        offense on the same ladder — a session whose ladder tops out
        (``quarantines > max_quarantines``) evicts with reason
        ``"diverged"``."""
        hpol = self.health_policy
        if not self._quarantined or hpol is None:
            return
        self._quar_ticks += 1
        if self._quar_ticks % hpol.probe_every:
            return
        due = list(self._quarantined)
        P = self.bank.opt.batch_size
        m = self.bank.easi.n_features
        pulled: List[Tuple[Hashable, QuarantinedSession, np.ndarray]] = []
        for sid in due:
            qs = self._quarantined[sid]
            blk = self._pull_probe_block(
                sid, qs, pool=self._quarantined, probe_every=hpol.probe_every
            )
            if blk is not None:
                pulled.append((sid, qs, blk))
        batch = 64  # quarantine pools are small; one pow-2 launch per 64
        for lo in range(0, len(pulled), batch):
            chunk = pulled[lo : lo + batch]
            width = self._probe_width(len(chunk))
            bank, probe_fn = self._probe_bank(width)
            states = [qs.record.state for _, qs, _ in chunk]
            states += [states[-1]] * (width - len(chunk))
            state = SeparatorBank.stack_states(states)
            if bank.fused:
                state = bank.pad_state(state)
                lay = bank.layout
                P_stage, m_stage = lay.P_pad, lay.m_pad
            else:
                P_stage, m_stage = P, m
            X = np.zeros((width, P_stage, m_stage), dtype=np.float32)
            for j, (_, _, blk) in enumerate(chunk):
                X[j, :P, :m] = blk.T
            active = np.zeros((width,), dtype=np.int32)
            active[: len(chunk)] = 1
            _conv, health, _mom = probe_fn(
                state, jnp.asarray(X), jnp.asarray(active)
            )
            health = np.asarray(health)
            self._n_probes += len(chunk)
            self._n_probe_launches += 1
            for j, (sid, qs, _) in enumerate(chunk):
                word = int(health[j])
                if word == 0:
                    qs.monitor.healthy_streak += 1
                    if qs.monitor.healthy_streak >= hpol.probation:
                        self._release_quarantine(sid, qs)
                else:
                    qs.monitor.healthy_streak = 0
                    qs.monitor.last_word = word
                    # a failed probe is an offense on the same ladder: when
                    # the rollback budget is exhausted AGAIN while already
                    # quarantined, the quarantine counter climbs — a session
                    # that never produces a healthy probe tops out without
                    # ever being released
                    if qs.monitor.record_offense(self._n_ticks, word, hpol):
                        qs.monitor.quarantines += 1
                    if qs.monitor.quarantines > hpol.max_quarantines:
                        del self._quarantined[sid]
                        record = dataclasses.replace(
                            qs.record,
                            reason="diverged",
                            tick=self._n_ticks,
                            health=qs.monitor,
                        )
                        self._finished[sid] = record
                        self._n_evicted += 1
                        self._n_diverged += 1
                        self._record_health(
                            HealthEvent(sid, self._n_ticks, word, "diverge")
                        )
                        if self.on_evict is not None:
                            self.on_evict(sid, record)

    def _release_quarantine(
        self, session_id: Hashable, qs: QuarantinedSession
    ) -> None:
        """QUARANTINED → ACTIVE after probation: back through the scheduler's
        admission gate, warm-started from the last-known-good state, with the
        escalation ladder's memory intact (a repeat offender escalates past
        its earlier rungs).  Like ``_readmit``, the release only proceeds
        when it can activate immediately — otherwise the session stays
        quarantined and the next probe retries."""
        del self._quarantined[session_id]
        self._health_mon[session_id] = qs.monitor
        try:
            slot = self.admit(
                session_id,
                source=qs.source,
                state=qs.record.state,
                tenant=qs.meta.tenant,
                priority=qs.meta.priority,
                deadline=qs.meta.deadline,
            )
        except RuntimeError:  # bank AND queue full: stay quarantined
            self._health_mon.pop(session_id, None)
            self._quarantined[session_id] = qs
            return
        if slot is None:  # would queue: back out, stay quarantined
            self.evict(session_id)  # dequeues; detaches source/warm bindings
            self._health_mon.pop(session_id, None)
            self._quarantined[session_id] = qs
            return
        self._record_health(
            HealthEvent(
                session_id, self._n_ticks, qs.monitor.last_word, "release", slot
            )
        )

    def _virtual_conv(self, state: SMBGDState, X: jnp.ndarray) -> float:
        """The conv statistic a bank step WOULD commit from ``state`` on
        ``X (P, m)`` — same ``‖ΔB‖_F/‖B‖_F`` formula, computed out of band
        without touching the bank (the parked-session drift probe)."""
        if self._probe_fn is None:
            ecfg, ocfg = self.bank.easi, self.bank.opt

            def probe(st, x):
                st2, _ = smbgd_lib.smbgd_batched_step(st, x, ecfg, ocfg)
                return metrics_lib.update_magnitude(st2.B, st.B)

            self._probe_fn = jax.jit(probe)
        return float(self._probe_fn(state, X))

    def _probe_parked(self) -> None:
        """Every ``probe_every`` run_ticks, probe every parked session: pull
        one block per parked source, compute the virtual conv statistics (the
        update a bank step WOULD commit from each frozen state), fold them
        into the drift monitors, and re-admit (warm-started, through the
        scheduler) the sessions whose mixing has drifted.  A parked source
        that drains mid-probe evicts the session (reason ``"exhausted"``).

        The due batch — all parked sessions, in park order — runs through the
        BATCHED engine by default: frozen states are stacked into a transient
        probe bank and each ``probe_batch``-wide chunk costs ONE no-commit
        bank launch (``stream.SeparatorBank.probe``; the megakernel's
        freeze-only fast path on fused banks), so watchdog latency scales as
        O(parked / probe_batch) dispatches.  ``DriftPolicy(probe_batch=0)``
        selects the legacy sequential loop — one jitted dispatch per session
        — kept as the oracle the batched engine is differentially tested
        against.  Probe decisions are applied in park order in both engines,
        so they re-admit identically.

        Probes treat the source as LIVE: a parked session is not consuming
        its feed, so the samples that arrived between probes are skipped
        (``seek`` past them, for sources exposing a cursor) — the probe sees
        the present, and parked time advances at service time.

        With ``DriftPolicy.probe_phases > 1`` the parked population is
        STAGGERED: each session hashes (stably, by id) into one of
        ``probe_phases`` buckets and only the rotating due bucket is probed
        per probe tick, so a large parked pool spreads its probe cost over
        ``probe_phases`` ticks instead of stalling one.  Every session keeps
        a fixed probe period of ``probe_every * probe_phases`` run_ticks
        (the seek-past skip accounts for it); ``probe_phases=1`` is exactly
        the legacy everyone-at-once sweep."""
        dpol = self.drift_policy
        if not self._parked or dpol is None or dpol.mode != "readmit":
            return
        self._probe_ticks += 1
        if self._probe_ticks % dpol.probe_every:
            return
        due = list(self._parked)  # the due batch, in park order
        if dpol.probe_phases > 1:
            # rotating bucket: probe cycle k serves phase k mod probe_phases
            phase = (self._probe_ticks // dpol.probe_every) % dpol.probe_phases
            due = [
                sid
                for sid in due
                if self._probe_phase(sid, dpol.probe_phases) == phase
            ]
        if not due:
            return
        if dpol.probe_batch == 0:
            self._probe_sequential(due)
        else:
            self._probe_batched(due)

    @staticmethod
    def _probe_phase(sid: Hashable, phases: int) -> int:
        """Stable stagger bucket of a parked session: the same
        JSON-serialized crc32 the parked-leaf fingerprint uses, mod the
        bucket count — deterministic across processes and restores (Python's
        ``hash`` is salted per process and would reshuffle buckets on every
        restart)."""
        import zlib

        return zlib.crc32(json.dumps(sid, default=str).encode()) % phases

    def _pull_probe_block(
        self,
        sid: Hashable,
        ps,
        pool: Optional[Dict[Hashable, Any]] = None,
        probe_every: Optional[int] = None,
    ):
        """Seek ``sid``'s parked (or quarantined) source to service time and
        pull one probe block ``(m, P)``.  Returns ``None`` when the session
        cannot be probed this tick: no source bound yet (fresh restore
        awaiting ``bind_source``), the source faulted (degraded probe — the
        wrapper's retries were already spent), or the source drained — which
        EVICTS the session from ``pool`` with reason ``"exhausted"`` (a
        drained feed is a finished session; no exception ever escapes
        ``run_tick``)."""
        if ps.source is None:
            return None
        pool = self._parked if pool is None else pool
        if probe_every is None:
            # a staggered session's effective period is probe_every ×
            # probe_phases run_ticks — the seek must skip the whole gap or
            # staggered probes would lag live time by (phases−1) windows
            dpol = self.drift_policy
            probe_every = dpol.probe_every * max(dpol.probe_phases, 1)
        P = self.bank.opt.batch_size
        skip = (probe_every - 1) * P
        if skip and hasattr(ps.source, "seek") and hasattr(ps.source, "position"):
            target = ps.source.position + skip
            limit = getattr(ps.source, "n_samples", None)
            if limit is not None and getattr(ps.source, "loop", False):
                target %= max(limit, 1)  # looping feed: modular live time
            elif limit is not None:
                # finite feed near its end: clamp to the last full block
                # so the probe still measures the PRESENT, not a window
                # from (probe_every-1) ticks ago — but never move the
                # cursor backward (a fully drained feed must exhaust,
                # not re-probe its final block forever)
                target = max(
                    min(target, max(limit - P, 0)), ps.source.position
                )
            try:
                ps.source.seek(target)
            except ValueError:
                pass  # source without absolute seek semantics: best effort
        try:
            blk = np.asarray(ps.source.next_block(P), dtype=np.float32)
        except sources_lib.SourceExhausted:
            del pool[sid]
            record = dataclasses.replace(
                ps.record, reason="exhausted", tick=self._n_ticks
            )
            self._finished[sid] = record
            self._n_evicted += 1
            if self.on_evict is not None:
                self.on_evict(sid, record)
            return None
        except Exception as e:  # noqa: BLE001 — probe-side fault isolation
            self._n_degraded_ticks += 1
            self._last_fault[sid] = f"{type(e).__name__}: {e}"
            return None
        if hasattr(ps.source, "pop_retries"):
            self._n_source_retries += int(ps.source.pop_retries())
        if blk.shape != (self.bank.easi.n_features, P):
            self._n_degraded_ticks += 1
            self._last_fault[sid] = f"probe block shape {blk.shape}"
            return None
        return blk

    def _probe_sequential(self, due: List[Hashable]) -> None:
        """The PR-4 probe engine: one jitted virtual-conv dispatch per parked
        session (``DriftPolicy(probe_batch=0)``) — the differential-test
        oracle of ``_probe_batched``."""
        dpol = self.drift_policy
        for sid in due:
            ps = self._parked[sid]
            blk = self._pull_probe_block(sid, ps)
            if blk is None:
                continue
            x = self._virtual_conv(ps.record.state, jnp.asarray(blk.T))
            self._n_probes += 1
            self._n_probe_launches += 1
            if ps.monitor.update(x, dpol):
                self._readmit(sid, ps)

    def _probe_batched(self, due: List[Hashable]) -> None:
        """The batched probe engine: assemble the due batch (one pulled block
        per parked source), stack the frozen ``(B, Ĥ, step)`` states of each
        ``probe_batch``-wide chunk into a transient probe bank, and compute
        the whole chunk's virtual conv statistics with ONE no-commit launch.
        Ragged chunks are padded to the bank's power-of-two width and masked
        inactive, so at most log2(probe_batch) distinct programs ever
        compile.  Frozen states are immutable while a session stays parked,
        so each chunk's stacked probe-bank state is CACHED (keyed by the
        sessions' park stamps) — a steady parked population pays the
        Python-side stacking once, not every probe tick.  Monitor updates /
        re-admissions are applied in park order, so both engines reach the
        same decisions and end state (the differential property tests pin
        this); the one observable ordering difference is that exhaustion
        evictions surface during the up-front pull phase here, where the
        sequential loop interleaves them per session."""
        dpol = self.drift_policy
        P = self.bank.opt.batch_size
        m = self.bank.easi.n_features
        # fused probe banks consume block-aligned X: staging at padded shape
        # hits pad_batch's zero-copy fast path inside the jitted probe (the
        # same trick the serving tick's staging buffer plays)
        if self.bank.fused:
            lay = self.bank.layout
            P_stage, m_stage = lay.P_pad, lay.m_pad
        else:
            P_stage, m_stage = P, m
        pulled: List[Tuple[Hashable, ParkedSession, np.ndarray]] = []
        for sid in due:
            ps = self._parked[sid]
            blk = self._pull_probe_block(sid, ps)
            if blk is not None:
                pulled.append((sid, ps, blk))
        stacks: Dict[Tuple, BankState] = {}  # chunks live this tick only
        for lo in range(0, len(pulled), dpol.probe_batch):
            chunk = pulled[lo : lo + dpol.probe_batch]
            width = self._probe_width(len(chunk))
            bank, probe_fn = self._probe_bank(width)
            for _, ps, _ in chunk:
                if ps.park_seq < 0:  # white-box/legacy parks: stamp lazily
                    ps.park_seq = self._park_seq
                    self._park_seq += 1
            stamp = tuple(ps.park_seq for _, ps, _ in chunk)
            state = self._probe_stacks.get(stamp)
            if state is None:
                # pad ragged chunks by repeating the last frozen state
                # (masked out below — any well-formed state works; repeating
                # avoids manufacturing degenerate all-zero operands)
                states = [ps.record.state for _, ps, _ in chunk]
                states += [states[-1]] * (width - len(chunk))
                state = SeparatorBank.stack_states(states)
                if bank.fused:
                    state = bank.pad_state(state)
            stacks[stamp] = state
            X = np.zeros((width, P_stage, m_stage), dtype=np.float32)
            for j, (_, _, blk) in enumerate(chunk):
                X[j, :P, :m] = blk.T
            active = np.zeros((width,), dtype=np.int32)
            active[: len(chunk)] = 1
            conv, _health, _mom = probe_fn(
                state, jnp.asarray(X), jnp.asarray(active)
            )
            conv = np.asarray(conv)
            self._n_probes += len(chunk)
            self._n_probe_launches += 1
            for j, (sid, ps, _) in enumerate(chunk):
                if ps.monitor.update(float(conv[j]), dpol):
                    self._readmit(sid, ps)
        self._probe_stacks = stacks  # drop stacks of reshuffled/gone chunks

    @staticmethod
    def _probe_width(k: int) -> int:
        """Probe-bank width for a chunk of ``k`` sessions: the next power of
        two — ragged due batches retrace at most log2(probe_batch) widths."""
        w = 1
        while w < k:
            w *= 2
        return w

    def _probe_bank(self, width: int) -> Tuple[SeparatorBank, Any]:
        """The (cached) transient probe bank of ``width`` slots: same step
        geometry AND memory-system knobs as the serving bank (fused / pallas
        / block_p / dtype_policy / prefetch) with the bank's base
        hyperparameters — exactly what ``_virtual_conv`` models per session —
        and its jitted no-commit probe step.  ``autotune=False``: the probe
        width is a transient pow-2, not a shape anyone tuned for, so the
        serving bank's resolved geometry is pinned rather than re-looked-up."""
        got = self._probe_banks.get(width)
        if got is None:
            bank = SeparatorBank(
                self.bank.easi,
                self.bank.opt,
                n_streams=width,
                algorithm="smbgd_batched",
                use_pallas=self.bank.use_pallas,
                fused=self.bank.fused,
                block_p=(
                    self.bank.layout.block_p
                    if self.bank.fused
                    else self.bank.block_p
                ),
                dtype_policy=self.bank.dtype_policy,
                prefetch=bool(self.bank.prefetch),
                moments=bool(self.bank.moments),
                autotune=False,
            )
            got = (bank, bank.make_probe())
            self._probe_banks[width] = got
        return got

    def _readmit(self, session_id: Hashable, ps: ParkedSession) -> None:
        """PARKED → ACTIVE on watchdog fire: back through the scheduler's
        admission gate, warm-started from the frozen separator.  The
        re-admission only proceeds when it can ACTIVATE immediately (a free
        slot, or a preemptable hot session); if it would merely queue —
        backpressure, tenant quota — the session stays parked and the next
        probe retries.  A queued re-admission would hold its warm-start
        state as an un-snapshotable pending array; parked-until-activatable
        keeps checkpoints exact."""
        del self._parked[session_id]
        try:
            slot = self.admit(
                session_id,
                source=ps.source,
                state=ps.record.state,
                tenant=ps.meta.tenant,
                priority=ps.meta.priority,
                deadline=ps.meta.deadline,
            )
        except RuntimeError:  # bank AND queue full: stay parked, retry later
            self._parked[session_id] = ps
            return
        if slot is None:  # would queue (gated/contended): back out, stay parked
            self.evict(session_id)  # dequeues; detaches the source/warm bindings
            self._parked[session_id] = ps
            return
        self._record_drift(
            DriftEvent(
                session_id=session_id,
                tick=self._n_ticks,
                stat=ps.monitor.stat,
                action="readmit",
                slot=slot,
            )
        )

    # -- elastic capacity --------------------------------------------------
    @staticmethod
    def _step_key(bank: SeparatorBank) -> Tuple:
        """Jitted-step cache key: a resize back to a previously served
        (width, geometry) reuses its compiled program instead of retracing."""
        return (bank.n_streams, bank.block_p, bank.block_s, bank.prefetch)

    def _get_step(self, bank: SeparatorBank):
        got = self._step_cache.get(self._step_key(bank))
        if got is None:
            got = bank.make_step(with_hyperparams=self._hp_step)
            self._step_cache[self._step_key(bank)] = got
        return got

    def prewarm(self, widths) -> None:
        """Compile (and jit-cache) the serving step at each width in
        ``widths`` ahead of time, so the first tick after a resize pays no
        compile.  The warm-up CALLS each jitted step on blank operands with
        the serving tick's exact dtypes (f32 X, bool active mask, the bank's
        base hyperparameter rows when the μ machinery is armed) — lowering
        alone would not populate the jit cache.  It also exercises the
        slot-write and resize paths at every width (and ``resize_state``
        across each consecutive pair of widths, both directions): those are
        eager jnp ops whose first execution at a new shape pays a one-off
        XLA compile that would otherwise land on the serving tick that
        resizes.  Throwaway states only: the serving state, RNG key and free
        list are untouched."""
        widths = sorted(set(widths))
        banks, states = {}, {}
        for w in widths:
            bank = (
                self.bank if w == self.bank.n_streams
                else self.bank.with_streams(w)
            )
            fn = self._get_step(bank)
            state = bank.init(jax.random.PRNGKey(0))
            if bank.fused:
                lay = bank.layout
                X = np.zeros((w, lay.P_pad, lay.m_pad), dtype=np.float32)
            else:
                X = np.zeros(
                    (w, bank.opt.batch_size, bank.easi.n_features),
                    dtype=np.float32,
                )
            active = np.zeros((w,), dtype=bool)
            args = (state, jnp.asarray(X), jnp.asarray(active))
            if self._hp_step:
                args = args + (bank._bank_hyperparams(),)
            out_state, _Y = fn(*args)
            jax.block_until_ready(out_state.conv)
            # per-session output slice of the serving step (dynamic slot
            # index — one gather program covers every slot at this width)
            jax.block_until_ready(
                _Y[
                    bank._dyn(0),
                    : bank.opt.batch_size,
                    : bank.easi.n_components,
                ]
            )
            banks[w], states[w] = bank, out_state
        for w in widths:
            bank, state = banks[w], states[w]
            # activation (set_slot), fresh-init (init_slot) and compaction
            # (move_slot) writes at this width
            sub = bank.slot_state(state, 0)
            jax.block_until_ready(bank.set_slot(state, 0, sub).B)
            jax.block_until_ready(
                bank.init_slot(state, 0, jax.random.PRNGKey(0)).B
            )
            if w > 1:
                jax.block_until_ready(bank.move_slot(state, 0, w - 1).B)
        # all ordered width pairs: the autoscaler's shrink can skip ladder
        # rungs (8 -> 2 straight), and each (from, to) pair has its own
        # concat/slice shapes
        for src in widths:
            for dst in widths:
                if src != dst:
                    jax.block_until_ready(
                        banks[dst].resize_state(states[src]).B
                    )

    def compact(self) -> int:
        """Migrate every live slot to the low end of the bank (preserving
        slot order) so the high end is contiguously free — what lets a
        half-empty wide bank actually release width.  Each move carries the
        slot's FULL row (``SeparatorBank.move_slot``: B, Ĥ, step, conv,
        health, moments — plus the shadow snapshot and the per-slot μ
        multipliers), so a compacted session's trajectory is bit-identical
        to never having moved; sid-keyed bookkeeping (monitors, stats,
        deadline windows, kurtosis EMAs) never even notices.  Returns the
        number of sessions moved (0 = already compact, not counted as a
        compaction)."""
        order = sorted(self._slot_of.items(), key=lambda kv: kv[1])
        moved = 0
        for target, (sid, slot) in enumerate(order):
            if slot == target:
                continue
            # slots ascend and each target < its source, so no move ever
            # reads a row an earlier move already overwrote
            self.state = self.bank.move_slot(self.state, target, slot)
            if self._shadow is not None:
                self._shadow = self.bank.move_slot(self._shadow, target, slot)
            for arr in (
                self._boost_scale,
                self._cut_scale,
                self._ctrl_scale,
                self._cut_on,
            ):
                arr[target] = arr[slot]
            self._reset_mu(slot)
            self._slot_of[sid] = target
            moved += 1
        if moved:
            taken = set(self._slot_of.values())
            self._free = [
                s
                for s in range(self.bank.n_streams - 1, -1, -1)
                if s not in taken
            ]
            self._n_compactions += 1
            self._resize_history.append(
                {
                    "tick": self._n_ticks,
                    "action": "compact",
                    "from": self.bank.n_streams,
                    "to": self.bank.n_streams,
                    "reason": f"moved={moved}",
                }
            )
        return moved

    def grow(self, new_S: int, reason: str = "manual") -> None:
        """Widen the bank to ``new_S`` slots in place: surviving sessions
        keep their slots (state grows by leaf-wise prefix copy; no RNG is
        consumed for the blank slots), the free list gains the new high
        slots, and the waiting room backfills into them immediately."""
        if new_S < self.bank.n_streams:
            raise ValueError(
                f"grow target {new_S} < current width "
                f"{self.bank.n_streams}; use shrink"
            )
        self._resize(new_S, "grow", reason)

    def shrink(self, new_S: int, reason: str = "manual") -> None:
        """Narrow the bank to ``new_S`` slots, compacting live sessions to
        the low end first when any of them occupies a slot the truncation
        would drop.  Raises an actionable ``ValueError`` (naming the live
        sids and both widths) when the live sessions simply do not fit."""
        if new_S > self.bank.n_streams:
            raise ValueError(
                f"shrink target {new_S} > current width "
                f"{self.bank.n_streams}; use grow"
            )
        self._resize(new_S, "shrink", reason)

    def _resize(self, new_S: int, action: str, reason: str) -> None:
        """The shared grow/shrink edge: swap in ``bank.with_streams(new_S)``
        (autotune geometry re-resolves at the new width key; explicit knobs
        win — see ``SeparatorBank.with_streams``), prefix-copy every
        width-dependent array (state, shadow, μ ladders, staging buffer),
        rebuild the free list around the surviving slot map, and re-point
        the jitted step at the cached program for the new geometry."""
        old_S = self.bank.n_streams
        if new_S == old_S:
            return
        if new_S < 1:
            raise ValueError("bank width must be >= 1")
        if new_S < old_S:
            if self.n_active > new_S:
                raise ValueError(
                    f"cannot shrink bank {old_S} -> {new_S}: "
                    f"{self.n_active} live sessions exceed the new capacity "
                    f"({sorted(map(str, self._slot_of))})"
                )
            if any(slot >= new_S for slot in self._slot_of.values()):
                self.compact()
        new_bank = self.bank.with_streams(new_S)
        self.state = new_bank.resize_state(self.state)
        if self._shadow is not None:
            self._shadow = new_bank.resize_state(self._shadow)
        if new_S > old_S:
            pad = new_S - old_S
            self._boost_scale = np.concatenate(
                [self._boost_scale, np.ones((pad,), np.float32)]
            )
            self._cut_scale = np.concatenate(
                [self._cut_scale, np.ones((pad,), np.float32)]
            )
            self._ctrl_scale = np.concatenate(
                [self._ctrl_scale, np.ones((pad,), np.float32)]
            )
            self._cut_on = np.concatenate(
                [self._cut_on, np.zeros((pad,), bool)]
            )
        else:
            self._boost_scale = self._boost_scale[:new_S].copy()
            self._cut_scale = self._cut_scale[:new_S].copy()
            self._ctrl_scale = self._ctrl_scale[:new_S].copy()
            self._cut_on = self._cut_on[:new_S].copy()
        if new_bank.fused:
            lay = new_bank.layout
            stage_shape = (new_S, lay.P_pad, lay.m_pad)
        else:
            stage_shape = (
                new_S, new_bank.opt.batch_size, new_bank.easi.n_features
            )
        self._stage = np.zeros(stage_shape, dtype=np.float32)
        self._base_hp = (
            new_bank._bank_hyperparams() if self._hp_step else None
        )
        self._step = self._get_step(new_bank)
        # probe banks pin the SERVING bank's resolved geometry — drop them
        # only when the re-resolution actually changed it (stacked-state
        # caches key on park stamps, not geometry, but a probe bank rebuild
        # would re-pad them, so they go together)
        old_geom = (
            self.bank.layout.block_p if self.bank.fused else self.bank.block_p,
            bool(self.bank.prefetch),
        )
        new_geom = (
            new_bank.layout.block_p if new_bank.fused else new_bank.block_p,
            bool(new_bank.prefetch),
        )
        if old_geom != new_geom:
            self._probe_banks = {}
            self._probe_stacks = {}
        self.bank = new_bank
        taken = set(self._slot_of.values())
        self._free = [
            s for s in range(new_S - 1, -1, -1) if s not in taken
        ]
        if action == "grow":
            self._n_grows += 1
        else:
            self._n_shrinks += 1
        self._resize_history.append(
            {
                "tick": self._n_ticks,
                "action": action,
                "from": old_S,
                "to": new_S,
                "reason": reason,
            }
        )
        self._last_resize_tick = self._elastic_ticks
        if action == "grow":
            # new slots serve waiting work the same tick they appear
            self._backfill()

    def _autoscale_tick(self) -> None:
        """One autoscaler evaluation per ``run_tick`` (after the probe
        phase, before the tick's latency record closes — resize cost is
        billed to the tick that resized)."""
        pol = self.autoscale
        if pol is None:
            return
        self._elastic_ticks += 1
        since = (
            None
            if self._last_resize_tick is None
            else self._elastic_ticks - self._last_resize_tick
        )
        decision: Optional[ResizeDecision] = pol.decide(
            self.bank.n_streams,
            self.n_active,
            self.n_queued,
            self.deadline_miss_rate,
            since,
        )
        if decision is None:
            return
        if decision.action == "grow":
            self.grow(decision.target, reason=decision.reason)
        else:
            if pol.compact_before_shrink:
                self.compact()
            self.shrink(decision.target, reason=decision.reason)

    # -- scheduler-driven ingestion ---------------------------------------
    def run_tick(self) -> Dict[Hashable, jnp.ndarray]:
        """One pull tick: backfill free slots from the scheduler, pull a
        channel-major ``(m, P)`` block from every active session's bound
        ``SignalSource``, advance them all with ONE fused bank step, evict
        sessions whose source drained (reason ``"exhausted"``), and probe
        parked and quarantined sessions out of band.  Returns session_id →
        separated ``(P, n)`` outputs (sessions without a source are skipped —
        push their batches through ``step`` instead; both modes mix freely).

        Per-session fault isolation: a source raising anything other than
        ``SourceExhausted`` (transient I/O error, stall past a
        ``ResilientSource`` timeout, short read) degrades THAT session's tick
        — it is simply left out of the batch, so the bank's active mask
        freezes its slot — and never fails the launch for everyone else.
        Degraded session-ticks count in ``metrics['n_degraded_ticks']``; the
        last per-session failure string is kept in ``last_faults``.

        Latency accounting (PR-8): the tick's recorded latency is the FULL
        ``run_tick`` duration — pull + bank step (time-to-ready) + drain
        evictions + out-of-band probes — so probe work is billed to the tick
        that ran it and a ``deadline_budget_s`` judges what a real-time
        caller actually waited.  A run_tick whose batches all degraded or
        drained (or that only probed) no longer vanishes from telemetry: it
        counts in ``metrics['n_empty_ticks']`` and its duration still lands
        in the latency sketch and the deadline check (``n_ticks`` remains
        data ticks only — lifecycle stamps keep their meaning)."""
        t0 = time.perf_counter()
        self._backfill()  # deadline/quota gates may have reopened
        P = self.bank.opt.batch_size
        m = self.bank.easi.n_features
        batches: Dict[Hashable, np.ndarray] = {}
        drained: List[Hashable] = []
        for sid in list(self._slot_of):
            src = self._sources.get(sid)
            if src is None:
                continue
            try:
                blk = np.asarray(src.next_block(P), dtype=np.float32)
                if blk.shape != (m, P):
                    raise ValueError(
                        f"block shape {blk.shape} != (m={m}, n_samples={P})"
                    )
            except sources_lib.SourceExhausted:
                drained.append(sid)
                continue
            except Exception as e:  # noqa: BLE001 — per-session isolation
                self._n_degraded_ticks += 1
                self._last_fault[sid] = f"{type(e).__name__}: {e}"
                continue
            if hasattr(src, "pop_retries"):
                self._n_source_retries += int(src.pop_retries())
            batches[sid] = blk.T
        if batches:
            self._defer_slo = True
            try:
                out = self.step(batches)
            finally:
                self._defer_slo = False
        else:
            out = {}
        for sid in drained:
            if sid in self._slot_of:
                self._release(sid, reason="exhausted")
        had_oob = bool(self._parked or self._quarantined)
        pt0 = time.perf_counter()
        self._probe_parked()
        self._probe_quarantined()
        pt1 = time.perf_counter()
        if had_oob:
            self._last_probe_s = pt1 - pt0  # out-of-band probe phase, timed
        # autoscale AFTER serve+probe (decisions see this tick's telemetry)
        # and BEFORE the latency record closes: resize cost is billed to the
        # tick that resized, so the SLO sketch and the bench's resize-tick
        # overhead metric both see it
        self._autoscale_tick()
        dt = time.perf_counter() - t0
        if self._pending_tick is not None:
            served, timed, samples = self._pending_tick
            self._pending_tick = None
            self._finish_tick(dt, served, timed, samples)
        else:
            # empty tick: every source degraded/drained, or probe-only work —
            # distinctly counted, and its wall-clock still faces the budget
            # (probes end host-synced, so dt is honest without a sync leaf)
            self._n_empty_ticks += 1
            self._record_latency(dt, [])
        return out

    @property
    def last_faults(self) -> Dict[Hashable, str]:
        """Most recent per-session source-failure strings (degraded ticks —
        the observability twin of ``metrics['n_degraded_ticks']``)."""
        return dict(self._last_fault)

    # -- persistence -------------------------------------------------------
    # The bank state is a plain pytree, so the array side round-trips through
    # any Checkpointer.  The session→slot map, admission queue and monitor
    # counters are host bookkeeping (arbitrary hashable ids — not arrays):
    # callers persist them via ``sessions``/``lifecycle`` and hand them back
    # to ``restore`` to resume live sessions and queued admissions.

    @property
    def sessions(self) -> Dict[Hashable, int]:
        """Snapshot of the live session→slot map (save alongside the arrays)."""
        return dict(self._slot_of)

    @property
    def lifecycle(self) -> Dict[str, Any]:
        """JSON-friendly snapshot of the full host-side lifecycle state:
        session→slot map, the scheduler's waiting room (ids + scheduling
        metadata), per-session convergence monitors, active-session metadata,
        the drift watchdog (hot-session monitors, remaining boost ticks,
        per-slot μ multipliers, bound-source cursor positions), and the
        parked population under out-of-band probe — each parked session's
        drift-monitor EMA, scheduling metadata, eviction provenance and
        source cursor, in park order, plus the probe cadence counter
        (``probe_ticks``), so a restored watchdog resumes mid-cycle with the
        exact due-batch membership and phase it left off at.  Save alongside
        the arrays; hand back to ``restore`` to resume sessions, queue,
        convergence progress AND drift watch in place.

        Deliberately excluded (arrays / live objects, not JSON): mixing
        matrices registered via ``set_mixing`` (re-register after restore),
        the ``SignalSource`` objects themselves (re-attach via
        ``bind_source``, which seeks them to the recorded positions — parked
        sessions included; an unbound parked session stays parked and simply
        skips probes), the parked sessions' frozen separator arrays (those
        ride ``save``/``restore`` as stacked ``parked_*`` checkpoint leaves),
        and pending warm-start states of QUEUED sessions (a caller's
        ``admit(state=...)`` under backpressure activates FRESH after a
        restore; the watchdog itself never queues a warm re-admission —
        see ``_readmit``)."""
        return {
            "sessions": dict(self._slot_of),
            "queue": self.scheduler.snapshot(),
            "monitors": {
                sid: dataclasses.asdict(mon)
                for sid, mon in self._monitors.items()
            },
            "meta": {sid: meta.asdict() for sid, meta in self._meta.items()},
            "hot": {
                sid: dataclasses.asdict(mon) for sid, mon in self._hot.items()
            },
            "boost": dict(self._boost_left),
            # legacy composite (pre-split readers) + the per-ladder arrays
            "mu_scale": [float(v) for v in self._effective_mu_scale()],
            "mu_boost_scale": [float(v) for v in self._boost_scale],
            "mu_cut_scale": [float(v) for v in self._cut_scale],
            "mu_ctrl_scale": [float(v) for v in self._ctrl_scale],
            "mu_cut_on": [bool(v) for v in self._cut_on],
            "moments": (
                self._moments.state_dict() if self._moments is not None else {}
            ),
            "sources": {
                sid: int(src.position)
                for sid, src in self._sources.items()
                if hasattr(src, "position")
            },
            "probe_ticks": self._probe_ticks,
            "health": {
                sid: dataclasses.asdict(mon)
                for sid, mon in self._health_mon.items()
            },
            "cut": dict(self._cut_left),
            "quarantine_ticks": self._quar_ticks,
            "resize_history": [dict(e) for e in self._resize_history],
            "shadow": self._shadow is not None,
            "quarantined": [
                [
                    sid,
                    {
                        "monitor": dataclasses.asdict(qs.monitor),
                        "meta": qs.meta.asdict(),
                        "reason": qs.record.reason,
                        "tick": qs.record.tick,
                        "position": (
                            int(qs.source.position)
                            if qs.source is not None
                            and hasattr(qs.source, "position")
                            else None
                        ),
                    },
                ]
                for sid, qs in self._quarantined.items()
            ],
            "parked": [
                [
                    sid,
                    {
                        "monitor": dataclasses.asdict(ps.monitor),
                        "meta": ps.meta.asdict(),
                        "reason": ps.record.reason,
                        "tick": ps.record.tick,
                        "position": (
                            int(ps.source.position)
                            if ps.source is not None
                            and hasattr(ps.source, "position")
                            else None
                        ),
                    },
                ]
                for sid, ps in self._parked.items()
            ],
        }

    @staticmethod
    def _parked_fingerprint(sids) -> jnp.ndarray:
        """Order-sensitive (K,) uint32 fingerprint of parked session ids.

        Saved alongside the stacked ``parked_*`` leaves and recomputed from
        the ``lifecycle`` snapshot at restore: the arrays and the snapshot
        are separate artifacts zipped back together BY INDEX, so a snapshot
        captured at a different moment than ``save`` (same parked count,
        different membership/order) must fail loudly instead of silently
        attaching frozen separators to the wrong sessions."""
        import zlib

        return jnp.asarray(
            [
                zlib.crc32(json.dumps(sid, default=str).encode())
                for sid in sids
            ],
            dtype=jnp.uint32,
        )

    def save(self, checkpointer, step: int) -> None:
        # rng_key rides along so post-restore admissions continue the key
        # sequence instead of replaying pre-save inits; parked sessions'
        # frozen separators ride as stacked leaves (in the ``lifecycle``
        # snapshot's park order — restore zips the two back together, with
        # the sid fingerprint guarding the index pairing)
        tree = dict(self.state._asdict(), rng_key=self.key)
        if self._parked:
            frozen = [ps.record.state for ps in self._parked.values()]
            tree["parked_B"] = jnp.stack([jnp.asarray(s.B) for s in frozen])
            tree["parked_H_hat"] = jnp.stack(
                [jnp.asarray(s.H_hat) for s in frozen]
            )
            tree["parked_step"] = jnp.stack(
                [jnp.asarray(s.step) for s in frozen]
            )
            tree["parked_ids"] = self._parked_fingerprint(self._parked)
        # the last-known-good shadow rides as its own leaves: a restored
        # service must be able to roll back to the SAME snapshot the
        # checkpointed one would have, not to the post-restore state
        if self._shadow is not None:
            tree["shadow_B"] = self._shadow.B
            tree["shadow_H_hat"] = self._shadow.H_hat
            tree["shadow_step"] = self._shadow.step
            tree["shadow_conv"] = self._shadow.conv
        # quarantined sessions' last-known-good states ride like parked ones
        # (zipped back by index against lifecycle['quarantined'], fingerprint
        # guarded)
        if self._quarantined:
            lkg = [qs.record.state for qs in self._quarantined.values()]
            tree["quar_B"] = jnp.stack([jnp.asarray(s.B) for s in lkg])
            tree["quar_H_hat"] = jnp.stack([jnp.asarray(s.H_hat) for s in lkg])
            tree["quar_step"] = jnp.stack([jnp.asarray(s.step) for s in lkg])
            tree["quar_ids"] = self._parked_fingerprint(self._quarantined)
        checkpointer.save(step, tree)

    def restore(
        self,
        checkpointer,
        step: Optional[int] = None,
        sessions: Optional[Dict[Hashable, int]] = None,
        lifecycle: Optional[Dict[str, Any]] = None,
    ) -> int:
        """Restore bank arrays and (optionally) re-attach host lifecycle state.

        Without ``sessions``/``lifecycle`` every slot is considered free:
        restored separator matrices are still in the arrays but will be
        overwritten as slots are re-admitted.  Pass the ``sessions`` map (or
        the richer ``lifecycle`` snapshot, which also carries the admission
        queue, the per-session convergence monitors AND the parked probe
        population — frozen separators from the checkpoint's stacked
        ``parked_*`` leaves, drift-monitor EMAs, probe cadence and due-batch
        order from the snapshot) captured at save time to resume in place.
        Restored parked sessions hold no source until ``bind_source``
        re-attaches one (seeking it to the recorded cursor); until then they
        stay parked and skip probes.

        Ground-truth mixing matrices are NOT part of the snapshot (they are
        arrays, not host bookkeeping, and the snapshot stays JSON-able):
        callers using ``ConvergencePolicy.amari_threshold`` must re-register
        them via ``set_mixing`` after restore, or the Amari confirmation is
        skipped and the blind statistic decides alone.
        """
        lifecycle = lifecycle or {}
        if sessions is None:
            sessions = lifecycle.get("sessions") or {}
        queue_entries = list(lifecycle.get("queue") or [])
        # entries are [sid, meta] pairs (new) or plain sids (PR-3 snapshots)
        queue_ids = [
            e[0]
            if isinstance(e, (list, tuple)) and len(e) == 2 and isinstance(e[1], dict)
            else e
            for e in queue_entries
        ]
        monitors = lifecycle.get("monitors") or {}
        meta_snap = lifecycle.get("meta") or {}
        hot_snap = lifecycle.get("hot") or {}
        boost_snap = lifecycle.get("boost") or {}
        mu_scale = lifecycle.get("mu_scale")
        parked_snap = list(lifecycle.get("parked") or [])
        parked_ids = [sid for sid, _info in parked_snap]
        health_snap = lifecycle.get("health") or {}
        cut_snap = lifecycle.get("cut") or {}
        boost_scale_snap = lifecycle.get("mu_boost_scale")
        cut_scale_snap = lifecycle.get("mu_cut_scale")
        ctrl_scale_snap = lifecycle.get("mu_ctrl_scale")
        cut_on_snap = lifecycle.get("mu_cut_on")
        moments_snap = lifecycle.get("moments") or {}
        quar_snap = list(lifecycle.get("quarantined") or [])
        quar_ids = [sid for sid, _info in quar_snap]
        want_shadow = bool(lifecycle.get("shadow"))
        # elastic restore: the checkpoint's true width comes from the
        # manifest peek (no array data loaded) — a service resized since
        # save builds its restore target at the SAVED width and re-places
        # the sessions into the current free list afterwards, instead of
        # failing the Checkpointer's per-leaf shape check
        saved_S = self.bank.n_streams
        peek = getattr(checkpointer, "leaf_shapes", None)
        if peek is not None:
            shape = peek(step=step).get("B")
            if shape:
                saved_S = int(shape[0])
        if saved_S != self.bank.n_streams and len(sessions) > self.bank.n_streams:
            raise ValueError(
                f"cannot restore checkpoint of width {saved_S} into a bank "
                f"of width {self.bank.n_streams}: {len(sessions)} live "
                f"sessions exceed the new capacity "
                f"({sorted(map(str, sessions))}) — grow the bank or evict "
                f"before restoring"
            )
        bad = {
            s: slot
            for s, slot in sessions.items()
            if not 0 <= slot < saved_S
        }
        if bad:
            raise ValueError(f"session slots out of range: {bad}")
        if len(set(sessions.values())) != len(sessions):
            raise ValueError(f"duplicate slots in session map: {sessions}")
        overlap = set(queue_ids) & set(sessions)
        if overlap or len(set(queue_ids)) != len(queue_ids):
            raise ValueError(f"queue/session overlap or duplicates: {queue_ids}")
        parked_overlap = set(parked_ids) & (set(sessions) | set(queue_ids))
        if parked_overlap or len(set(parked_ids)) != len(parked_ids):
            raise ValueError(
                f"parked/session/queue overlap or duplicates: {parked_ids}"
            )
        if parked_snap and (
            self.drift_policy is None or self.drift_policy.mode != "readmit"
        ):
            raise ValueError(
                "lifecycle snapshot carries parked sessions but this service "
                "has no readmit-mode drift_policy to probe them"
            )
        quar_overlap = set(quar_ids) & (
            set(sessions) | set(queue_ids) | set(parked_ids)
        )
        if quar_overlap or len(set(quar_ids)) != len(quar_ids):
            raise ValueError(
                f"quarantined/session/queue/parked overlap or duplicates: "
                f"{quar_ids}"
            )
        if (quar_snap or health_snap or cut_snap) and self.health_policy is None:
            raise ValueError(
                "lifecycle snapshot carries health-containment state "
                "(quarantined/health/cut) but this service has no "
                "health_policy to run the escalation ladder"
            )
        for name, arr in (
            ("mu_scale", mu_scale),
            ("mu_boost_scale", boost_scale_snap),
            ("mu_cut_scale", cut_scale_snap),
            ("mu_ctrl_scale", ctrl_scale_snap),
            ("mu_cut_on", cut_on_snap),
        ):
            if arr is not None and len(arr) != saved_S:
                raise ValueError(
                    f"{name} length {len(arr)} != n_streams "
                    f"{saved_S}"
                )
        if moments_snap and self._moments is None:
            raise ValueError(
                "lifecycle snapshot carries moment-controller state but this "
                "service has no moment_policy to apply it"
            )
        # drift-watch state needs the drift machinery to run: re-arming hot
        # monitors without a policy would crash the next served tick, and μ
        # multipliers without the hyperparam step would be silently inert
        if (hot_snap or boost_snap) and self.drift_policy is None:
            raise ValueError(
                "lifecycle snapshot carries drift-watch state (hot/boost) "
                "but this service has no drift_policy"
            )
        if not self._hp_step and any(
            any(float(v) != 1.0 for v in arr)
            for arr in (mu_scale, boost_scale_snap, cut_scale_snap, ctrl_scale_snap)
            if arr is not None
        ):
            raise ValueError(
                "lifecycle snapshot carries μ multipliers but this service "
                "cannot apply them (no boost-mode drift_policy)"
            )
        # validate BEFORE mutating: a rejected map must leave the live
        # service untouched
        if saved_S == self.bank.n_streams:
            target = dict(self.state._asdict(), rng_key=self.key)
        else:
            # restore target at the checkpoint's width; the current state's
            # trailing dims are width-independent, so they size the leaves
            target = {
                name: (
                    None
                    if leaf is None
                    else jnp.zeros((saved_S,) + leaf.shape[1:], leaf.dtype)
                )
                for name, leaf in self.state._asdict().items()
            }
            target["rng_key"] = self.key
        if parked_snap:
            n = self.bank.easi.n_components
            m = self.bank.easi.n_features
            dt = self.bank.easi.dtype
            K = len(parked_snap)
            target["parked_B"] = jnp.zeros((K, n, m), dt)
            target["parked_H_hat"] = jnp.zeros((K, n, n), dt)
            target["parked_step"] = jnp.zeros((K,), jnp.int32)
            target["parked_ids"] = jnp.zeros((K,), jnp.uint32)
        if want_shadow:
            # shadow leaves are width-dependent too — sized off the (possibly
            # saved-width) state target so they match the checkpoint
            target["shadow_B"] = jnp.zeros_like(target["B"])
            target["shadow_H_hat"] = jnp.zeros_like(target["H_hat"])
            target["shadow_step"] = jnp.zeros_like(target["step"])
            target["shadow_conv"] = jnp.zeros_like(target["conv"])
        if quar_snap:
            n = self.bank.easi.n_components
            m = self.bank.easi.n_features
            dt = self.bank.easi.dtype
            K = len(quar_snap)
            target["quar_B"] = jnp.zeros((K, n, m), dt)
            target["quar_H_hat"] = jnp.zeros((K, n, n), dt)
            target["quar_step"] = jnp.zeros((K,), jnp.int32)
            target["quar_ids"] = jnp.zeros((K,), jnp.uint32)
        tree, got = checkpointer.restore(target, step=step)
        if quar_snap:
            want = np.asarray(self._parked_fingerprint(quar_ids))
            saved = np.asarray(tree.pop("quar_ids"))
            if not np.array_equal(saved, want):
                raise ValueError(
                    "lifecycle['quarantined'] does not match the checkpoint's "
                    "quar_* leaves (membership/order changed between save and "
                    "snapshot?) — last-known-good states would attach to the "
                    "wrong sessions"
                )
        if parked_snap:
            # the arrays and the snapshot are zipped by index: the saved sid
            # fingerprint must match the snapshot's park order exactly
            want = np.asarray(self._parked_fingerprint(parked_ids))
            saved = np.asarray(tree.pop("parked_ids"))
            if not np.array_equal(saved, want):
                raise ValueError(
                    "lifecycle['parked'] does not match the checkpoint's "
                    "parked_* leaves (membership/order changed between save "
                    "and snapshot?) — frozen separators would attach to the "
                    "wrong sessions"
                )
        self.key = tree.pop("rng_key")
        parked_B = tree.pop("parked_B", None)
        parked_H = tree.pop("parked_H_hat", None)
        parked_step = tree.pop("parked_step", None)
        shadow_B = tree.pop("shadow_B", None)
        shadow_H = tree.pop("shadow_H_hat", None)
        shadow_step = tree.pop("shadow_step", None)
        shadow_conv = tree.pop("shadow_conv", None)
        quar_B = tree.pop("quar_B", None)
        quar_H = tree.pop("quar_H_hat", None)
        quar_step = tree.pop("quar_step", None)
        self.state = BankState(**tree)
        if shadow_B is not None:
            self._shadow = BankState(
                B=shadow_B,
                H_hat=shadow_H,
                step=shadow_step,
                conv=shadow_conv,
                health=jnp.zeros_like(self.state.health),
                moments=jnp.zeros((shadow_B.shape[0], 2), jnp.float32),
            )
        elif self.health_policy is not None:
            # checkpoint predates the shadow (or was saved without one):
            # re-seed the last-known-good snapshot from the restored state —
            # a state that was committed and saved is by definition healthy
            self._shadow = self.state
        else:
            self._shadow = None
        if saved_S != self.bank.n_streams:
            # re-placement: gather the restored sessions' rows (in slot
            # order), re-place them contiguously from slot 0, and pad or
            # truncate to the CURRENT width — every surviving row is carried
            # verbatim, so the restored trajectories stay bit-identical
            order = sorted(sessions.items(), key=lambda kv: kv[1])
            idx = jnp.asarray(
                [slot for _sid, slot in order], dtype=jnp.int32
            )

            def _gather(st: BankState) -> BankState:
                return BankState(
                    B=st.B[idx],
                    H_hat=st.H_hat[idx],
                    step=st.step[idx],
                    conv=None if st.conv is None else st.conv[idx],
                    health=None if st.health is None else st.health[idx],
                    moments=(
                        None if st.moments is None else st.moments[idx]
                    ),
                )

            self.state = self.bank.resize_state(_gather(self.state))
            if self._shadow is not None:
                self._shadow = self.bank.resize_state(_gather(self._shadow))

            def _remap(arr, fill):
                if arr is None:
                    return None
                out = [fill] * self.bank.n_streams
                for new_slot, (_sid, old_slot) in enumerate(order):
                    out[new_slot] = arr[old_slot]
                return out

            mu_scale = _remap(mu_scale, 1.0)
            boost_scale_snap = _remap(boost_scale_snap, 1.0)
            cut_scale_snap = _remap(cut_scale_snap, 1.0)
            ctrl_scale_snap = _remap(ctrl_scale_snap, 1.0)
            cut_on_snap = _remap(cut_on_snap, False)
            sessions = {sid: i for i, (sid, _slot) in enumerate(order)}
        self._slot_of = dict(sessions)
        self.scheduler.load(queue_entries)
        # convergence progress resumes exactly; sessions without a saved
        # monitor restart their decision state (but not their separator)
        self._monitors = {
            sid: ConvergenceMonitor(**monitors[sid])
            if sid in monitors
            else ConvergenceMonitor()
            for sid in sessions
        }
        self._meta = {
            sid: SessionMeta(**meta_snap[sid])
            if sid in meta_snap
            else SessionMeta()
            for sid in sessions
        }
        # drift watch resumes exactly: hot monitors, boost countdowns, μ rows
        self._hot = {
            sid: DriftMonitor(**mon)
            for sid, mon in hot_snap.items()
            if sid in sessions
        }
        self._boost_left = {
            sid: int(v) for sid, v in boost_snap.items() if sid in sessions
        }
        S = self.bank.n_streams
        if (
            boost_scale_snap is not None
            or cut_scale_snap is not None
            or ctrl_scale_snap is not None
        ):
            # per-ladder snapshot (PR-9+): restore each writer's multiplier
            self._boost_scale = (
                np.asarray(boost_scale_snap, np.float32)
                if boost_scale_snap is not None
                else np.ones((S,), np.float32)
            )
            self._cut_scale = (
                np.asarray(cut_scale_snap, np.float32)
                if cut_scale_snap is not None
                else np.ones((S,), np.float32)
            )
            self._ctrl_scale = (
                np.asarray(ctrl_scale_snap, np.float32)
                if ctrl_scale_snap is not None
                else np.ones((S,), np.float32)
            )
            self._cut_on = (
                np.asarray(cut_on_snap, bool)
                if cut_on_snap is not None
                else np.zeros((S,), bool)
            )
        else:
            # legacy single-array snapshot: attribute each slot's composite
            # multiplier to the ladder that owns the session there (μ-cut
            # sessions are exactly the cut_left keys; everything else was a
            # boost — the controller never persisted pre-split)
            self._boost_scale = np.ones((S,), np.float32)
            self._cut_scale = np.ones((S,), np.float32)
            self._ctrl_scale = np.ones((S,), np.float32)
            self._cut_on = np.zeros((S,), bool)
            if mu_scale is not None:
                cut_slots = {
                    sessions[sid] for sid in cut_snap if sid in sessions
                }
                for slot, v in enumerate(mu_scale):
                    v = float(v)
                    if v == 1.0:
                        continue
                    if slot in cut_slots:
                        self._cut_scale[slot] = v
                        self._cut_on[slot] = True
                    else:
                        self._boost_scale[slot] = v
        if self._moments is not None:
            # stringified keys resolve against the restored roster (active
            # sessions only — parked/quarantined re-seed at re-admission)
            self._moments.load_state_dict(
                moments_snap, key_map={str(sid): sid for sid in sessions}
            )
        self._sources = {}
        self._warm = {}
        self._drift_events = []
        self._n_drift_events = 0
        self._n_probes = 0
        self._n_probe_launches = 0
        self._probe_stacks = {}
        # the probe cadence resumes mid-cycle: a restored watchdog fires its
        # next probe exactly when the checkpointed one would have
        self._probe_ticks = int(lifecycle.get("probe_ticks") or 0)
        # bind_source(seek=True) replays these cursors into re-bound sources
        self._restored_positions = dict(lifecycle.get("sources") or {})
        # parked sessions resume in park order (= due-batch order): frozen
        # separators from the stacked checkpoint leaves, monitors/meta from
        # the snapshot, sources re-bound (and re-sought) via bind_source
        now = time.perf_counter()
        self._parked = {}
        for i, (sid, info) in enumerate(parked_snap):
            frozen = SMBGDState(
                B=parked_B[i], H_hat=parked_H[i], step=parked_step[i]
            )
            self._parked[sid] = ParkedSession(
                record=EvictionRecord(
                    state=frozen,
                    stats=SessionStats(admitted_at=now),
                    monitor=None,
                    reason=info.get("reason", "converged"),
                    tick=int(info.get("tick", 0)),
                ),
                source=None,
                monitor=DriftMonitor(**(info.get("monitor") or {})),
                meta=SessionMeta(**(info.get("meta") or {})),
            )
            pos = info.get("position")
            if pos is not None:
                self._restored_positions[sid] = int(pos)
        # quarantined sessions resume with their escalation memory intact:
        # last-known-good states from the stacked leaves, monitors/meta from
        # the snapshot, sources re-bound via bind_source (unbound quarantined
        # sessions skip probes, exactly like unbound parked ones)
        self._quarantined = {}
        for i, (sid, info) in enumerate(quar_snap):
            lkg = SMBGDState(B=quar_B[i], H_hat=quar_H[i], step=quar_step[i])
            self._quarantined[sid] = QuarantinedSession(
                record=EvictionRecord(
                    state=lkg,
                    stats=SessionStats(admitted_at=now),
                    monitor=None,
                    reason=info.get("reason", "quarantined"),
                    tick=int(info.get("tick", 0)),
                ),
                source=None,
                monitor=HealthMonitor(**(info.get("monitor") or {})),
                meta=SessionMeta(**(info.get("meta") or {})),
            )
            pos = info.get("position")
            if pos is not None:
                self._restored_positions[sid] = int(pos)
        # active sessions' ladder memory + μ-cut countdowns resume exactly
        self._health_mon = {
            sid: HealthMonitor(**health_snap[sid])
            for sid in sessions
            if sid in health_snap
        }
        self._cut_left = {
            sid: int(v) for sid, v in cut_snap.items() if sid in sessions
        }
        self._quar_ticks = int(lifecycle.get("quarantine_ticks") or 0)
        self._health_events = []
        self._n_health_events = 0
        self._n_rollbacks = 0
        self._n_diverged = 0
        self._n_degraded_ticks = 0
        self._n_source_retries = 0
        self._last_fault = {}
        queue_meta_orders = [
            e[1].get("order", 0)
            for e in queue_entries
            if isinstance(e, (list, tuple)) and len(e) == 2 and isinstance(e[1], dict)
        ]
        self._seq = 1 + max(
            [m.order for m in self._meta.values()]
            + [ps.meta.order for ps in self._parked.values()]
            + [qs.meta.order for qs in self._quarantined.values()]
            + queue_meta_orders,
            default=-1,
        )
        self._mixing = {}
        self._finished = {}
        # serving counters restart at restore time — per-session AND aggregate
        # (metrics must describe the restored epoch, not blend the old run)
        now = time.perf_counter()
        self._stats = {
            sid: SessionStats(admitted_at=now, activated_at=now)
            for sid in sessions
        }
        self._admit_time = {}
        self._n_ticks = 0
        self._total_samples = 0
        self._total_tick_s = 0.0
        self._last_tick_s = float("nan")
        self._n_evicted = 0
        self._n_auto_evicted = 0
        # SLO telemetry restarts with the epoch (sketch, deadline monitors,
        # miss window, empty-tick counters — same rule as the counters above)
        self._reset_slo()
        # resize provenance rides the lifecycle snapshot; the elastic
        # counters restart with the epoch like every other serving counter
        self._resize_history = [
            dict(e) for e in (lifecycle.get("resize_history") or [])
        ]
        self._n_grows = 0
        self._n_shrinks = 0
        self._n_compactions = 0
        self._elastic_ticks = 0
        self._last_resize_tick = None
        taken = set(sessions.values())
        self._free = [s for s in range(self.bank.n_streams - 1, -1, -1) if s not in taken]
        return got
