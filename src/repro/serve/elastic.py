"""Telemetry-driven autoscaling for elastic separator banks.

The bank's width S is capacity: slots cost persistent HBM
(``bank.layout.persistent_bytes_per_session``) whether occupied or not, and
a full bank turns every admission into queue wait.  PR 10 makes the serving
bank elastic (``SeparationService.grow`` / ``shrink`` / ``compact`` over
``SeparatorBank.with_streams`` / ``resize_state`` / ``move_slot``); this
module supplies the CONTROLLER — a pure, stateless policy that turns the
service's live telemetry into resize decisions the ``run_tick`` loop applies:

  * GROW when demand is visible: sessions waiting in the admission queue
    (``grow_queue_depth``) or the PR-8 windowed deadline-miss rate over
    ``grow_miss_rate`` — both mean the current width is costing latency.
    Targets double (``factor``) up to ``max_streams``, so bursts are served
    in O(log burst) resizes and widths stay on one ladder (min·factorᵏ) the
    service can pre-compile step functions for.
  * SHRINK when the bank is provably idle: the queue is EMPTY, miss pressure
    is off, and utilization (active/width) sits under ``shrink_utilization``.
    The target is the smallest ladder width whose post-shrink utilization is
    at most ``hold_utilization`` — sized with headroom, not packed tight.
  * NEVER FLAP: the two bands are separated by construction (validated:
    ``shrink_utilization ≤ hold_utilization / factor``, so a just-shrunk bank
    sits strictly ABOVE the shrink band), growth triggers only on
    queue/deadline pressure (which a grow immediately relieves — low
    post-grow utilization alone never triggers a shrink while the queue
    refills), and ``cooldown_ticks`` of ``run_tick`` quiet time must pass
    after any resize before the next decision.

The policy is deliberately memoryless — everything it needs (width, active
count, queue depth, miss rate, ticks since the last resize) is passed in per
decision, so it snapshots trivially and a restored service resumes identical
behavior.  Shrinks compact first (``SeparationService.shrink``): live slots
migrate to the low end via ``SeparatorBank.move_slot``, which carries every
state leaf verbatim — a resized co-tenant's trajectory stays bit-identical
to a fixed-width run on both the vmap and megakernel paths (pinned by
tests/test_elastic.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ResizeDecision:
    """One autoscaler verdict: ``action`` ("grow"/"shrink"), the ``target``
    width, and a human-readable ``reason`` (lands in the service's resize
    history for observability)."""

    action: str
    target: int
    reason: str


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Hysteresis-banded resize controller (see module docstring).

    ``max_streams`` caps growth (the provisioned ceiling); ``min_streams``
    floors shrink (never below — and implicitly never below the live session
    count).  ``grow_queue_depth`` sessions waiting, or a windowed deadline-
    miss rate above ``grow_miss_rate`` (``None`` disables the latency
    trigger), grows by ``factor``; a queue-empty, pressure-free bank whose
    utilization drops under ``shrink_utilization`` shrinks to the smallest
    ladder width holding utilization at or under ``hold_utilization``.
    ``cooldown_ticks`` run_tick calls must pass after any resize before the
    next decision fires."""

    max_streams: int
    min_streams: int = 1
    grow_queue_depth: int = 1
    grow_miss_rate: Optional[float] = None
    shrink_utilization: float = 0.25
    hold_utilization: float = 0.5
    cooldown_ticks: int = 8
    factor: int = 2
    compact_before_shrink: bool = True

    def __post_init__(self) -> None:
        if self.min_streams < 1:
            raise ValueError("min_streams must be >= 1")
        if self.max_streams < self.min_streams:
            raise ValueError(
                f"max_streams ({self.max_streams}) must be >= "
                f"min_streams ({self.min_streams})"
            )
        if self.factor < 2:
            raise ValueError("factor must be >= 2")
        if self.grow_queue_depth < 1:
            raise ValueError("grow_queue_depth must be >= 1")
        if self.grow_miss_rate is not None and not (
            0.0 < self.grow_miss_rate <= 1.0
        ):
            raise ValueError("grow_miss_rate must be in (0, 1]")
        if not (0.0 < self.hold_utilization <= 1.0):
            raise ValueError("hold_utilization must be in (0, 1]")
        if not (0.0 <= self.shrink_utilization < 1.0):
            raise ValueError("shrink_utilization must be in [0, 1)")
        # the anti-flap band: the smallest holding width leaves utilization
        # strictly above hold/factor, which must clear the shrink trigger
        if self.shrink_utilization > self.hold_utilization / self.factor:
            raise ValueError(
                f"shrink_utilization ({self.shrink_utilization}) must be <= "
                f"hold_utilization / factor "
                f"({self.hold_utilization / self.factor}) or the bank flaps"
            )
        if self.cooldown_ticks < 0:
            raise ValueError("cooldown_ticks must be >= 0")

    def _ladder_up(self, width: int) -> int:
        """Smallest ladder width (min_streams · factorᵏ) >= ``width``."""
        w = self.min_streams
        while w < width:
            w *= self.factor
        return w

    def decide(
        self,
        n_streams: int,
        n_active: int,
        queue_depth: int,
        deadline_miss_rate: float = 0.0,
        ticks_since_resize: Optional[int] = None,
    ) -> Optional[ResizeDecision]:
        """The controller: current width + live telemetry in, at most one
        ``ResizeDecision`` out (``None`` = hold).  ``ticks_since_resize`` is
        ``None`` when the service has never resized (cooldown waived)."""
        if (
            ticks_since_resize is not None
            and ticks_since_resize < self.cooldown_ticks
        ):
            return None
        queued = queue_depth >= self.grow_queue_depth
        missing = (
            self.grow_miss_rate is not None
            and deadline_miss_rate > self.grow_miss_rate
        )
        if (queued or missing) and n_streams < self.max_streams:
            target = min(self.max_streams, n_streams * self.factor)
            reason = (
                f"queue_depth={queue_depth}"
                if queued
                else f"deadline_miss_rate={deadline_miss_rate:.3f}"
            )
            return ResizeDecision("grow", target, reason)
        if queued or missing or queue_depth > 0:
            return None  # demand present — never shrink into it
        if n_streams <= self.min_streams:
            return None
        if n_active / n_streams >= self.shrink_utilization:
            return None
        # smallest ladder width that holds utilization <= hold_utilization
        # (ceil division; n_active == 0 shrinks all the way to the floor)
        needed = -(-n_active // max(self.hold_utilization, 1e-9))
        target = self._ladder_up(max(self.min_streams, int(needed), n_active))
        if target >= n_streams:
            return None
        return ResizeDecision(
            "shrink",
            target,
            f"utilization={n_active}/{n_streams}",
        )
