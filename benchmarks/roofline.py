"""§Roofline report generator: reads the dry-run JSONs and emits the
per-(arch × shape) table (single-pod mesh, per the assignment).

    PYTHONPATH=src python -m benchmarks.roofline [--dir benchmarks/results/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional


def load_results(directory: str = "benchmarks/results/dryrun", mesh: str = "single") -> List[Dict]:
    rows = []
    for p in sorted(Path(directory).glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if "skipped" in r:
            rows.append(r)
            continue
        rows.append(r)
    return rows


def fmt_table(rows: List[Dict], md: bool = True) -> str:
    hdr = [
        "arch", "shape", "t_compute(s)", "t_memory(s)", "t_coll(s)",
        "bottleneck", "useful_flops", "roofline_frac",
    ]
    lines = []
    if md:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for r in rows:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |"
                if md else f"{r['arch']},{r['shape']},skipped"
            )
            continue
        ro = r["roofline"]
        vals = [
            r["arch"], r["shape"],
            f"{ro['t_compute_s']:.4g}", f"{ro['t_memory_s']:.4g}",
            f"{ro['t_collective_s']:.4g}", ro["bottleneck"],
            f"{ro['useful_flops_ratio']:.3f}", f"{ro['roofline_fraction']:.4f}",
        ]
        lines.append(("| " + " | ".join(vals) + " |") if md else ",".join(vals))
    return "\n".join(lines)


def worst_cells(rows: List[Dict], k: int = 5) -> List[Dict]:
    live = [r for r in rows if "roofline" in r]
    return sorted(live, key=lambda r: r["roofline"]["roofline_fraction"])[:k]


def most_collective_bound(rows: List[Dict], k: int = 5) -> List[Dict]:
    live = [r for r in rows if "roofline" in r]

    def coll_share(r):
        ro = r["roofline"]
        tot = ro["t_compute_s"] + ro["t_memory_s"] + ro["t_collective_s"]
        return ro["t_collective_s"] / tot if tot else 0.0

    return sorted(live, key=coll_share, reverse=True)[:k]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)
    rows = load_results(args.dir, args.mesh)
    if not rows:
        print("roofline,no-results (run: python -m repro.launch.dryrun --all)")
        return []
    print(fmt_table(rows, md=not args.csv))
    print()
    print("worst roofline fractions:")
    for r in worst_cells(rows, 3):
        print(f"  {r['arch']}/{r['shape']}: {r['roofline']['roofline_fraction']:.4f}")
    print("most collective-bound:")
    for r in most_collective_bound(rows, 3):
        ro = r["roofline"]
        print(f"  {r['arch']}/{r['shape']}: t_coll={ro['t_collective_s']:.3g}s")
    return rows


if __name__ == "__main__":
    main()
