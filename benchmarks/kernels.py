"""Kernel microbenches.

On this CPU container Pallas runs in interpret mode (Python — not indicative),
so wall-times are reported for the jit'd XLA paths (ref oracle vs fused closed
form) and the Pallas kernels are validated by allclose + their VMEM/tiling
parameters reported structurally (the TPU-relevant numbers)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import easi as easi_lib
from repro.kernels.easi_gradient.ref import easi_gradient_ref
from repro.kernels.flash_attention.ref import attention_ref


def _time(fn, *args, reps=10) -> float:
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run() -> List[Dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    # EASI gradient: naive per-sample einsum (FPGA-order) vs fused closed form
    for P, n in ((4096, 8), (16384, 64)):
        Y = jax.random.normal(key, (P, n))
        w = jnp.full((P,), 1e-3)
        t_ref = _time(jax.jit(easi_gradient_ref), Y, w)
        t_fused = _time(
            jax.jit(lambda Y, w: easi_lib.batched_relative_gradient(Y, w, lambda v: v**3)),
            Y, w,
        )
        rows.append({
            "name": f"easi_gradient_P{P}_n{n}",
            "us_ref": t_ref * 1e6,
            "us_fused": t_fused * 1e6,
            "speedup": t_ref / t_fused,
        })

    # attention: XLA dense reference timing (flash kernel = TPU target,
    # validated by allclose in tests/test_kernels.py)
    B, Hq, Hkv, T, d = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (B, Hq, T, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, T, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, T, d))
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v, scale=d**-0.5))
    rows.append({"name": f"attention_ref_T{T}", "us_ref": _time(f, q, k, v) * 1e6})

    # structural: flash kernel VMEM working set per grid step
    bq = bk = 128
    vmem = (bq * d + 2 * bk * d + bq * bk + bq * d + 2 * bq) * 4
    rows.append({
        "name": "flash_attention_vmem_per_step",
        "block_q": bq, "block_k": bk,
        "vmem_bytes": vmem,
        "fits_16MB_vmem": vmem < 16 * 2**20,
    })
    return rows


def main():
    out = run()
    for r in out:
        if "us_fused" in r:
            print(f"kernel,{r['name']},ref={r['us_ref']:.0f}us,fused={r['us_fused']:.0f}us,speedup={r['speedup']:.1f}x")
        elif "us_ref" in r:
            print(f"kernel,{r['name']},{r['us_ref']:.0f}us")
        else:
            print(f"kernel,{r['name']},vmem={r['vmem_bytes']}B,fits={r['fits_16MB_vmem']}")
    return out


if __name__ == "__main__":
    main()
