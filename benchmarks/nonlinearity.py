"""Paper §V.B: nonlinearity cost — tanh vs cubic vs relu.

On the FPGA the cubic saved DSP/ALM resources at equal clock.  The TPU
analogue: time per batched-relative-gradient call (the g(.) evaluation is the
only difference) and the transcendental-op count.  Cubic and relu are
mul/add-only (VPU-cheap) exactly as the paper argues.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import easi as easi_lib
from repro.core import nonlinearities


def _time(fn, *args, reps=20) -> float:
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(P: int = 65_536, n: int = 64) -> List[Dict[str, float]]:
    key = jax.random.PRNGKey(0)
    Y = jax.random.normal(key, (P, n))
    w = jnp.full((P,), 1e-3)
    rows = []
    for name in ("cubic", "tanh", "relu", "scaled_tanh"):
        g = nonlinearities.get(name)
        f = jax.jit(lambda Y, w, g=g: easi_lib.batched_relative_gradient(Y, w, g))
        t = _time(f, Y, w)
        rows.append({"nonlinearity": name, "us_per_call": t * 1e6, "P": P, "n": n})
    base = next(r for r in rows if r["nonlinearity"] == "tanh")["us_per_call"]
    for r in rows:
        r["vs_tanh"] = base / r["us_per_call"]
    return rows


def main():
    for r in run():
        print(
            f"nonlinearity,{r['nonlinearity']},{r['us_per_call']:.0f}us"
            f",speed_vs_tanh={r['vs_tanh']:.2f}x"
        )
    return run()


if __name__ == "__main__":
    main()
