"""Paper §V.A: convergence-rate comparison, SGD vs SMBGD.

Protocol mirrors the paper: multiple instances of the same separation problem
(m=4 → n=2) from different random initial separation matrices; count
iterations (samples seen) until the Amari index stays below threshold; average
across runs.  Paper reports 4166 (SGD) vs 3166 (SMBGD) → ~24 % improvement.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import easi as easi_lib
from repro.core import metrics, smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig
from repro.data import signals

M_, N_ = 4, 2
T = 30_000
THRESH = 0.08
N_SEEDS = 24
CHECK = 50  # evaluate Amari every CHECK samples


def _convergence_iters_sgd(key, ecfg: EASIConfig) -> float:
    kp, ki = jax.random.split(key)
    A, S, X = signals.make_problem(kp, m=M_, n=N_, T=T)
    B = easi_lib.init_separation_matrix(ecfg, ki)

    Xc = X[: T // CHECK * CHECK].reshape(-1, CHECK, M_)

    def body(B, xc):
        B, _ = easi_lib.easi_sgd_scan(B, xc, ecfg)
        pi = metrics.amari_index(metrics.global_system(B, A))
        return B, pi

    _, trace = jax.lax.scan(body, B, Xc)
    it = metrics.iterations_to_converge(trace, THRESH, sustain=3)
    return jnp.where(it == trace.shape[0], jnp.inf, it * CHECK)


def _convergence_iters_smbgd(key, ecfg: EASIConfig, ocfg: SMBGDConfig) -> float:
    kp, ki = jax.random.split(key)
    A, S, X = signals.make_problem(kp, m=M_, n=N_, T=T)
    st = smbgd_lib.init_state(ecfg, ki)
    Xc = X[: T // CHECK * CHECK].reshape(-1, CHECK, M_)

    def body(st, xc):
        st, _ = smbgd_lib.smbgd_epoch(st, xc, ecfg, ocfg)
        pi = metrics.amari_index(metrics.global_system(st.B, A))
        return st, pi

    _, trace = jax.lax.scan(body, st, Xc)
    it = metrics.iterations_to_converge(trace, THRESH, sustain=3)
    return jnp.where(it == trace.shape[0], jnp.inf, it * CHECK)


def _mean_converged(v):
    ok = jnp.isfinite(v)
    mean = float(jnp.sum(jnp.where(ok, v, 0.0)) / jnp.maximum(jnp.sum(ok), 1))
    frac = float(jnp.mean(ok))
    # penalize non-convergence so "fast but unstable" settings don't win
    return mean if frac == 1.0 else float("inf"), int(jnp.sum(ok))


def run() -> Dict[str, float]:
    """Best-tuned vs best-tuned (the paper's hyper-parameters are not
    published; momentum's speedup materializes through the larger stable
    effective step it affords, so each algorithm gets its best μ — and SMBGD
    its best (β, γ) — over a fixed public grid, averaged over seeds)."""
    keys = jax.random.split(jax.random.PRNGKey(2017), N_SEEDS)
    mus = (5e-4, 1e-3, 2e-3, 5e-3)

    best_sgd: Dict = {"iters": float("inf")}
    for mu in mus:
        ecfg = EASIConfig(n_components=N_, n_features=M_, mu=mu, nonlinearity="cubic")
        f = jax.jit(lambda k, e=ecfg: _convergence_iters_sgd(k, e))
        iters, ok = _mean_converged(jnp.stack([f(k) for k in keys]))
        if iters < best_sgd["iters"]:
            best_sgd = {"iters": iters, "mu": mu, "converged": ok}

    best_smb: Dict = {"iters": float("inf")}
    for mu in mus:
        for beta, gamma in ((0.9, 0.5), (0.9, 0.8), (1.0, 0.5), (1.0, 0.8)):
            ecfg = EASIConfig(
                n_components=N_, n_features=M_, mu=mu, nonlinearity="cubic"
            )
            ocfg = SMBGDConfig(batch_size=8, mu=mu, beta=beta, gamma=gamma)
            f = jax.jit(lambda k, e=ecfg, o=ocfg: _convergence_iters_smbgd(k, e, o))
            iters, ok = _mean_converged(jnp.stack([f(k) for k in keys]))
            if iters < best_smb["iters"]:
                best_smb = {
                    "iters": iters, "mu": mu, "beta": beta, "gamma": gamma,
                    "converged": ok,
                }

    improvement = 100.0 * (1.0 - best_smb["iters"] / best_sgd["iters"])
    return {
        "sgd": best_sgd,
        "smbgd": best_smb,
        "improvement_pct": improvement,
        "paper_sgd": 4166,
        "paper_smbgd": 3166,
        "paper_improvement_pct": 24.0,
    }


def main():
    t0 = time.time()
    r = run()
    s, m = r["sgd"], r["smbgd"]
    print(
        f"convergence,sgd_iters={s['iters']:.0f} (mu={s['mu']}, {s['converged']}/{N_SEEDS}),"
        f"smbgd_iters={m['iters']:.0f} (mu={m['mu']},beta={m['beta']},gamma={m['gamma']},"
        f" {m['converged']}/{N_SEEDS}),"
        f"improvement={r['improvement_pct']:.1f}% (paper: 24%) [{time.time()-t0:.0f}s]"
    )
    return r


if __name__ == "__main__":
    main()
