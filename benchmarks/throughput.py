"""Paper Table I analogue: throughput of EASI-with-SGD vs EASI-with-SMBGD.

On the FPGA the win came from pipelining (one sample/clock, 4.81 → 717.21
MIPS = 149×).  The TPU/JAX analogue of the same dependency-breaking insight:
the serial per-sample scan (loop-carried B update) vs the batched SMBGD step
(rank-P MXU matmuls, B committed once per mini-batch).  We measure
samples/second of both on identical streams, sweeping the mini-batch size P
(the pipeline-depth analogue), plus the m=4/n=2 paper dims and a scaled
problem to show the gap widens with dimensionality.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import easi as easi_lib
from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig
from repro.data import signals


def _time(fn, *args, reps=5) -> float:
    jax.block_until_ready(fn(*args))  # compile
    jax.block_until_ready(fn(*args))  # warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_case(m: int, n: int, T: int, P: int, use_pallas: bool = False) -> Dict[str, float]:
    key = jax.random.PRNGKey(0)
    A, S, X = signals.make_problem(key, m=m, n=n, T=T)
    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
    B0 = easi_lib.init_separation_matrix(ecfg, jax.random.PRNGKey(1))
    st0 = smbgd_lib.init_state(ecfg, jax.random.PRNGKey(1))

    t_sgd = _time(lambda x: easi_lib.easi_sgd_scan(B0, x, ecfg)[0], X)
    t_smb = _time(
        lambda x: smbgd_lib.smbgd_epoch(st0, x, ecfg, ocfg, use_pallas)[0].B, X
    )
    return {
        "m": m, "n": n, "P": P, "T": T,
        "sgd_samples_per_s": T / t_sgd,
        "smbgd_samples_per_s": T / t_smb,
        "speedup": t_sgd / t_smb,
    }


def run() -> List[Dict[str, float]]:
    out = []
    # the paper's dims (m=4, n=2), P sweep = pipeline-depth analogue
    for P in (4, 8, 32, 128):
        out.append(bench_case(4, 2, 32_768, P))
    # dimensional scaling: the MXU form keeps winning as n grows
    out.append(bench_case(16, 8, 16_384, 64))
    out.append(bench_case(64, 32, 16_384, 64))
    return out


def main():
    rows = run()
    for r in rows:
        print(
            f"throughput,m={r['m']},n={r['n']},P={r['P']}"
            f",sgd={r['sgd_samples_per_s']:.3g}sps,smbgd={r['smbgd_samples_per_s']:.3g}sps"
            f",speedup={r['speedup']:.1f}x (paper: 149.1x at m=4,n=2)"
        )
    return rows


if __name__ == "__main__":
    main()
