"""Benchmark orchestrator — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

  convergence   — §V.A  (SGD 4166 vs SMBGD 3166 iterations, 24 %)
  throughput    — Table I analogue (serial SGD vs batched SMBGD, P sweep)
  streams       — SeparatorBank scaling (fused S-stream step vs Python loop,
                  S sweep; writes BENCH_streams.json)
  nonlinearity  — §V.B  (tanh vs cubic vs relu cost)
  kernels       — Pallas hot-spot microbenches / structural VMEM report
  roofline      — §Roofline table from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip the slow convergence study")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from benchmarks import (
        convergence,
        kernels,
        nonlinearity,
        roofline,
        stream_throughput,
        throughput,
    )

    suites = {
        "throughput": throughput.main,
        "streams": lambda: stream_throughput.run(
            quick=args.quick, out="BENCH_streams.json"
        ),
        "nonlinearity": nonlinearity.main,
        "kernels": kernels.main,
        "roofline": lambda: roofline.main([]),
        "convergence": convergence.main,
    }
    if args.quick:
        suites.pop("convergence")
    if args.only:
        suites = {args.only: suites[args.only]}

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        print(f"== {name} ==")
        try:
            fn()
        except Exception as e:  # keep the harness running
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        print(f"== {name} done in {time.time()-t0:.1f}s ==")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
