"""Multi-stream scaling: SeparatorBank vs a Python loop over S separators.

The paper's Table I measured one datapath's throughput; this measures the
*rack*.  Scenario = streaming deployment (what ``serve.SeparationService``
does): every tick each live session delivers a ``(P, m)`` mini-batch, and the
engine must advance all S sessions before the next tick.

  * ``bank``   — ONE fused ``SeparatorBank.step`` per tick (vmap XLA math on
    the leading stream axis),
  * ``bank_pallas`` — the PR-1 Pallas path: the weighted gradient sum of all
    streams in one (streams, P-tiles) kernel, Y/commit as XLA ops around it,
  * ``fused_step`` — the whole-step megakernel: Y = X Bᵀ, nonlinearity,
    gradient sum AND the SMBGD commit in one launch on persistent padded
    state, with donated buffers and a block-aligned X (the zero-copy serving
    configuration),
  * ``loop``   — the naive engine: a Python loop dispatching S jitted
    single-stream ``smbgd_batched_step`` calls per tick.

Per-tick wall-clock of the bank grows sublinearly in S (one dispatch, one
compiled program, vectorized math) while the loop pays per-session dispatch
every tick.  samples/sec vs S goes to ``BENCH_streams.json`` so the perf
trajectory is recorded run over run.

    PYTHONPATH=src python benchmarks/stream_throughput.py [--quick]
    PYTHONPATH=src python benchmarks/stream_throughput.py --autotune   # 2-D sweep:
        (block_p, block_s) x prefetch, bf16 measured at the winning
        geometry; winners persist to AUTOTUNE.json (stream.autotune),
        which SeparatorBank loads by default
    PYTHONPATH=src python benchmarks/stream_throughput.py --autotune-smoke  # CI:
        fails when AUTOTUNE.json is stale for the S=8 key on this backend
        or the persistent bytes/session implied by the current layout
        regress >10% vs the recorded numbers
    PYTHONPATH=src python benchmarks/stream_throughput.py --smoke      # CI gate:
        re-measures S=8 and exits 1 on a >2x per-tick regression vs the
        checked-in BENCH_streams.json (plus the S=1 crossover floor)
    PYTHONPATH=src python benchmarks/stream_throughput.py --churn      # lifecycle
        churn: sessions arriving/converging/evicting through the
        SeparationService admission queue; effective samples/sec of
        convergence-aware auto-eviction vs a periodic-sweep baseline
    PYTHONPATH=src python benchmarks/stream_throughput.py --probe      # batched
        out-of-band drift probing: 256 parked sessions probed through the
        transient probe bank (one launch per probe_batch) vs the PR-4
        sequential one-dispatch-per-session loop
    PYTHONPATH=src python benchmarks/stream_throughput.py --health     # fault
        containment overhead: the per-stream health word + in-kernel commit
        masking (health_checks=True, the default) vs the telemetry-free bank
        at S=64; exits 1 when containment's HBM overhead exceeds the 5% bar
        or the wall ratio exceeds the documented interpreter ceiling
    PYTHONPATH=src python benchmarks/stream_throughput.py --adapt      # adaptive
        μ: the same abrupt mixing rotation served with the PR-4 fixed
        drift boost vs the moment-scaled controller over the in-kernel
        [Σy², Σy⁴] telemetry; records ticks-to-reconverge for both, the
        controller's μ trajectory, and the telemetry's analytic HBM
        overhead (gated ≤5% and ≥1.3x fewer ticks via --smoke)
    PYTHONPATH=src python benchmarks/stream_throughput.py --slo        # latency
        SLO replay: re-run the checked-in recorded load
        (benchmarks/traces/slo_small.npz) through the serving engine with a
        per-tick deadline budget calibrated off a warmup pass; records
        p50/p99/p999 time-to-ready and the deadline miss rate
    PYTHONPATH=src python benchmarks/stream_throughput.py --record-trace  # re-
        generate the checked-in SLO trace (deterministic synthetic load)
    PYTHONPATH=src python benchmarks/stream_throughput.py --elastic    # elastic
        burst trace: a width-2 bank under an 8-session burst with the
        run_tick autoscaler on (prewarmed power-of-two ladder) vs a bank
        frozen at max width; records steady-tick latency for both, the
        resize-tick overhead (gated ≤5x steady), and mean utilization
        (autoscaled gated ≥1.5x the fixed-wide baseline)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig
from repro.kernels.easi_gradient import ops as easi_ops
from repro.stream import SeparatorBank
from repro.stream import autotune as autotune_lib

DEFAULT_OUT = Path(__file__).parent.parent / "BENCH_streams.json"
SMOKE_S = 8
SMOKE_FACTOR = 2.0  # CI fails when a tick gets this much slower
SMOKE_KEYS = ("bank_tick_s", "fused_tick_s")
# Known interpret-mode crossover, documented rather than papered over: at S=1
# the megakernel's per-launch fixed costs aren't amortized over streams, so
# the PR-1 pallas path wins (checked-in fused/pr1 ≈ 0.72x; fused wins from
# S≥8 and widens with S).  The smoke gate only fails if the ratio COLLAPSES
# below this floor — i.e. someone added per-launch overhead, not the known
# constant.
S1_CROSSOVER_FLOOR = 0.45
# --autotune-smoke: recorded persistent bytes/session may grow at most 10%
PERSISTENT_BYTES_SLACK = 1.10
# --health acceptance bar: fault containment must add ≤ 5% to the fused
# tick's HBM traffic at serving scale.  The health word is an in-register
# epilogue (isfinite folds + the blow-up bound on the conv statistic already
# in registers); its ONLY extra HBM traffic is the int32 word written per
# stream per tick, so the analytic ratio sits at ~1.0002 — the gate exists to
# fail loudly if containment ever grows a real extra pass over X/Y/state.
HEALTH_OVERHEAD_BAR = 1.05
# Interpret-mode wall-clock ceiling for the same comparison, documented
# rather than papered over (the S1_CROSSOVER_FLOOR idiom): the interpreter
# executes every VPU op as a separate host array pass, so the free-beside-MXU
# epilogue prices at 1.1-1.4x here.  A STRUCTURAL regression — health
# re-reading state or Y from HBM — shows as ≥2x on the interpreter; the
# ceiling only fails on that, not on the known emulation constant.
HEALTH_WALL_CEIL_INTERPRET = 1.6
HEALTH_S = 64
BF16_REDUCTION_BAR = 1.5  # acceptance: bf16 persistent bytes cut ≥ 1.5x
# --slo: the checked-in recorded load and its budget calibration.  The budget
# is derived from THIS machine's warmup p50 (budget = factor x p50), so the
# recorded miss rate measures tail spread, not absolute machine speed — the
# number CI can compare across runners.
DEFAULT_TRACE = Path(__file__).parent / "traces" / "slo_small.npz"
# --adapt acceptance bars.  The moment telemetry's ONLY extra HBM traffic is
# the (2,) f32 raw-moment row written per stream per tick (the fold itself
# rides the in-register reduction pass that already produces conv and the
# health word), so the analytic ratio sits at ~1.002 — the 5% bar fails
# loudly if kurtosis estimation ever grows a real extra pass over X/Y/state.
ADAPT_OVERHEAD_BAR = 1.05
# ...and the controller must EARN its keep: ≥1.3x fewer ticks to re-converge
# after the abrupt rotation than the PR-4 open-loop fixed boost (the
# checked-in row records ~2.3x on the drill scenario).
ADAPT_RECONV_BAR = 1.3
SLO_BUDGET_FACTOR = 5.0
# --elastic acceptance bars: a resize tick (grow/shrink/compact inside
# run_tick, prewarmed ladder so no XLA compile rides along) must stay within
# ELASTIC_RESIZE_FACTOR x the elastic run's own steady tick — self-relative,
# so machine speed cancels — and the autoscaled bank's mean utilization over
# the burst trace must beat the fixed-wide baseline's by ELASTIC_UTIL_GAIN x
# (the capacity the autoscaler refuses to strand).
ELASTIC_RESIZE_FACTOR = 5.0
ELASTIC_UTIL_GAIN = 1.5
SLO_MISS_REGRESSION = 2.0  # smoke: fail when miss rate regresses this much
SLO_MISS_FLOOR = 0.10  # ...but never below this absolute slack (tiny-N noise)


def _time_step_loop(step, state0, n_ticks, reps, *args, copy_state=False):
    """Best-of-reps per-tick wall clock for ``state, _ = step(state, *args)``.

    ``copy_state=True`` re-clones the initial state each rep — required when
    ``step`` donates its state buffers (the clone is outside the timed
    region, like a real service's startup)."""
    t_best = float("inf")
    for _ in range(reps):
        st = jax.tree.map(jnp.copy, state0) if copy_state else state0
        jax.block_until_ready(st)
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            st, _ = step(st, *args)
        jax.block_until_ready(st)
        t_best = min(t_best, (time.perf_counter() - t0) / n_ticks)
    return t_best


def _measured_tick_bytes(jitted_step, *args) -> Optional[float]:
    """XLA's own bytes-moved estimate for one tick ("bytes accessed" from the
    compiled program's cost_analysis), or None where the backend doesn't
    report it — callers fall back to the layout's analytic floor."""
    try:
        cost = jitted_step.lower(*args).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        val = cost.get("bytes accessed")
        return float(val) if val is not None else None
    except Exception:
        return None


def bench_streams(
    S: int,
    P: int = 32,
    m: int = 4,
    n: int = 2,
    n_ticks: int = 50,
    reps: int = 3,
    block_p: Optional[int] = None,
) -> Dict[str, float]:
    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(jax.random.fold_in(key, 1), (S, P, m))

    # fused bank: one jitted step advances all S sessions (vmap XLA baseline)
    bank = SeparatorBank(ecfg, ocfg, n_streams=S)
    bank_step = jax.jit(bank.step)
    state0 = bank.init(key)
    jax.block_until_ready(bank_step(state0, X))  # compile
    t_bank = _time_step_loop(bank_step, state0, n_ticks, reps, X)

    # PR-1 Pallas path: gradient-sum kernel, XLA Y/commit around it
    pbank = SeparatorBank(ecfg, ocfg, n_streams=S, use_pallas=True)
    pbank_step = jax.jit(pbank.step)
    jax.block_until_ready(pbank_step(state0, X))
    t_pallas = _time_step_loop(pbank_step, state0, n_ticks, reps, X)

    # whole-step megakernel: persistent padded state, block-aligned X,
    # donation per backend default — the zero-copy serving configuration
    fused = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True, block_p=block_p)
    fstep = fused.make_step()
    state0f = fused.init(key)
    Xp = jax.block_until_ready(fused.pad_batch(X))
    act = jnp.ones((S,), jnp.int32)
    warm = jax.tree.map(jnp.copy, state0f)
    jax.block_until_ready(fstep(warm, Xp, act))  # compile
    t_fused = _time_step_loop(
        lambda st, x: fstep(st, x, act), state0f, n_ticks, reps, Xp,
        copy_state=True,
    )

    # bytes-moved accounting: the bandwidth claim as numbers, not a story.
    # Analytic per-stream estimates read off the layout; the measured total
    # is XLA's own cost model for the whole compiled tick (None on backends
    # that don't report it).
    lay = fused.layout
    lay_bf16 = easi_ops.bank_layout(
        n, m, P, block_p=lay.block_p, dtype_policy="bf16"
    )
    measured_bytes = _measured_tick_bytes(fstep, state0f, Xp, act)

    # bf16 storage + prefetch at the SAME resolved geometry as the f32 fused
    # bank — the reduced-footprint serving configuration
    fused_bf = SeparatorBank(
        ecfg, ocfg, n_streams=S, fused=True,
        block_p=lay.block_p, block_s=fused.block_s,
        dtype_policy="bf16", prefetch=True, autotune=False,
    )
    fstep_bf = fused_bf.make_step()
    state0bf = fused_bf.init(key)
    warm = jax.tree.map(jnp.copy, state0bf)
    jax.block_until_ready(fstep_bf(warm, Xp, act))  # compile
    t_fused_bf = _time_step_loop(
        lambda st, x: fstep_bf(st, x, act), state0bf, n_ticks, reps, Xp,
        copy_state=True,
    )

    # naive engine: Python loop of S single-stream jitted steps per tick
    # (the jit cache is shared across sessions — the loop pays dispatch,
    # not recompilation)
    single_step = jax.jit(
        lambda st, x: smbgd_lib.smbgd_batched_step(st, x, ecfg, ocfg)
    )
    states0 = [smbgd_lib.init_state(ecfg, k) for k in jax.random.split(key, S)]
    jax.block_until_ready(single_step(states0[0], X[0]))  # compile
    t_loop = float("inf")
    for _ in range(reps):
        states = list(states0)
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            states = [single_step(states[s], X[s])[0] for s in range(S)]
        jax.block_until_ready(states)  # ALL streams — async backends
        t_loop = min(t_loop, (time.perf_counter() - t0) / n_ticks)

    samples_per_tick = S * P
    row = {
        "S": S, "P": P, "m": m, "n": n, "n_ticks": n_ticks,
        "fused_block_p": lay.block_p,
        "fused_prefetch": bool(fused.prefetch),
        "bank_tick_s": t_bank,
        "bank_pallas_tick_s": t_pallas,
        "fused_tick_s": t_fused,
        "fused_bf16_prefetch_tick_s": t_fused_bf,
        "loop_tick_s": t_loop,
        "bank_samples_per_s": samples_per_tick / t_bank,
        "bank_pallas_samples_per_s": samples_per_tick / t_pallas,
        "fused_samples_per_s": samples_per_tick / t_fused,
        "fused_bf16_prefetch_samples_per_s": samples_per_tick / t_fused_bf,
        "loop_samples_per_s": samples_per_tick / t_loop,
        "bank_over_loop": t_loop / t_bank,
        "fused_over_bank_pallas": t_pallas / t_fused,
        # bytes-per-tick columns (analytic floor per stream; measured = XLA
        # cost model for the whole tick, null where unreported)
        "est_tick_hbm_bytes_per_stream": lay.tick_hbm_bytes_per_stream,
        "est_tick_hbm_bytes_per_stream_bf16": lay_bf16.tick_hbm_bytes_per_stream,
        "measured_tick_bytes": measured_bytes,
        "persistent_bytes_per_session_f32": lay.persistent_bytes_per_session,
        "persistent_bytes_per_session_bf16": lay_bf16.persistent_bytes_per_session,
        "persistent_bytes_reduction": (
            lay.persistent_bytes_per_session
            / lay_bf16.persistent_bytes_per_session
        ),
    }
    print(
        f"streams,S={S},bank={row['bank_samples_per_s']:.3g}sps"
        f",pr1_pallas={row['bank_pallas_samples_per_s']:.3g}sps"
        f",fused={row['fused_samples_per_s']:.3g}sps"
        f",bf16+pf={row['fused_bf16_prefetch_samples_per_s']:.3g}sps"
        f",loop={row['loop_samples_per_s']:.3g}sps"
        f",bank/loop={row['bank_over_loop']:.1f}x"
        f",fused/pr1={row['fused_over_bank_pallas']:.2f}x"
        f",persist={row['persistent_bytes_per_session_f32']}B"
        f"→{row['persistent_bytes_per_session_bf16']}B"
        f" ({row['persistent_bytes_reduction']:.2f}x)"
    )
    return row


def autotune_bank(
    S: int,
    P: int = 32,
    m: int = 4,
    n: int = 2,
    n_ticks: int = 20,
    reps: int = 2,
    write_cache: bool = True,
) -> List[Dict[str, float]]:
    """2-D ``(block_p, block_s)`` sweep of the megakernel, toggling prefetch
    at every geometry, with bf16 storage measured at the winning geometry.

    Times ONLY the fused path (the other engines don't depend on the tile
    geometry).  The winner persists to the autotune cache (``AUTOTUNE.json``,
    keyed by ``(S, P, m, n, backend)``) where ``SeparatorBank`` picks it up
    by default; ``dtype_policy`` numbers are recorded but never auto-applied.
    Interpret-mode numbers steer nothing on real hardware — the cache key's
    backend tag keeps them apart (run with REPRO_PALLAS_INTERPRET=0 on TPU).
    """
    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(jax.random.fold_in(key, 1), (S, P, m))
    act = jnp.ones((S,), jnp.int32)

    def time_cfg(bp, bs, prefetch, policy=None):
        fused = SeparatorBank(
            ecfg, ocfg, n_streams=S, fused=True,
            block_p=bp, block_s=bs, prefetch=prefetch,
            dtype_policy=policy, autotune=False,
        )
        fstep = fused.make_step()
        state0 = fused.init(key)
        Xp = jax.block_until_ready(fused.pad_batch(X))
        warm = jax.tree.map(jnp.copy, state0)
        jax.block_until_ready(fstep(warm, Xp, act))  # compile
        return _time_step_loop(
            lambda st, x: fstep(st, x, act), state0, n_ticks, reps, Xp,
            copy_state=True,
        )

    bp_candidates = [bp for bp in (8, 16, 32, 64, 128, 256, 512) if bp <= P] or [P]
    bs_candidates = [d for d in range(1, S + 1) if S % d == 0]
    rows = []
    for bp in bp_candidates:
        for bs in bs_candidates:
            for pf in (False, True):
                t = time_cfg(bp, bs, pf)
                rows.append({
                    "autotune": True, "S": S, "P": P, "m": m, "n": n,
                    "block_p": bp, "block_s": bs, "prefetch": pf,
                    "fused_tick_s": t,
                })
    best = min(rows, key=lambda r: r["fused_tick_s"])
    # bf16 at the winning geometry: recorded for the capacity story, never
    # auto-applied (precision stays a caller decision)
    t_bf16 = time_cfg(
        best["block_p"], best["block_s"], best["prefetch"], "bf16"
    )
    lay_f32 = easi_ops.bank_layout(n, m, P, block_p=best["block_p"])
    lay_bf16 = easi_ops.bank_layout(
        n, m, P, block_p=best["block_p"], dtype_policy="bf16"
    )
    entry = {
        "block_p": best["block_p"],
        "block_s": best["block_s"],
        "prefetch": best["prefetch"],
        "fused_tick_s": best["fused_tick_s"],
        "bf16_fused_tick_s": t_bf16,
        "persistent_bytes_per_session": lay_f32.persistent_bytes_per_session,
        "bf16_persistent_bytes_per_session": lay_bf16.persistent_bytes_per_session,
        "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    if write_cache:
        path = autotune_lib.store(S, P, m, n, entry)
        print(f"autotune: wrote {autotune_lib.cache_key(S, P, m, n)} → {path}")
    print(
        f"autotune,S={S},P={P}: best block_p={best['block_p']} "
        f"block_s={best['block_s']} prefetch={best['prefetch']} "
        f"({best['fused_tick_s']*1e6:.1f}us/tick; bf16 {t_bf16*1e6:.1f}us)"
    )
    return rows


def churn_bench(
    S: int = 8,
    n_sessions: int = 32,
    P: int = 32,
    m: int = 4,
    n: int = 2,
    converge_ticks: int = 20,
    sweep_every: int = 60,
) -> Dict[str, float]:
    """Serving churn: ``n_sessions`` sessions contend for ``S`` slots, each
    "converging" after ``converge_ticks`` mini-batches (policy-driven — the
    conv statistic of random data sits far below the huge threshold, so the
    min-ticks floor models time-to-convergence deterministically).

      * ``auto``     — convergence-aware lifecycle: the policy evicts each
        session the tick it converges and backfills from the admission queue
        within the same tick.  Every slot-tick feeds an unconverged session.
      * ``baseline`` — no convergence signal: an operator sweep evicts
        finished sessions only every ``sweep_every`` ticks (the pre-policy
        deployment pattern).  Converged sessions keep burning slot-ticks.

    Effective samples/sec counts ONLY samples delivered to not-yet-converged
    sessions — the utilization the ROADMAP's eviction item is about.
    """
    from repro.serve.engine import ConvergencePolicy, SeparationService

    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (P, m))  # shared batch: data gen off the clock
    Xnp = jax.block_until_ready(X)
    sids = [f"s{i}" for i in range(n_sessions)]

    def drain(policy, manual_sweep: bool):
        svc = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=S),
            seed=0,
            policy=policy,
            max_queue=n_sessions,
        )
        for sid in sids:
            svc.admit(sid)
        useful = ticks = 0
        t0 = time.perf_counter()
        while True:
            active = [s for s in sids if svc.status(s) == "active"]
            if not active:
                break
            # count BEFORE stepping: an auto-evicted session's stats leave
            # with it.  This tick is "useful" for a session still short of
            # its convergence tick (ticks is pre-step here, hence the +1).
            useful += P * sum(
                1
                for sid in active
                if svc.session_stats(sid)["ticks"] + 1 <= converge_ticks
            )
            svc.step({sid: Xnp for sid in active})
            ticks += 1
            if manual_sweep and ticks % sweep_every == 0:
                for sid in active:
                    if svc.session_stats(sid)["ticks"] >= converge_ticks:
                        svc.evict(sid)
            if ticks > 100 * n_sessions * converge_ticks:
                raise RuntimeError("churn benchmark failed to drain")
        jax.block_until_ready(svc.state)
        dt = time.perf_counter() - t0
        return useful, ticks, dt

    policy = ConvergencePolicy(
        threshold=1e9, patience=1, min_ticks=converge_ticks
    )
    u_auto, t_auto, s_auto = drain(policy, manual_sweep=False)
    u_base, t_base, s_base = drain(None, manual_sweep=True)
    row = {
        "churn": True,
        "S": S, "P": P, "m": m, "n": n,
        "n_sessions": n_sessions,
        "converge_ticks": converge_ticks,
        "sweep_every": sweep_every,
        "auto_ticks": t_auto,
        "baseline_ticks": t_base,
        "auto_effective_samples_per_s": u_auto / s_auto,
        "baseline_effective_samples_per_s": u_base / s_base,
        "auto_useful_fraction": u_auto / (t_auto * S * P),
        "baseline_useful_fraction": u_base / (t_base * S * P),
        # wall-clock effective throughput ratio: honest but host-dominated at
        # CPU-interpret toy sizes (Python staging ≫ kernel time there)
        "effective_speedup_wall": (u_auto / s_auto) / (u_base / s_base),
        # tick-normalized drain speedup: on real hardware the tick rate is
        # set by the kernel, so this IS the slot-utilization win
        "drain_speedup_ticks": t_base / t_auto,
    }
    print(
        f"churn,S={S},sessions={n_sessions},K={converge_ticks}: "
        f"auto={row['auto_effective_samples_per_s']:.3g} eff-sps "
        f"({row['auto_useful_fraction']:.0%} useful, {t_auto} ticks) vs "
        f"baseline={row['baseline_effective_samples_per_s']:.3g} eff-sps "
        f"({row['baseline_useful_fraction']:.0%} useful, {t_base} ticks) "
        f"→ {row['drain_speedup_ticks']:.2f}x fewer ticks to drain "
        f"({row['effective_speedup_wall']:.2f}x wall)"
    )
    return row


def drift_bench(
    S: int = 4,
    P: int = 16,
    m: int = 4,
    n: int = 2,
    jump_tick: int = 250,
    n_ticks: int = 600,
) -> Dict[str, float]:
    """Drift scenario: ``S`` sessions under rotating mixing (an abrupt ≈1.2
    rad rotation at ``jump_tick``), served via ``run_tick`` from per-session
    ``SyntheticSource``s — watchdog ON vs OFF.

      * ``watchdog`` — ``DriftPolicy(mode="boost")``: converged sessions stay
        hot, the conv-statistic watchdog flags the rotation and μ-boosts the
        re-adaptation; separators end re-converged on the NEW mixing.
      * ``baseline`` — convergence lifecycle only (the PR-3 deployment):
        sessions converge, auto-evict, and their frozen separators go stale
        the moment the mixing moves.

    The figure of merit is the mean/max Amari index of each session's final
    separation matrix against the mixing at END of wall time — the quality
    of what the service would actually be serving."""
    from repro.core import metrics as metrics_lib
    from repro.data.pipeline import MixedSignals
    from repro.data.sources import SyntheticSource
    from repro.serve import ConvergencePolicy, DriftPolicy, SeparationService

    ecfg = EASIConfig(n_components=n, n_features=m, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=3e-3, beta=0.9, gamma=0.5)
    policy = ConvergencePolicy(threshold=0.025, patience=5, min_ticks=50, ema=0.9)
    dpol = DriftPolicy(
        retrigger=0.03, patience=2, ema=0.8, cooldown=3,
        mode="boost", boost=4.0, boost_ticks=40,
    )
    sids = [f"s{i}" for i in range(S)]

    def sources():
        # one distinct separation problem per session, same drift schedule
        return {
            sid: SyntheticSource(
                MixedSignals(m=m, n=n, batch=P, seed=i, drift_rate=1.2 / (5 * P)),
                drift_start=jump_tick,
                drift_stop=jump_tick + 5,
            )
            for i, sid in enumerate(sids)
        }

    def final_amari(svc, srcs):
        out = []
        for sid, src in srcs.items():
            if svc.status(sid) in ("active", "converged"):
                B = svc.bank.slot_state(svc.state, svc.sessions[sid]).B
            else:  # evicted: the frozen separator the service would serve
                B = svc.finished[sid].state.B
            A = src.mixing_at(n_ticks)  # mixing at END of wall time
            out.append(
                float(
                    metrics_lib.amari_index(
                        metrics_lib.global_system(B, jnp.asarray(A))
                    )
                )
            )
        return out

    def run_one(watchdog: bool):
        svc = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=S),
            seed=0,
            policy=policy,
            drift_policy=dpol if watchdog else None,
        )
        srcs = sources()
        for sid in sids:
            svc.admit(sid, source=srcs[sid])
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            svc.run_tick()
        jax.block_until_ready(svc.state)
        return svc, srcs, time.perf_counter() - t0

    svc_w, srcs_w, dt_w = run_one(watchdog=True)
    svc_b, srcs_b, dt_b = run_one(watchdog=False)
    pi_w, pi_b = final_amari(svc_w, srcs_w), final_amari(svc_b, srcs_b)
    row = {
        "drift": True,
        "S": S, "P": P, "m": m, "n": n,
        "jump_tick": jump_tick, "n_ticks": n_ticks,
        "watchdog_final_amari_mean": sum(pi_w) / S,
        "watchdog_final_amari_max": max(pi_w),
        "baseline_final_amari_mean": sum(pi_b) / S,
        "baseline_final_amari_max": max(pi_b),
        "watchdog_drift_events": svc_w.metrics["n_drift_events"],
        "watchdog_wall_s": dt_w,
        "baseline_wall_s": dt_b,
        # how much staler the baseline's served separators end up
        "stale_amari_ratio": (sum(pi_b) / S) / max(sum(pi_w) / S, 1e-9),
    }
    print(
        f"drift,S={S},jump@{jump_tick}: watchdog amari "
        f"mean={row['watchdog_final_amari_mean']:.4f} "
        f"max={row['watchdog_final_amari_max']:.4f} "
        f"({int(row['watchdog_drift_events'])} events) vs baseline (stale) "
        f"mean={row['baseline_final_amari_mean']:.4f} "
        f"max={row['baseline_final_amari_max']:.4f} "
        f"→ {row['stale_amari_ratio']:.1f}x staler without the watchdog"
    )
    return row


def probe_bench(
    n_parked: int = 256,
    P: int = 16,
    m: int = 4,
    n: int = 2,
    probe_batch: int = 64,
    n_probe_ticks: int = 5,
    reps: int = 2,
) -> Dict[str, float]:
    """Watchdog scaling: ``n_parked`` parked sessions under out-of-band drift
    probe, batched vs sequential.

      * ``batched``    — the transient-probe-bank engine: due sessions are
        stacked ``probe_batch`` at a time and each chunk's virtual conv
        statistics come out of ONE no-commit bank launch.
      * ``sequential`` — the PR-4 loop (``DriftPolicy(probe_batch=0)``): one
        jitted virtual-conv dispatch per parked session per probe tick.

    The figure of merit is probe launches per tick (the dispatch-bound cost
    that dominates watchdog reaction latency at serving scale) and the
    measured per-tick wall clock of ``run_tick`` with every session parked.
    """
    from repro.core import smbgd as smbgd_lib
    from repro.data.sources import ReplaySource
    from repro.serve import (
        ConvergencePolicy,
        DriftMonitor,
        DriftPolicy,
        ParkedSession,
        SeparationService,
        SessionMeta,
    )
    from repro.serve.engine import EvictionRecord, SessionStats

    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
    key = jax.random.PRNGKey(0)
    data = jax.device_get(
        jax.random.normal(jax.random.fold_in(key, 1), (64 * P, m))
    ).astype("float32")

    def build(batch):
        svc = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=8),
            seed=0,
            policy=ConvergencePolicy(),
            # retrigger unreachable: a stable parked population (the probe
            # cost itself is what's being measured, not readmission churn)
            drift_policy=DriftPolicy(
                mode="readmit", retrigger=1e9, probe_every=1, probe_batch=batch
            ),
        )
        keys = jax.random.split(key, n_parked)
        for i in range(n_parked):
            st = smbgd_lib.init_state(ecfg, keys[i])._replace(
                step=jnp.asarray(1, jnp.int32)
            )
            svc._parked[f"p{i}"] = ParkedSession(
                record=EvictionRecord(
                    state=st, stats=SessionStats(admitted_at=0.0),
                    monitor=None, reason="converged", tick=0,
                ),
                source=ReplaySource(data, loop=True),
                monitor=DriftMonitor(),
                meta=SessionMeta(order=i),
            )
        return svc

    def time_probes(batch):
        svc = build(batch)
        svc.run_tick()  # compile / warm the probe programs
        launches0 = svc.metrics["n_probe_launches"]
        t_best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            for _ in range(n_probe_ticks):
                svc.run_tick()
            t_best = min(t_best, (time.perf_counter() - t0) / n_probe_ticks)
        launches_per_tick = (svc.metrics["n_probe_launches"] - launches0) / (
            reps * n_probe_ticks
        )
        return t_best, launches_per_tick

    t_seq, l_seq = time_probes(0)
    t_bat, l_bat = time_probes(probe_batch)
    row = {
        "probe": True,
        "n_parked": n_parked, "P": P, "m": m, "n": n,
        "probe_batch": probe_batch,
        "n_probe_ticks": n_probe_ticks,
        "seq_tick_s": t_seq,
        "batched_tick_s": t_bat,
        "seq_launches_per_tick": l_seq,
        "batched_launches_per_tick": l_bat,
        "probe_launch_ratio": l_seq / max(l_bat, 1e-9),
        "probe_speedup": t_seq / t_bat,
    }
    print(
        f"probe,parked={n_parked},batch={probe_batch}: "
        f"batched={t_bat*1e3:.2f}ms/tick ({l_bat:.0f} launches) vs "
        f"sequential={t_seq*1e3:.2f}ms/tick ({l_seq:.0f} launches) "
        f"→ {row['probe_launch_ratio']:.0f}x fewer launches, "
        f"{row['probe_speedup']:.2f}x faster"
    )
    return row


def health_bench(
    S: int = HEALTH_S,
    P: int = 32,
    m: int = 4,
    n: int = 2,
    n_ticks: int = 50,
    reps: int = 3,
) -> Dict[str, float]:
    """Cost of fault containment: the per-stream health word + in-kernel
    commit masking (``health_checks=True``, the default) vs the telemetry-free
    bank (``health_checks=False``), at identical geometry.

    Measured on both serving engines:

      * ``fused`` — the megakernel, where health is ONE more in-register
        reduction folded into the existing epilogue (isfinite over B'/H'/Y
        plus the blow-up bound on the conv statistic already in registers),
      * ``vmap``  — the XLA bank, where the same word is a handful of
        elementwise reductions fused into the step program.

    Two figures of merit, because always-on containment must be cheap enough
    to never turn off:

      * the ANALYTIC HBM overhead — (tick bytes + the health word's 4 bytes)
        / tick bytes off the layout accounting, the quantity the ≤5%
        acceptance bar (``HEALTH_OVERHEAD_BAR``) gates.  This is the
        hardware-relevant cost: on a bandwidth-bound kernel the epilogue's
        VPU ops hide behind the MXU and only bytes moved matter,
      * the measured wall-clock ratio on THIS backend — recorded for the
        trajectory, gated only against ``HEALTH_WALL_CEIL_INTERPRET`` (the
        interpreter prices each in-register op as a host array pass, so the
        known emulation constant sits well above 5%; see the constant's
        comment).
    """
    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(jax.random.fold_in(key, 1), (S, P, m))
    act = jnp.ones((S,), jnp.int32)

    def time_fused(health: bool) -> float:
        fused = SeparatorBank(
            ecfg, ocfg, n_streams=S, fused=True, health_checks=health
        )
        fstep = fused.make_step()
        state0 = fused.init(key)
        Xp = jax.block_until_ready(fused.pad_batch(X))
        warm = jax.tree.map(jnp.copy, state0)
        jax.block_until_ready(fstep(warm, Xp, act))  # compile
        return _time_step_loop(
            lambda st, x: fstep(st, x, act), state0, n_ticks, reps, Xp,
            copy_state=True,
        )

    def time_vmap(health: bool) -> float:
        bank = SeparatorBank(ecfg, ocfg, n_streams=S, health_checks=health)
        bstep = jax.jit(bank.step)
        state0 = bank.init(key)
        jax.block_until_ready(bstep(state0, X))  # compile
        return _time_step_loop(bstep, state0, n_ticks, reps, X)

    t_fused_on = time_fused(True)
    t_fused_off = time_fused(False)
    t_vmap_on = time_vmap(True)
    t_vmap_off = time_vmap(False)
    lay = easi_ops.bank_layout(n, m, P)
    tick_bytes = lay.tick_hbm_bytes_per_stream
    hbm_overhead = (
        tick_bytes + easi_ops.HEALTH_TICK_BYTES_PER_STREAM
    ) / tick_bytes
    row = {
        "health": True,
        "S": S, "P": P, "m": m, "n": n, "n_ticks": n_ticks,
        "fused_health_tick_s": t_fused_on,
        "fused_nohealth_tick_s": t_fused_off,
        "vmap_health_tick_s": t_vmap_on,
        "vmap_nohealth_tick_s": t_vmap_off,
        "fused_health_wall_overhead": t_fused_on / t_fused_off,
        "vmap_health_wall_overhead": t_vmap_on / t_vmap_off,
        "health_tick_bytes_per_stream": easi_ops.HEALTH_TICK_BYTES_PER_STREAM,
        "health_hbm_overhead": hbm_overhead,
        "health_overhead_bar": HEALTH_OVERHEAD_BAR,
        "health_wall_ceil_interpret": HEALTH_WALL_CEIL_INTERPRET,
    }
    print(
        f"health,S={S}: hbm +{easi_ops.HEALTH_TICK_BYTES_PER_STREAM}B/stream "
        f"({hbm_overhead:.4f}x of {tick_bytes}B/tick); fused wall "
        f"{t_fused_on*1e6:.1f}us vs {t_fused_off*1e6:.1f}us off "
        f"({row['fused_health_wall_overhead']:.3f}x), vmap "
        f"{t_vmap_on*1e6:.1f}us vs {t_vmap_off*1e6:.1f}us off "
        f"({row['vmap_health_wall_overhead']:.3f}x)"
    )
    return row


def health_gate(row: Dict[str, float], slack: float = 1.0) -> int:
    """Exit code for the health-overhead acceptance bars: the analytic HBM
    overhead against ``HEALTH_OVERHEAD_BAR`` (the ≤5% claim), the measured
    wall ratio against the documented interpreter ceiling (``slack`` widens
    only the latter for noisy shared CI runners)."""
    rc = 0
    hbm = row["health_hbm_overhead"]
    if hbm > HEALTH_OVERHEAD_BAR:
        print(
            f"health: FAIL — containment adds {hbm:.4f}x HBM traffic "
            f"(> {HEALTH_OVERHEAD_BAR}x): the health word must stay an "
            f"in-register epilogue, not an extra pass over X/Y/state"
        )
        rc = 1
    else:
        print(f"health: hbm overhead {hbm:.4f}x ≤ {HEALTH_OVERHEAD_BAR}x ok")
    ceil = HEALTH_WALL_CEIL_INTERPRET * slack
    wall = row["fused_health_wall_overhead"]
    if wall > ceil:
        print(
            f"health: FAIL — fused wall overhead {wall:.3f}x exceeds the "
            f"{ceil:.3f}x interpreter ceiling (structural regression: the "
            f"emulation constant alone sits at 1.1-1.4x)"
        )
        rc = 1
    else:
        print(f"health: fused wall overhead {wall:.3f}x ≤ {ceil:.3f}x ok")
    return rc


def adapt_bench(
    P: int = 16,
    m: int = 4,
    n: int = 2,
    jump_tick: int = 300,
    n_ticks: int = 650,
    wall_ticks: int = 20,
    wall_reps: int = 2,
) -> Dict[str, float]:
    """Adaptive μ: ticks-to-reconverge after an abrupt mixing rotation, the
    PR-4 fixed drift boost vs the moment-scaled controller.

    One session serves a deterministic recording whose mixing rotates 1.4 rad
    at ``jump_tick`` — hard enough that re-adaptation outlasts the fixed
    40-tick boost window, which is exactly where an open-loop pulse
    mis-calibrates.  Two services from identical seeds:

      * ``fixed`` — ``DriftPolicy(mode="boost", boost=4, boost_ticks=40)``:
        the watchdog fires and μ is 4x for exactly 40 ticks, need it or not,
      * ``ctrl``  — the same watchdog with a no-op boost (boost=1) plus a
        ``MomentPolicy`` reading the bank's in-kernel [Σy², Σy⁴] telemetry:
        μ scales with the EMA-kurtosis deviation and anneals back to base as
        the separator re-converges (closed loop).

    Re-convergence = the tracked Amari index re-entering 1.5x its pre-jump
    value (censored at the horizon when never re-entered).  The row also
    records the telemetry's cost both ways the ≤5% claim can be read: the
    ANALYTIC HBM overhead off the layout accounting (the gated quantity —
    the output row is the telemetry's only extra traffic) and the measured
    fused wall ratio moments-on vs -off on THIS backend (trajectory only;
    the interpreter prices in-register folds as host array passes)."""
    from repro.core import metrics as metrics_lib
    from repro.data import signals
    from repro.data.sources import ReplaySource, _givens
    from repro.serve import (
        ConvergencePolicy, DriftPolicy, MomentPolicy, SeparationService,
    )

    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
    T = n_ticks * P
    src = signals.source_bank(jax.random.PRNGKey(1), n, T)
    A0 = signals.random_mixing_matrix(jax.random.PRNGKey(0), m, n)
    A1 = _givens(m, 1.4) @ A0
    At = jnp.where(
        (jnp.arange(T) < jump_tick * P)[:, None, None],
        jnp.broadcast_to(A0, (T, m, n)),
        jnp.broadcast_to(A1, (T, m, n)),
    )
    X = jax.device_get(signals.mix_nonstationary(At, src)).astype("float32")

    def run_one(moment_policy=None, boost=4.0):
        svc = SeparationService(
            SeparatorBank(
                ecfg, ocfg, n_streams=2, moments=moment_policy is not None
            ),
            seed=0,
            policy=ConvergencePolicy(
                threshold=0.025, patience=5, min_ticks=50, ema=0.9
            ),
            drift_policy=DriftPolicy(
                retrigger=0.03, patience=2, ema=0.8, cooldown=3,
                mode="boost", boost=boost, boost_ticks=40,
            ),
            moment_policy=moment_policy,
        )
        svc.admit("s0", source=ReplaySource(X))
        trace = []
        peak = 1.0
        for tick in range(n_ticks - 1):
            svc.run_tick()
            if moment_policy is not None and "s0" in svc.sessions:
                peak = max(peak, svc.session_stats("s0").get("mu_ctrl", 1.0))
            if tick % 5 == 4 and svc.status("s0") in ("active", "converged"):
                B = svc.bank.slot_state(svc.state, svc.sessions["s0"]).B
                A = A0 if tick < jump_tick else A1
                trace.append((tick, float(
                    metrics_lib.amari_index(
                        metrics_lib.global_system(B, jnp.asarray(A))
                    )
                )))
        final = (
            svc.session_stats("s0").get("mu_ctrl", 1.0)
            if moment_policy is not None and "s0" in svc.sessions
            else 1.0
        )
        return trace, peak, final

    def reconverge_ticks(trace):
        pre = [pi for t, pi in trace if t < jump_tick]
        band = 1.5 * pre[-1]  # "recovered" = back inside 1.5x pre-jump error
        for t, pi in trace:
            if t >= jump_tick + 10 and pi <= band:
                return t - jump_tick
        return None  # censored at the horizon

    tr_fixed, _, _ = run_one()
    tr_ctrl, peak, final_scale = run_one(
        moment_policy=MomentPolicy(
            ema_fast=0.3, ema_slow=0.005, warmup_ticks=20,
            deadband=0.05, gain=6.0, max_scale=8.0,
        ),
        boost=1.0,
    )
    horizon = n_ticks - jump_tick
    r_fixed = reconverge_ticks(tr_fixed)
    r_ctrl = reconverge_ticks(tr_ctrl)
    ratio = (r_fixed if r_fixed is not None else horizon) / max(
        r_ctrl if r_ctrl is not None else horizon, 1
    )

    # telemetry cost: the analytic HBM ratio (the gated quantity) + the
    # measured fused wall ratio at serving scale (trajectory only)
    lay = easi_ops.bank_layout(n, m, P)
    tick_bytes = lay.tick_hbm_bytes_per_stream
    hbm_overhead = (
        tick_bytes + easi_ops.MOMENT_TICK_BYTES_PER_STREAM
    ) / tick_bytes
    S_w, P_w = HEALTH_S, 32
    ocfg_w = SMBGDConfig(batch_size=P_w, mu=1e-3, beta=0.9, gamma=0.5)
    key = jax.random.PRNGKey(0)
    Xw = jax.random.normal(jax.random.fold_in(key, 1), (S_w, P_w, m))
    act = jnp.ones((S_w,), jnp.int32)

    def time_fused(mom: bool) -> float:
        bank = SeparatorBank(
            ecfg, ocfg_w, n_streams=S_w, fused=True, moments=mom
        )
        fstep = bank.make_step()
        state0 = bank.init(key)
        Xp = jax.block_until_ready(bank.pad_batch(Xw))
        warm = jax.tree.map(jnp.copy, state0)
        jax.block_until_ready(fstep(warm, Xp, act))  # compile
        return _time_step_loop(
            lambda st, x: fstep(st, x, act), state0, wall_ticks, wall_reps,
            Xp, copy_state=True,
        )

    t_on = time_fused(True)
    t_off = time_fused(False)
    row = {
        "adapt": True,
        "P": P, "m": m, "n": n,
        "jump_tick": jump_tick, "n_ticks": n_ticks,
        "fixed_reconverge_ticks": r_fixed,
        "ctrl_reconverge_ticks": r_ctrl,
        "reconverge_ratio": ratio,
        "reconverge_bar": ADAPT_RECONV_BAR,
        "ctrl_peak_mu_scale": peak,
        "ctrl_final_mu_scale": final_scale,
        "moment_tick_bytes_per_stream": easi_ops.MOMENT_TICK_BYTES_PER_STREAM,
        "moment_hbm_overhead": hbm_overhead,
        "moment_overhead_bar": ADAPT_OVERHEAD_BAR,
        "fused_moments_tick_s": t_on,
        "fused_nomoments_tick_s": t_off,
        "moments_wall_overhead": t_on / t_off,
    }
    fmt = lambda v: f"{v}t" if v is not None else f">{horizon}t"
    print(
        f"adapt,jump@{jump_tick}: reconverge fixed-boost {fmt(r_fixed)} vs "
        f"moment-scaled {fmt(r_ctrl)} → {ratio:.2f}x fewer ticks "
        f"(μ 1.0 → {peak:.2f} peak → {final_scale:.2f} annealed); telemetry "
        f"hbm +{easi_ops.MOMENT_TICK_BYTES_PER_STREAM}B/stream "
        f"({hbm_overhead:.4f}x of {tick_bytes}B/tick), fused wall "
        f"{t_on*1e6:.1f}us vs {t_off*1e6:.1f}us off "
        f"({row['moments_wall_overhead']:.3f}x)"
    )
    return row


def adapt_gate(row: Dict[str, float], hbm_overhead: float | None = None) -> int:
    """Exit code for the adaptive-μ acceptance bars: the telemetry's analytic
    HBM overhead ≤ ``ADAPT_OVERHEAD_BAR`` and the controller's re-convergence
    win ≥ ``ADAPT_RECONV_BAR`` x the fixed boost.  ``hbm_overhead`` overrides
    the row's recorded value (the smoke gate recomputes it from the CURRENT
    layout code, so a checked-in row can't hide regressed accounting)."""
    rc = 0
    for k in ("reconverge_ratio", "moment_hbm_overhead"):
        if k not in row or row[k] is None:
            print(f"adapt: FAIL — row lacks {k!r}; regenerate the artifact "
                  f"with `... --quick ... --adapt`")
            return 1
    hbm = row["moment_hbm_overhead"] if hbm_overhead is None else hbm_overhead
    if hbm > ADAPT_OVERHEAD_BAR:
        print(
            f"adapt: FAIL — moment telemetry adds {hbm:.4f}x HBM traffic "
            f"(> {ADAPT_OVERHEAD_BAR}x): the kurtosis fold must stay in the "
            f"existing in-register reduction pass, not an extra pass over "
            f"X/Y/state"
        )
        rc = 1
    else:
        print(f"adapt: hbm overhead {hbm:.4f}x ≤ {ADAPT_OVERHEAD_BAR}x ok")
    ratio = row["reconverge_ratio"]
    if ratio < ADAPT_RECONV_BAR:
        print(
            f"adapt: FAIL — moment-scaled μ re-converges only {ratio:.2f}x "
            f"faster than the fixed boost (< {ADAPT_RECONV_BAR}x): the "
            f"controller regressed (or the drill scenario drifted)"
        )
        rc = 1
    else:
        print(f"adapt: reconverge ratio {ratio:.2f}x ≥ {ADAPT_RECONV_BAR}x ok")
    return rc


def elastic_bench(
    S_min: int = 2,
    S_max: int = 8,
    n_sessions: int = 8,
    P: int = 32,
    m: int = 4,
    n: int = 2,
    n_blocks: int = 8,
) -> Dict[str, float]:
    """Elastic burst trace: the autoscaled bank vs a fixed-wide baseline.

    ``n_sessions`` sessions with staggered finite feeds (every session
    serves ``n_blocks`` blocks except the last, which serves ``8 *
    n_blocks`` — a burst that collapses to a single long-tail session)
    burst into (a) a width-``S_min`` bank driven by an ``AutoscalePolicy`` capped
    at ``S_max`` with the power-of-two ladder prewarmed, and (b) a bank
    frozen at ``S_max``.  Both serve the identical trace through
    ``run_tick``.  Recorded:

      * steady-tick latency for both (ticks with no resize), and the
        resize-tick latency — the grow/shrink/compact cost the autoscaler
        bills to the tick that resized (gated self-relative at
        ``ELASTIC_RESIZE_FACTOR`` x steady),
      * mean bank utilization (active/width per tick) for both — the
        stranded-capacity story (gated at ``ELASTIC_UTIL_GAIN`` x),
      * the resize counters and history length.
    """
    from repro.data.sources import SourceExhausted, SyntheticSource
    from repro.data.pipeline import MixedSignals
    from repro.serve import AutoscalePolicy
    from repro.serve.engine import SeparationService

    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)

    class FiniteSource:
        def __init__(self, seed, blocks):
            self._src = SyntheticSource(
                MixedSignals(m=m, n=n, batch=P, seed=seed)
            )
            self._left = blocks

        def next_block(self, n_samples):
            if self._left <= 0:
                raise SourceExhausted("trace drained")
            self._left -= 1
            return self._src.next_block(n_samples)

    def drive(svc, widths):
        svc.prewarm(widths)
        for k in range(n_sessions):
            blocks = n_blocks * 8 if k == n_sessions - 1 else n_blocks
            svc.admit(f"s{k}", source=FiniteSource(k, blocks))
        steady, resize, utils = [], [], []
        n_resizes = 0
        while svc.n_active or svc.n_queued:
            before = len(svc.lifecycle["resize_history"])
            t0 = time.perf_counter()
            svc.run_tick()
            dt = time.perf_counter() - t0
            resized = len(svc.lifecycle["resize_history"]) > before
            (resize if resized else steady).append(dt)
            n_resizes += resized
            if svc.n_active:
                utils.append(svc.n_active / svc.bank.n_streams)
            if len(steady) + len(resize) > 100 * n_sessions * n_blocks:
                raise RuntimeError("elastic benchmark failed to drain")
        m_ = svc.metrics
        return {
            "steady_tick_s": sum(steady) / max(len(steady), 1),
            "resize_tick_s": (
                sum(resize) / len(resize) if resize else float("nan")
            ),
            "utilization": sum(utils) / max(len(utils), 1),
            "n_resize_ticks": n_resizes,
            "n_grows": int(m_["n_grows"]),
            "n_shrinks": int(m_["n_shrinks"]),
            "n_compactions": int(m_["n_compactions"]),
        }

    ladder = []
    w = S_min
    while w <= S_max:
        ladder.append(w)
        w *= 2
    pol = AutoscalePolicy(
        max_streams=S_max, min_streams=S_min, cooldown_ticks=2
    )
    # untimed warmup drive: absorbs every process-level one-off (the shared
    # source-generator compile, host-transfer layouts, ...) so the measured
    # runs see steady-state costs — the resize gate judges the RESIZE path,
    # not whatever global compile happens to land on an early tick
    drive(
        SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=S_min),
            seed=0,
            autoscale=pol,
            max_queue=n_sessions,
        ),
        ladder,
    )
    el = drive(
        SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=S_min),
            seed=0,
            autoscale=pol,
            max_queue=n_sessions,
        ),
        ladder,
    )
    fx = drive(
        SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=S_max),
            seed=0,
            max_queue=n_sessions,
        ),
        [S_max],
    )
    row = {
        "elastic": True,
        "S_min": S_min, "S_max": S_max, "P": P, "m": m, "n": n,
        "n_sessions": n_sessions, "n_blocks": n_blocks,
        "elastic_steady_tick_s": el["steady_tick_s"],
        "resize_tick_s": el["resize_tick_s"],
        "fixed_tick_s": fx["steady_tick_s"],
        # self-relative: resize cost in units of this machine's steady tick
        "resize_tick_ratio": el["resize_tick_s"] / el["steady_tick_s"],
        "n_resize_ticks": el["n_resize_ticks"],
        "n_grows": el["n_grows"],
        "n_shrinks": el["n_shrinks"],
        "n_compactions": el["n_compactions"],
        "elastic_utilization": el["utilization"],
        "fixed_utilization": fx["utilization"],
        "utilization_gain": el["utilization"] / fx["utilization"],
        "resize_factor_bar": ELASTIC_RESIZE_FACTOR,
        "util_gain_bar": ELASTIC_UTIL_GAIN,
    }
    print(
        f"elastic,S={S_min}->{S_max},sessions={n_sessions}: steady "
        f"{row['elastic_steady_tick_s']*1e3:.2f}ms/tick (fixed-wide "
        f"{row['fixed_tick_s']*1e3:.2f}ms), resize tick "
        f"{row['resize_tick_s']*1e3:.2f}ms ({row['resize_tick_ratio']:.2f}x "
        f"steady over {row['n_resize_ticks']} resizes: {row['n_grows']}g/"
        f"{row['n_shrinks']}s/{row['n_compactions']}c), utilization "
        f"{row['elastic_utilization']:.2f} vs fixed "
        f"{row['fixed_utilization']:.2f} "
        f"({row['utilization_gain']:.2f}x)"
    )
    return row


def elastic_gate(row: Dict[str, float]) -> int:
    """CI gate over the ``--elastic`` row: the resize tick must stay within
    ``ELASTIC_RESIZE_FACTOR`` x the elastic run's own steady tick (both
    measured on the same machine, so the ratio travels), and the autoscaled
    utilization must beat the fixed-wide baseline's by
    ``ELASTIC_UTIL_GAIN`` x."""
    failed = 0
    ratio = row.get("resize_tick_ratio")
    if ratio is None or ratio != ratio:  # missing or NaN (no resize fired)
        print("elastic: FAIL — row carries no resize_tick_ratio; the trace "
              "never resized (autoscaler mis-wired?)")
        failed = 1
    elif ratio > ELASTIC_RESIZE_FACTOR:
        print(
            f"elastic: FAIL — resize tick {ratio:.2f}x steady "
            f"(> {ELASTIC_RESIZE_FACTOR}x): a resize should be a prefix "
            f"copy + cached-program swap, not a recompile"
        )
        failed = 1
    else:
        print(f"elastic: resize tick {ratio:.2f}x steady ≤ "
              f"{ELASTIC_RESIZE_FACTOR}x ok")
    gain = row.get("utilization_gain", 0.0)
    if gain < ELASTIC_UTIL_GAIN:
        print(
            f"elastic: FAIL — utilization gain {gain:.2f}x < "
            f"{ELASTIC_UTIL_GAIN}x over the fixed-wide baseline: the "
            f"autoscaler is stranding capacity"
        )
        failed = 1
    else:
        print(f"elastic: utilization gain {gain:.2f}x ≥ "
              f"{ELASTIC_UTIL_GAIN}x ok")
    return failed


def record_trace(
    path: Path = DEFAULT_TRACE,
    n_sessions: int = 4,
    n_blocks: int = 64,
    S: int = 4,
    P: int = 16,
    m: int = 4,
    n: int = 2,
) -> Path:
    """(Re)generate the checked-in SLO load trace: ``n_sessions`` synthetic
    mixed-signal feeds (distinct seeds), each captured block-for-block through
    a ``RecordingSource`` tap, with staggered admit events (session ``i``
    arrives at tick ``i``) and EDF deadlines in the metadata.  Deterministic:
    ``SyntheticSource`` blocks are a pure function of the cursor, so the same
    call always writes the same trace."""
    from repro.data.pipeline import MixedSignals
    from repro.data.sources import (
        RecordingSource, SourceExhausted, SyntheticSource, save_recording,
    )

    taps = {}
    events = []
    for i in range(n_sessions):
        sid = f"s{i}"
        tap = RecordingSource(
            SyntheticSource(MixedSignals(m=m, n=n, batch=P, seed=100 + i))
        )
        for _ in range(n_blocks):
            tap.next_block(P)
        tap.exhausted = True  # the trace ends here; replay drains at block k
        taps[sid] = tap
        events.append(
            {
                "action": "admit", "sid": sid, "tick": i, "order": i,
                "deadline": float(n_sessions - i),
            }
        )
    path.parent.mkdir(parents=True, exist_ok=True)
    save_recording(
        path, taps, events=events,
        meta={"S": S, "P": P, "m": m, "n": n, "n_blocks": n_blocks},
    )
    print(f"wrote {path} ({n_sessions} sessions x {n_blocks} blocks of "
          f"({m},{P}))")
    return path


def slo_bench(
    trace_path: Path = DEFAULT_TRACE,
    budget_factor: float = SLO_BUDGET_FACTOR,
    fused: bool = True,
) -> Dict[str, float]:
    """Latency-SLO replay: drive the serving engine through the checked-in
    recorded load twice — a warmup pass (default always-on telemetry) to
    calibrate the deadline budget at ``budget_factor`` x this machine's p50
    time-to-ready, then a measured pass with the budget armed.  The row
    records the time-to-ready tail (p50/p99/p999 over every tick, probe-only
    ticks included) and the deadline miss rate — the paper's throughput story
    restated as "do ticks land on time", which is what a BCI/teleoperation
    deployment actually buys."""
    from repro.data.sources import load_recording
    from repro.serve import SLOPolicy, SeparationService
    from repro.serve.slo import replay

    rec = load_recording(trace_path)
    meta = rec.meta
    S, P, m, n = (int(meta[k]) for k in ("S", "P", "m", "n"))
    ecfg = EASIConfig(n_components=n, n_features=m, mu=2e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)

    def fresh(slo=None):
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=S, fused=fused),
            seed=0, max_queue=len(rec.sources), slo=slo,
        )

    # pass 1: calibrate the budget off this machine's median time-to-ready
    warm = fresh()
    replay(warm, load_recording(trace_path))
    p50_warm = warm.metrics["p50_tick_s_life"]
    budget = budget_factor * p50_warm

    # pass 2: the measured run, budget armed.  One throwaway tick first so
    # the fresh bank's compile lands outside the measured tail (the recorded
    # p99 is steady-state jitter, not XLA compilation).
    svc = fresh(slo=SLOPolicy(deadline_budget_s=budget))
    svc.admit("__warm__")
    svc.step({"__warm__": jnp.zeros((P, m), jnp.float32)})
    svc.evict("__warm__")
    svc._reset_slo()
    replay(svc, rec)
    mtr = svc.metrics
    timed = mtr["n_timed_ticks"] + mtr["n_empty_ticks"]
    miss_rate = mtr["n_deadline_misses"] / timed if timed else float("nan")
    row = {
        "slo": True,
        "trace": trace_path.name,
        "S": S, "P": P, "m": m, "n": n, "fused": fused,
        "n_ticks": mtr["n_ticks"],
        "n_empty_ticks": mtr["n_empty_ticks"],
        "budget_factor": budget_factor,
        "budget_s": budget,
        "p50_tick_s": mtr["p50_tick_s_life"],
        "p99_tick_s": mtr["p99_tick_s_life"],
        "p999_tick_s": mtr["p999_tick_s_life"],
        "n_deadline_misses": mtr["n_deadline_misses"],
        "miss_rate": miss_rate,
    }
    print(
        f"slo,{trace_path.name}: p50 {row['p50_tick_s']*1e3:.2f}ms "
        f"p99 {row['p99_tick_s']*1e3:.2f}ms p999 {row['p999_tick_s']*1e3:.2f}ms "
        f"budget {budget*1e3:.2f}ms ({budget_factor}x p50) -> "
        f"{int(row['n_deadline_misses'])} misses / {int(timed)} ticks "
        f"({miss_rate:.3f})"
    )
    return row


def slo_gate(baseline_rows: List[Dict], trace_path: Path = DEFAULT_TRACE) -> int:
    """CI gate for the SLO replay: the checked-in artifact must carry the
    ``--slo`` row WITH its p99 column, and a fresh replay of the same trace
    (budget re-calibrated on this runner, so machine speed cancels) must not
    regress the miss rate more than ``SLO_MISS_REGRESSION``x — with an
    absolute ``SLO_MISS_FLOOR`` so a handful of misses over a short trace
    can't flap the gate."""
    base = next((r for r in baseline_rows if r.get("slo")), None)
    if base is None:
        print("slo: FAIL — no --slo row in the checked-in artifact; "
              "regenerate with `... --quick --churn --drift --probe "
              "--health --slo`")
        return 1
    if "p99_tick_s" not in base:
        print("slo: FAIL — checked-in --slo row lacks p99_tick_s; "
              "regenerate the artifact")
        return 1
    if not trace_path.exists():
        print(f"slo: FAIL — trace {trace_path} missing; regenerate with "
              f"--record-trace")
        return 1
    fresh = slo_bench(
        trace_path, budget_factor=float(base.get("budget_factor",
                                                 SLO_BUDGET_FACTOR))
    )
    ceiling = max(SLO_MISS_REGRESSION * base["miss_rate"], SLO_MISS_FLOOR)
    if fresh["miss_rate"] > ceiling:
        print(
            f"slo: FAIL — miss rate {fresh['miss_rate']:.3f} exceeds "
            f"{ceiling:.3f} (baseline {base['miss_rate']:.3f} x "
            f"{SLO_MISS_REGRESSION}, floor {SLO_MISS_FLOOR}): the tick tail "
            f"spread regressed, not just the machine"
        )
        return 1
    print(f"slo: miss rate {fresh['miss_rate']:.3f} ≤ {ceiling:.3f} ok")
    return 0


def smoke_check(baseline_path: Path) -> int:
    """CI regression gate: re-measure S=SMOKE_S quickly and fail (exit 1) when
    any tracked per-tick time is > SMOKE_FACTOR x the checked-in number."""
    baseline_rows = json.loads(baseline_path.read_text())
    # only default-config sweep rows qualify as a baseline: autotune rows
    # carry just block_p/fused_tick_s, and legacy --pallas rows measured a
    # different engine in the bank column
    base = next(
        (
            r
            for r in baseline_rows
            if r.get("S") == SMOKE_S
            and "bank_tick_s" in r
            and not r.get("use_pallas")
        ),
        None,
    )
    if base is None:
        print(
            f"smoke: FAIL — no default-config S={SMOKE_S} row in "
            f"{baseline_path}; regenerate it with "
            f"`python benchmarks/stream_throughput.py`"
        )
        return 1
    # same n_ticks as the checked-in sweep: per-tick numbers amortize the
    # Python loop overhead identically on both sides of the ratio
    fresh = bench_streams(SMOKE_S, n_ticks=int(base.get("n_ticks", 50)), reps=2)
    failed = False
    for k in SMOKE_KEYS:
        if k not in base:
            print(f"smoke: baseline missing {k!r}; regenerate {baseline_path}")
            failed = True
            continue
        ratio = fresh[k] / base[k]
        verdict = "FAIL" if ratio > SMOKE_FACTOR else "ok"
        if ratio > SMOKE_FACTOR:
            failed = True
        print(f"smoke: {k} {fresh[k]*1e6:.1f}us vs baseline "
              f"{base[k]*1e6:.1f}us ({ratio:.2f}x) {verdict}")
    # the acceptance bar rides along: the megakernel must not lose to the
    # PR-1 pallas path it replaces (0.9 leaves room for shared-runner noise;
    # the checked-in sweep records ≥ 1.15x on a quiet machine)
    if fresh["fused_over_bank_pallas"] < 0.9:
        print(f"smoke: FAIL fused slower than PR-1 pallas path "
              f"({fresh['fused_over_bank_pallas']:.2f}x)")
        failed = True
    # S=1 crossover gate: the single-stream fused/pr1 loss is a KNOWN,
    # documented interpret-mode constant (see S1_CROSSOVER_FLOOR) — gate it
    # against collapsing further, which would mean new per-launch overhead
    # snuck into the megakernel path.
    s1_base = next(
        (
            r
            for r in baseline_rows
            if r.get("S") == 1
            and "bank_tick_s" in r
            and not r.get("use_pallas")
        ),
        None,
    )
    if s1_base is not None:
        fresh1 = bench_streams(1, n_ticks=int(s1_base.get("n_ticks", 50)), reps=2)
        ratio1 = fresh1["fused_over_bank_pallas"]
        verdict = "FAIL" if ratio1 < S1_CROSSOVER_FLOOR else "ok"
        if ratio1 < S1_CROSSOVER_FLOOR:
            failed = True
        print(
            f"smoke: S=1 fused/pr1 crossover {ratio1:.2f}x "
            f"(documented floor {S1_CROSSOVER_FLOOR}, checked-in "
            f"{s1_base.get('fused_over_bank_pallas', float('nan')):.2f}x) {verdict}"
        )
    # batched-probe gate: re-measure the parked-probe tick at the checked-in
    # population and fail on a >2x regression of the batched engine (or on
    # the launch economics collapsing below the 5x acceptance bar)
    probe_base = next((r for r in baseline_rows if r.get("probe")), None)
    if probe_base is not None:
        fresh_probe = probe_bench(
            n_parked=int(probe_base["n_parked"]),
            P=int(probe_base["P"]),
            m=int(probe_base["m"]),
            n=int(probe_base["n"]),
            probe_batch=int(probe_base["probe_batch"]),
            n_probe_ticks=3,
            reps=2,
        )
        ratio = fresh_probe["batched_tick_s"] / probe_base["batched_tick_s"]
        verdict = "FAIL" if ratio > SMOKE_FACTOR else "ok"
        if ratio > SMOKE_FACTOR:
            failed = True
        print(
            f"smoke: batched_tick_s {fresh_probe['batched_tick_s']*1e3:.2f}ms "
            f"vs baseline {probe_base['batched_tick_s']*1e3:.2f}ms "
            f"({ratio:.2f}x) {verdict}"
        )
        if fresh_probe["probe_launch_ratio"] < 5.0:
            print(
                f"smoke: FAIL batched probe saves only "
                f"{fresh_probe['probe_launch_ratio']:.1f}x launches (< 5x)"
            )
            failed = True
    # health-overhead gate: recheck the analytic HBM bar against the CURRENT
    # layout code and the wall ratio against the interpreter ceiling (1.2x
    # slack on the ceiling absorbs shared-runner noise in the ratio of two
    # small numbers; a structural regression still lands far above it)
    health_base = next((r for r in baseline_rows if r.get("health")), None)
    if health_base is not None:
        fresh_health = health_bench(
            S=int(health_base["S"]),
            P=int(health_base["P"]),
            m=int(health_base["m"]),
            n=int(health_base["n"]),
            n_ticks=20,
            reps=2,
        )
        if health_gate(fresh_health, slack=1.2):
            failed = True
    # latency-SLO gate: the --slo row must exist with its p99 column, and a
    # budget-recalibrated replay of the checked-in trace must not blow up
    # the miss rate (see slo_gate)
    if slo_gate(baseline_rows):
        failed = True
    # adaptive-μ gate: the --adapt row must exist, the kurtosis telemetry's
    # analytic HBM overhead recomputed off the CURRENT layout code must hold
    # the ≤5% bar, and the checked-in re-convergence win must hold the 1.3x
    # bar (the CI quick bench re-measures it fresh via `--quick --adapt`;
    # smoke gates the artifact so a quietly-regressed row can't linger)
    adapt_base = next((r for r in baseline_rows if r.get("adapt")), None)
    if adapt_base is None:
        print(
            "smoke: FAIL — no adaptive-μ row in the artifact; regenerate "
            "with `python benchmarks/stream_throughput.py --quick ... --adapt`"
        )
        failed = True
    else:
        lay = easi_ops.bank_layout(
            int(adapt_base["n"]), int(adapt_base["m"]), int(adapt_base["P"])
        )
        hbm_now = (
            lay.tick_hbm_bytes_per_stream
            + easi_ops.MOMENT_TICK_BYTES_PER_STREAM
        ) / lay.tick_hbm_bytes_per_stream
        if adapt_gate(adapt_base, hbm_overhead=hbm_now):
            failed = True
    # elastic gate: the --elastic row must exist, its (self-relative,
    # machine-independent) resize-tick ratio must hold the 5x bar, and the
    # recorded utilization gain over the fixed-wide baseline must hold 1.5x
    elastic_base = next((r for r in baseline_rows if r.get("elastic")), None)
    if elastic_base is None:
        print(
            "smoke: FAIL — no --elastic row in the artifact; regenerate "
            "with `python benchmarks/stream_throughput.py --quick ... "
            "--elastic`"
        )
        failed = True
    elif elastic_gate(elastic_base):
        failed = True
    return 1 if failed else 0


def autotune_smoke(S: int = SMOKE_S, P: int = 32, m: int = 4, n: int = 2) -> int:
    """CI gate for the persisted autotune cache (exit 1 on failure):

      * ``AUTOTUNE.json`` must hold an entry for the swept ``S=8`` key on
        THIS backend — a missing/stale key means the sweep wasn't re-run
        after a geometry-affecting change,
      * a default ``SeparatorBank`` must actually resolve that geometry,
      * the persistent bytes/session implied by the CURRENT layout code must
        not exceed the recorded numbers by >10% (the capacity number is the
        point of the overhaul; silent growth fails CI),
      * the recorded bf16 reduction must hold the ≥1.5x acceptance bar.
    """
    ckey = autotune_lib.cache_key(S, P, m, n)
    path = autotune_lib.cache_path()
    entry = autotune_lib.lookup(S, P, m, n)
    if entry is None:
        print(
            f"autotune-smoke: FAIL — {path} has no entry for {ckey!r}; "
            f"regenerate with `python benchmarks/stream_throughput.py --autotune`"
        )
        return 1
    failed = False
    for field in ("block_p", "block_s", "prefetch",
                  "persistent_bytes_per_session",
                  "bf16_persistent_bytes_per_session"):
        if field not in entry:
            print(f"autotune-smoke: FAIL — {ckey!r} missing {field!r} "
                  f"(stale schema); re-run --autotune")
            failed = True
    if failed:
        return 1
    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
    bank = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True)  # autotune=True
    resolved = (bank.block_p, bank.block_s, bool(bank.prefetch))
    recorded = (
        int(entry["block_p"]), int(entry["block_s"]), bool(entry["prefetch"])
    )
    if resolved != recorded:
        print(
            f"autotune-smoke: FAIL — default bank resolved "
            f"(block_p, block_s, prefetch)={resolved} but {ckey!r} records "
            f"{recorded}; cache resolution is broken or the key is stale"
        )
        failed = True
    lay_f32 = easi_ops.bank_layout(n, m, P, block_p=int(entry["block_p"]))
    lay_bf16 = easi_ops.bank_layout(
        n, m, P, block_p=int(entry["block_p"]), dtype_policy="bf16"
    )
    for field, lay in (
        ("persistent_bytes_per_session", lay_f32),
        ("bf16_persistent_bytes_per_session", lay_bf16),
    ):
        now = lay.persistent_bytes_per_session
        rec = int(entry[field])
        verdict = "FAIL" if now > rec * PERSISTENT_BYTES_SLACK else "ok"
        if now > rec * PERSISTENT_BYTES_SLACK:
            failed = True
        print(f"autotune-smoke: {field} now={now}B recorded={rec}B {verdict}")
    reduction = (
        lay_f32.persistent_bytes_per_session
        / lay_bf16.persistent_bytes_per_session
    )
    if reduction < BF16_REDUCTION_BAR:
        print(
            f"autotune-smoke: FAIL — bf16 persistent-byte reduction "
            f"{reduction:.2f}x below the {BF16_REDUCTION_BAR}x bar"
        )
        failed = True
    else:
        print(f"autotune-smoke: bf16 reduction {reduction:.2f}x ok")
    return 1 if failed else 0


def run(
    quick: bool = False,
    out: str | None = None,
    autotune: bool = False,
    churn: bool = False,
    drift: bool = False,
    probe: bool = False,
    health: bool = False,
    slo: bool = False,
    adapt: bool = False,
    elastic: bool = False,
) -> List[Dict[str, float]]:
    """Sweep S; write the JSON artifact when ``out`` is given."""
    sweep = (1, 8, 64) if quick else (1, 8, 64, 512)
    reps = 2 if quick else 3
    ticks = 20 if quick else 50
    rows = [bench_streams(S, reps=reps, n_ticks=ticks) for S in sweep]
    if autotune:
        for S in (8, 64):
            rows.extend(autotune_bank(S, reps=reps, n_ticks=ticks))
    if churn:
        rows.append(
            churn_bench(n_sessions=16 if quick else 32,
                        converge_ticks=10 if quick else 20,
                        sweep_every=30 if quick else 60)
        )
    if drift:
        rows.append(
            drift_bench(S=2 if quick else 4,
                        jump_tick=250, n_ticks=450 if quick else 600)
        )
    if probe:
        rows.append(probe_bench(n_probe_ticks=3 if quick else 5))
    if health:
        row = health_bench(n_ticks=20 if quick else 50, reps=reps)
        health_gate(row)  # report against the bar; artifact records the ratio
        rows.append(row)
    if slo:
        rows.append(slo_bench())
    if adapt:
        row = adapt_bench(n_ticks=650)
        adapt_gate(row)  # report against the bars; artifact records the row
        rows.append(row)
    if elastic:
        row = elastic_bench(n_blocks=5 if quick else 8)
        elastic_gate(row)  # report against the bars; artifact records the row
        rows.append(row)
    if out:
        Path(out).write_text(json.dumps(rows, indent=2) + "\n")
        print(f"wrote {out}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="S ≤ 64, fewer reps (CI)")
    ap.add_argument("--autotune", action="store_true",
                    help="2-D (block_p, block_s) x prefetch sweep at S=8,64; "
                         "persists winners to AUTOTUNE.json")
    ap.add_argument("--autotune-smoke", action="store_true",
                    help="CI gate: AUTOTUNE.json fresh for the S=8 key and no "
                         ">10%% persistent bytes/session regression (no write)")
    ap.add_argument("--smoke", action="store_true",
                    help="regression gate vs the checked-in result file (no write)")
    ap.add_argument("--churn", action="store_true",
                    help="lifecycle churn scenario: auto-eviction vs periodic sweep")
    ap.add_argument("--drift", action="store_true",
                    help="drift scenario: rotating mixing, watchdog on vs off")
    ap.add_argument("--probe", action="store_true",
                    help="parked-session probe scenario: batched vs sequential")
    ap.add_argument("--health", action="store_true",
                    help="fault-containment overhead: health_checks on vs off "
                         f"at S=64; exits 1 past the {HEALTH_OVERHEAD_BAR}x "
                         "HBM bar or the interpreter wall ceiling "
                         "(no write when standalone)")
    ap.add_argument("--slo", action="store_true",
                    help="latency-SLO replay of the checked-in trace: "
                         "p50/p99/p999 time-to-ready + deadline miss rate "
                         f"at a {SLO_BUDGET_FACTOR}x-p50 budget")
    ap.add_argument("--adapt", action="store_true",
                    help="adaptive-μ scenario: ticks-to-reconverge after an "
                         "abrupt rotation, fixed drift boost vs the "
                         "moment-scaled controller, plus the kurtosis "
                         f"telemetry's HBM cost; exits 1 past the "
                         f"{ADAPT_OVERHEAD_BAR}x HBM bar or below the "
                         f"{ADAPT_RECONV_BAR}x re-convergence win "
                         "(no write when standalone)")
    ap.add_argument("--elastic", action="store_true",
                    help="elastic burst trace: autoscaled bank vs fixed-wide "
                         "baseline — steady/resize tick latency + mean "
                         f"utilization; exits 1 past the "
                         f"{ELASTIC_RESIZE_FACTOR}x resize-tick bar or "
                         f"below the {ELASTIC_UTIL_GAIN}x utilization gain "
                         "(no write when standalone)")
    ap.add_argument("--record-trace", action="store_true",
                    help="regenerate the checked-in SLO trace "
                         "(benchmarks/traces/slo_small.npz) and exit")
    ap.add_argument(
        "--out", default=str(DEFAULT_OUT), help="result file (JSON rows)"
    )
    args = ap.parse_args()
    if args.record_trace:
        record_trace()
        sys.exit(0)
    if args.autotune_smoke:
        sys.exit(autotune_smoke())
    if args.smoke:
        sys.exit(smoke_check(Path(args.out)))
    if (args.churn or args.drift or args.probe or args.health or args.slo
            or args.adapt or args.elastic) and not (args.quick or args.autotune):
        # standalone scenario run: print only, leave the sweep artifact alone
        rc = 0
        if args.churn:
            churn_bench()
        if args.drift:
            drift_bench()
        if args.probe:
            probe_bench()
        if args.health:
            rc = health_gate(health_bench())
        if args.slo:
            slo_bench()
        if args.adapt:
            rc = adapt_gate(adapt_bench()) or rc
        if args.elastic:
            rc = elastic_gate(elastic_bench()) or rc
        sys.exit(rc)
    run(quick=args.quick, out=args.out, autotune=args.autotune,
        churn=args.churn, drift=args.drift, probe=args.probe,
        health=args.health, slo=args.slo, adapt=args.adapt,
        elastic=args.elastic)


if __name__ == "__main__":
    main()
