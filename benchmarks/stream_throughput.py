"""Multi-stream scaling: SeparatorBank vs a Python loop over S separators.

The paper's Table I measured one datapath's throughput; this measures the
*rack*.  Scenario = streaming deployment (what ``serve.SeparationService``
does): every tick each live session delivers a ``(P, m)`` mini-batch, and the
engine must advance all S sessions before the next tick.

  * ``bank`` — ONE fused ``SeparatorBank.step`` per tick (leading stream axis;
    optionally the batched (streams, P-tiles) Pallas kernel),
  * ``loop`` — the naive engine: a Python loop dispatching S jitted
    single-stream ``smbgd_batched_step`` calls per tick.

Per-tick wall-clock of the bank grows sublinearly in S (one dispatch, one
compiled program, vectorized math) while the loop pays per-session dispatch
every tick.  samples/sec vs S goes to ``BENCH_streams.json`` so the perf
trajectory is recorded run over run.

    PYTHONPATH=src python benchmarks/stream_throughput.py [--quick] [--pallas]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig
from repro.stream import SeparatorBank


def bench_streams(
    S: int,
    P: int = 32,
    m: int = 4,
    n: int = 2,
    n_ticks: int = 50,
    use_pallas: bool = False,
    reps: int = 3,
) -> Dict[str, float]:
    ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(jax.random.fold_in(key, 1), (S, P, m))

    # fused bank: one jitted step advances all S sessions
    bank = SeparatorBank(ecfg, ocfg, n_streams=S, use_pallas=use_pallas)
    bank_step = jax.jit(bank.step)
    state0 = bank.init(key)
    jax.block_until_ready(bank_step(state0, X))  # compile
    t_bank = float("inf")
    for _ in range(reps):
        st = state0
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            st, _ = bank_step(st, X)
        jax.block_until_ready(st)
        t_bank = min(t_bank, (time.perf_counter() - t0) / n_ticks)

    # naive engine: Python loop of S single-stream jitted steps per tick
    # (the jit cache is shared across sessions — the loop pays dispatch,
    # not recompilation)
    single_step = jax.jit(
        lambda st, x: smbgd_lib.smbgd_batched_step(st, x, ecfg, ocfg)
    )
    states0 = [smbgd_lib.init_state(ecfg, k) for k in jax.random.split(key, S)]
    jax.block_until_ready(single_step(states0[0], X[0]))  # compile
    t_loop = float("inf")
    for _ in range(reps):
        states = list(states0)
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            states = [single_step(states[s], X[s])[0] for s in range(S)]
        jax.block_until_ready(states)  # ALL streams — async backends
        t_loop = min(t_loop, (time.perf_counter() - t0) / n_ticks)

    samples_per_tick = S * P
    row = {
        "S": S, "P": P, "m": m, "n": n, "n_ticks": n_ticks,
        "use_pallas": use_pallas,
        "bank_tick_s": t_bank,
        "loop_tick_s": t_loop,
        "bank_samples_per_s": samples_per_tick / t_bank,
        "loop_samples_per_s": samples_per_tick / t_loop,
        "bank_over_loop": t_loop / t_bank,
    }
    print(
        f"streams,S={S},bank={row['bank_samples_per_s']:.3g}sps"
        f",loop={row['loop_samples_per_s']:.3g}sps"
        f",bank/loop={row['bank_over_loop']:.1f}x"
    )
    return row


def run(
    quick: bool = False,
    use_pallas: bool = False,
    out: str | None = None,
) -> List[Dict[str, float]]:
    """Sweep S; write the JSON artifact when ``out`` is given."""
    sweep = (1, 8, 64) if quick else (1, 8, 64, 512)
    reps = 2 if quick else 3
    ticks = 20 if quick else 50
    rows = [
        bench_streams(S, use_pallas=use_pallas, reps=reps, n_ticks=ticks)
        for S in sweep
    ]
    if out:
        Path(out).write_text(json.dumps(rows, indent=2) + "\n")
        print(f"wrote {out}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="S ≤ 64, fewer reps (CI)")
    ap.add_argument("--pallas", action="store_true", help="fused Pallas bank kernel")
    ap.add_argument(
        "--out", default="BENCH_streams.json", help="result file (JSON rows)"
    )
    args = ap.parse_args()
    run(quick=args.quick, use_pallas=args.pallas, out=args.out)


if __name__ == "__main__":
    main()
