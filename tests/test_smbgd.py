"""SMBGD (the paper's Eq. 1): sequential/batched equivalence, momentum gating,
and the convergence-improvement claim (§V.A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import easi as easi_lib
from repro.core import metrics
from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig
from repro.data import signals


def _cfgs(P=8, mu=2e-3, beta=0.9, gamma=0.5, n=2, m=4):
    return (
        EASIConfig(n_components=n, n_features=m, mu=mu),
        SMBGDConfig(batch_size=P, mu=mu, beta=beta, gamma=gamma),
    )


class TestEq1Equivalence:
    """The TPU-native closed form must reproduce the paper's sequential
    recurrence exactly (DESIGN.md §2) — the central correctness claim."""

    @given(
        P=st.sampled_from([1, 2, 4, 8, 16]),
        beta=st.floats(0.0, 1.0),
        gamma=st.floats(0.0, 0.99),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_sequential_equals_batched(self, P, beta, gamma, seed):
        ecfg, ocfg = _cfgs(P=P, beta=beta, gamma=gamma)
        key = jax.random.PRNGKey(seed)
        X = jax.random.normal(key, (4 * P, 4))
        st0 = smbgd_lib.init_state(ecfg, jax.random.fold_in(key, 1))
        st_seq, Y_seq = smbgd_lib.smbgd_epoch_sequential(st0, X, ecfg, ocfg)
        st_bat, Y_bat = smbgd_lib.smbgd_epoch(st0, X, ecfg, ocfg)
        np.testing.assert_allclose(
            np.asarray(st_seq.B), np.asarray(st_bat.B), rtol=2e-4, atol=2e-5
        )
        np.testing.assert_allclose(
            np.asarray(st_seq.H_hat), np.asarray(st_bat.H_hat), rtol=2e-4, atol=2e-5
        )
        np.testing.assert_allclose(
            np.asarray(Y_seq), np.asarray(Y_bat), rtol=1e-4, atol=1e-5
        )

    def test_pallas_kernel_path_matches(self):
        ecfg, ocfg = _cfgs(P=16)
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (64, 4))
        st0 = smbgd_lib.init_state(ecfg, jax.random.PRNGKey(1))
        st_ref, _ = smbgd_lib.smbgd_epoch(st0, X, ecfg, ocfg, use_pallas=False)
        st_pal, _ = smbgd_lib.smbgd_epoch(st0, X, ecfg, ocfg, use_pallas=True)
        np.testing.assert_allclose(
            np.asarray(st_ref.B), np.asarray(st_pal.B), rtol=1e-5, atol=1e-6
        )

    def test_effective_momentum_formula(self):
        ocfg = SMBGDConfig(batch_size=8, mu=1e-3, beta=0.9, gamma=0.5)
        assert ocfg.effective_momentum == pytest.approx(0.5 * 0.9**7)
        w = ocfg.within_batch_weights()
        assert w.shape == (8,)
        # most recent sample (p = P-1) gets weight μ, earliest gets μβ^{P-1}
        assert float(w[-1]) == pytest.approx(1e-3)
        assert float(w[0]) == pytest.approx(1e-3 * 0.9**7)

    def test_first_batch_gamma_gated_off(self):
        """Paper: 'for the first mini-batch, γ is set to zero' — a restarted
        stream with stale Ĥ must ignore it at k=0."""
        ecfg, ocfg = _cfgs(P=4, gamma=0.9)
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (4, 4))
        st0 = smbgd_lib.init_state(ecfg, jax.random.PRNGKey(1))
        poisoned = st0._replace(H_hat=jnp.full((2, 2), 1e3))
        st_a, _ = smbgd_lib.smbgd_batched_step(st0, X, ecfg, ocfg)
        st_b, _ = smbgd_lib.smbgd_batched_step(poisoned, X, ecfg, ocfg)
        np.testing.assert_allclose(np.asarray(st_a.B), np.asarray(st_b.B), atol=1e-6)

    def test_p1_beta1_reduces_to_momentum_sgd(self):
        """Eq. 1 with P=1 is heavy-ball EASI: Ĥ_k = γĤ_{k-1} + μH_k."""
        ecfg, _ = _cfgs()
        ocfg = SMBGDConfig(batch_size=1, mu=1e-3, beta=1.0, gamma=0.7)
        key = jax.random.PRNGKey(2)
        X = jax.random.normal(key, (6, 4))
        st = smbgd_lib.init_state(ecfg, jax.random.PRNGKey(3))
        H_manual = jnp.zeros((2, 2))
        B_manual = st.B
        for k in range(6):
            y = B_manual @ X[k]
            H = easi_lib.relative_gradient(y, ecfg.g)
            g = 0.0 if k == 0 else 0.7
            H_manual = g * H_manual + 1e-3 * H
            B_manual = B_manual + H_manual @ B_manual
            st, _ = smbgd_lib.smbgd_batched_step(st, X[k : k + 1], ecfg, ocfg)
        np.testing.assert_allclose(np.asarray(st.B), np.asarray(B_manual), rtol=1e-5, atol=1e-6)


class TestConvergenceImprovement:
    def test_smbgd_converges_on_paper_problem(self):
        key = jax.random.PRNGKey(11)
        A, S, X = signals.make_problem(key, m=4, n=2, T=40_000)
        ecfg, ocfg = _cfgs(P=8, mu=2e-3, beta=0.9, gamma=0.5)
        st = smbgd_lib.init_state(ecfg, jax.random.PRNGKey(12))
        st, _ = smbgd_lib.smbgd_epoch(st, X, ecfg, ocfg)
        pi = metrics.amari_index(metrics.global_system(st.B, A))
        assert float(pi) < 0.12

    def test_iterations_to_converge_helper(self):
        trace = jnp.array([0.5, 0.3, 0.2, 0.04, 0.03, 0.02])
        assert int(metrics.iterations_to_converge(trace, 0.05)) == 3
        assert int(metrics.iterations_to_converge(trace, 0.001)) == 6  # never
