"""Shared test configuration: reproducible hypothesis profiles.

The ``property`` marker's sweeps (differential probe equivalence, scheduler
invariants, megakernel-vs-oracle) must be reproducible in CI: the
``full-matrix`` job pins ``HYPOTHESIS_PROFILE=ci``, which derandomizes the
generator (a fixed seed, so a red run replays locally) and disables the
per-example deadline (shared runners jitter enough to trip it spuriously).
Local runs default to the ``dev`` profile: random exploration, no deadline.
Without hypothesis installed, ``tests/_hypothesis_compat.py`` stands in with
seeded example sweeps and this file is a no-op.
"""
import os

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci",
        derandomize=True,  # the pinned seed: failures replay exactly
        deadline=None,
        max_examples=25,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ModuleNotFoundError:  # bare container: _hypothesis_compat stands in
    pass
