"""Optimizer library: SMBGD-general semantics + baselines + microbatch fold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adamw,
    apply_updates,
    chain,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    momentum,
    sgd,
    smbgd,
    warmup_cosine,
)
from repro.train.microbatch import smbgd_accumulate_grads, split_batch


def _quad_problem():
    """min ||x - t||²: every sane optimizer must converge."""
    t = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["x"] - t) ** 2)

    return loss, {"x": jnp.zeros(3)}, t


class TestSMBGDGeneral:
    def test_p1_equals_heavyball(self):
        """SMBGD with P=1 must match a hand-rolled heavy-ball loop (with the
        paper's first-step γ gate)."""
        loss, params, _ = _quad_problem()
        tx = smbgd(learning_rate=0.1, gamma=0.5, beta=1.0, microbatches=1)
        state = tx.init(params)
        p = params
        h = jnp.zeros(3)
        for k in range(5):
            g = jax.grad(loss)(p)["x"]
            gam = 0.0 if k == 0 else 0.5
            h = gam * h + 0.1 * g
            upd, state = tx.update(jax.grad(loss)(p), state, p)
            p = apply_updates(p, upd)
            np.testing.assert_allclose(np.asarray(upd["x"]), np.asarray(-h), rtol=1e-6)

    def test_converges_quadratic(self):
        loss, params, t = _quad_problem()
        tx = smbgd(learning_rate=0.05, gamma=0.8)
        state = tx.init(params)
        p = params
        for _ in range(200):
            upd, state = tx.update(jax.grad(loss)(p), state, p)
            p = apply_updates(p, upd)
        np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(t), atol=1e-3)

    def test_one_slot_state(self):
        """SMBGD memory claim: exactly one param-shaped slot (AdamW has two)."""
        _, params, _ = _quad_problem()
        s_smbgd = smbgd(0.1).init(params)
        s_adamw = adamw(0.1).init(params)
        n_big = lambda s: sum(1 for l in jax.tree.leaves(s) if l.ndim > 0)
        assert n_big(s_smbgd) == 1
        assert n_big(s_adamw) == 2


class TestBaselines:
    @pytest.mark.parametrize("name", ["sgd", "momentum", "adamw", "adafactor"])
    def test_converges(self, name):
        loss, params, t = _quad_problem()
        kw = {"weight_decay": 0.0} if name == "adamw" else {}
        tx = make_optimizer(name, 0.05, **kw)
        state = tx.init(params)
        p = params
        for _ in range(400):
            upd, state = tx.update(jax.grad(loss)(p), state, p)
            p = apply_updates(p, upd)
        np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(t), atol=2e-2)

    def test_clip_by_global_norm(self):
        tx = clip_by_global_norm(1.0)
        g = {"a": jnp.array([3.0, 4.0])}  # norm 5
        clipped, _ = tx.update(g, tx.init(g))
        np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-6)

    def test_chain_and_schedule(self):
        sched = warmup_cosine(peak_lr=1.0, warmup=10, total=100)
        assert float(sched(jnp.array(0))) == 0.0
        assert float(sched(jnp.array(10))) == pytest.approx(1.0)
        assert float(sched(jnp.array(100))) == pytest.approx(0.0, abs=1e-6)
        tx = chain(clip_by_global_norm(10.0), sgd(0.1))
        g = {"a": jnp.array([1.0])}
        upd, _ = tx.update(g, tx.init(g))
        np.testing.assert_allclose(np.asarray(upd["a"]), [-0.1], rtol=1e-6)


class TestMicrobatchFold:
    def test_beta1_equals_mean_gradient(self):
        """β=1 microbatch fold == full-batch gradient (exactly, for a loss
        that is a mean over examples)."""
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (16, 4))
        y = jax.random.normal(jax.random.fold_in(key, 1), (16,))
        params = {"w": jnp.zeros(4)}

        def loss_fn(p, batch):
            pred = batch["x"] @ p["w"]
            return jnp.mean((pred - batch["y"]) ** 2), None

        g_full = jax.grad(lambda p: loss_fn(p, {"x": X, "y": y})[0])(params)
        g_mb, loss = smbgd_accumulate_grads(
            loss_fn, params, {"x": X, "y": y}, microbatches=4, beta=1.0
        )
        np.testing.assert_allclose(
            np.asarray(g_mb["w"]), np.asarray(g_full["w"]), rtol=1e-5, atol=1e-6
        )

    def test_beta_weights_recent_microbatches(self):
        """β<1: last microbatch dominates the fold (Eq. 1 ordering)."""
        params = {"w": jnp.zeros(1)}

        def loss_fn(p, batch):
            # per-microbatch constant gradient = batch value
            return jnp.mean(p["w"] * batch), None

        batch = jnp.array([[1.0], [0.0], [0.0], [10.0]])  # 4 microbatches
        g, _ = smbgd_accumulate_grads(loss_fn, params, batch, 4, beta=0.5)
        # fold: Σ β^{P-1-p} g_p / Σ β^i = (0.125·1 + 10) / 1.875
        np.testing.assert_allclose(
            float(g["w"][0]), (0.5**3 * 1.0 + 10.0) / (1 + 0.5 + 0.25 + 0.125),
            rtol=1e-5,
        )

    def test_split_batch_shapes(self):
        b = {"tokens": jnp.zeros((8, 5)), "extra": jnp.zeros((8, 2, 3))}
        s = split_batch(b, 4)
        assert s["tokens"].shape == (4, 2, 5)
        assert s["extra"].shape == (4, 2, 2, 3)
