"""Elastic separator banks: grow/shrink/compact + the autoscaler, end to end.

Three layers, matching the machinery:

  * ``AutoscalePolicy`` decision logic — grow triggers (queue depth,
    deadline-miss rate), shrink band + ladder targets, cooldown, and the
    anti-flap construction (a just-shrunk bank never re-triggers shrink,
    growth only ever fires on demand).
  * Bank/service elasticity units — ``with_streams`` geometry re-resolution,
    ``resize_state`` prefix semantics, ``move_slot`` full-row carry,
    ``grow``/``shrink``/``compact`` bookkeeping (free list, μ ladders,
    counters, resize history, backfill).
  * The property sweep (``ci`` hypothesis profile in CI): a random
    admit/step/evict/grow/shrink/compact schedule against a fixed-max-width
    oracle — no sid dropped or duplicated, scheduler quotas never exceeded,
    free list consistent with ``status()``, and every surviving session's
    (B, Ĥ, step, conv) BIT-identical to the oracle, on the vmap AND
    megakernel paths.  Bit-identity is the paper's separation math surviving
    ops: a resize is a prefix copy, a compaction a verbatim row move —
    neither may perturb a single ULP of any co-tenant's trajectory.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EASIConfig, SMBGDConfig, SMBGDState
from repro.serve import AutoscalePolicy, PriorityScheduler, SeparationService
from repro.serve.elastic import ResizeDecision
from repro.stream import SeparatorBank
from _hypothesis_compat import given, settings, st

P, M, N = 8, 4, 2


def _cfgs():
    return (
        EASIConfig(n_components=N, n_features=M, mu=2e-3),
        SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5),
    )


def _bank(S, fused=False, **kw):
    ecfg, ocfg = _cfgs()
    return SeparatorBank(ecfg, ocfg, n_streams=S, fused=fused, **kw)


def _svc(S, fused=False, **kw):
    return SeparationService(_bank(S, fused=fused), seed=0, **kw)


def _warm(tag):
    """Deterministic per-sid warm state: admissions never split the service
    RNG, so elastic and oracle runs consume identical key sequences."""
    r = np.random.RandomState(0xE1A5 + tag)
    return SMBGDState(
        B=jnp.asarray(r.randn(N, M), jnp.float32),
        H_hat=jnp.zeros((N, N), jnp.float32),
        step=jnp.asarray(0, jnp.int32),
    )


class TestAutoscalePolicy:
    def test_grow_on_queue_depth(self):
        pol = AutoscalePolicy(max_streams=16, min_streams=2)
        dec = pol.decide(n_streams=4, n_active=4, queue_depth=3)
        assert isinstance(dec, ResizeDecision)
        assert dec.action == "grow" and dec.target == 8
        assert "queue_depth=3" in dec.reason

    def test_grow_caps_at_max(self):
        pol = AutoscalePolicy(max_streams=6, min_streams=2)
        dec = pol.decide(n_streams=4, n_active=4, queue_depth=1)
        assert dec.target == 6
        assert pol.decide(n_streams=6, n_active=6, queue_depth=5) is None

    def test_grow_on_miss_rate(self):
        pol = AutoscalePolicy(max_streams=8, min_streams=2, grow_miss_rate=0.1)
        dec = pol.decide(
            n_streams=4, n_active=4, queue_depth=0, deadline_miss_rate=0.5
        )
        assert dec.action == "grow" and "miss_rate" in dec.reason
        # miss trigger disabled by default
        off = AutoscalePolicy(max_streams=8, min_streams=2)
        assert off.decide(4, 4, 0, deadline_miss_rate=0.9) is None

    def test_never_shrinks_under_demand(self):
        pol = AutoscalePolicy(max_streams=8, min_streams=2, grow_miss_rate=0.1)
        # queue pressure at max width: hold, never shrink into demand
        assert pol.decide(8, 1, queue_depth=2) is None
        assert pol.decide(8, 1, queue_depth=0, deadline_miss_rate=0.5) is None

    def test_shrink_band_and_ladder_target(self):
        pol = AutoscalePolicy(max_streams=16, min_streams=2)
        # utilization 3/16 < 0.25 → shrink to the smallest ladder width
        # holding 3 sessions at <= 0.5 utilization: ceil(3/0.5)=6 → ladder 8
        dec = pol.decide(n_streams=16, n_active=3, queue_depth=0)
        assert dec is not None and dec.action == "shrink" and dec.target == 8
        # 5/16 >= 0.25 → inside the band, hold
        assert pol.decide(16, 5, 0) is None
        # empty bank shrinks to the floor
        assert pol.decide(16, 0, 0).target == 2
        assert pol.decide(2, 0, 0) is None  # already at min

    def test_cooldown_blocks_then_releases(self):
        pol = AutoscalePolicy(max_streams=8, min_streams=2, cooldown_ticks=4)
        assert pol.decide(2, 2, 3, ticks_since_resize=2) is None
        assert pol.decide(2, 2, 3, ticks_since_resize=4).action == "grow"
        # never-resized service: cooldown waived
        assert pol.decide(2, 2, 3, ticks_since_resize=None).action == "grow"

    def test_anti_flap_construction(self):
        # bands too close: the just-shrunk bank would sit inside the shrink
        # band and oscillate — rejected at construction
        with pytest.raises(ValueError, match="flaps"):
            AutoscalePolicy(
                max_streams=8,
                shrink_utilization=0.4,
                hold_utilization=0.5,
            )
        # and the legal default really is flap-free: post-shrink utilization
        # clears the shrink trigger for every active count
        pol = AutoscalePolicy(max_streams=64, min_streams=2)
        for n_active in range(1, 64):
            dec = pol.decide(64, n_active, 0)
            if dec is None:
                continue
            again = pol.decide(
                dec.target, n_active, 0, ticks_since_resize=pol.cooldown_ticks
            )
            assert again is None, (n_active, dec, again)

    def test_validation(self):
        with pytest.raises(ValueError, match="min_streams"):
            AutoscalePolicy(max_streams=4, min_streams=0)
        with pytest.raises(ValueError, match="max_streams"):
            AutoscalePolicy(max_streams=1, min_streams=2)
        with pytest.raises(ValueError, match="factor"):
            AutoscalePolicy(max_streams=4, factor=1)
        with pytest.raises(ValueError, match="grow_miss_rate"):
            AutoscalePolicy(max_streams=4, grow_miss_rate=0.0)
        # snapshot-safe: the policy is frozen (memoryless by construction)
        assert AutoscalePolicy.__dataclass_params__.frozen


class TestBankElasticity:
    def test_with_streams_resizes_and_keeps_explicit_knobs(self):
        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(
            ecfg, ocfg, n_streams=4, fused=True, block_p=8, autotune=False
        )
        wide = bank.with_streams(8)
        assert wide.n_streams == 8 and wide.block_p == 8
        assert wide is not bank and bank.n_streams == 4  # original untouched
        assert bank.with_streams(4) is bank

    def test_with_streams_drops_nondividing_block_s(self):
        bank = _bank(8, fused=True, block_s=4, autotune=False)
        assert bank.with_streams(16).block_s == 4  # still divides
        assert bank.with_streams(2).block_s is None  # 2 % 4 != 0 → dropped

    def test_with_streams_rejects_per_stream_hyperparams(self):
        ecfg, ocfg = _cfgs()
        from repro.core.smbgd import BankHyperparams

        bank = SeparatorBank(
            ecfg, ocfg, n_streams=4,
            hyperparams=BankHyperparams.broadcast(ocfg, 4),
        )
        with pytest.raises(ValueError, match="per-stream hyperparams"):
            bank.with_streams(8)

    @pytest.mark.parametrize("fused", [False, True])
    def test_resize_state_prefix_semantics(self, fused):
        bank = _bank(4, fused=fused)
        state = bank.init(jax.random.PRNGKey(0))
        wide = bank.with_streams(8)
        grown = wide.resize_state(state)
        assert grown.B.shape[0] == 8
        np.testing.assert_array_equal(
            np.asarray(grown.B[:4]), np.asarray(state.B)
        )
        # new rows: blank separators, never-stepped conv sentinel, no RNG use
        assert float(np.abs(np.asarray(grown.B[4:])).max()) == 0.0
        assert np.all(np.isinf(np.asarray(grown.conv[4:])))
        back = bank.resize_state(grown)
        for name in ("B", "H_hat", "step"):
            np.testing.assert_array_equal(
                np.asarray(getattr(back, name)), np.asarray(getattr(state, name))
            )

    def test_move_slot_carries_every_leaf_verbatim(self):
        bank = _bank(4)
        state = bank.init(jax.random.PRNGKey(1))
        moved = bank.move_slot(state, 0, 3)
        for name in ("B", "H_hat", "step"):
            np.testing.assert_array_equal(
                np.asarray(getattr(moved, name)[0]),
                np.asarray(getattr(state, name)[3]),
            )
        # conv rides too — unlike copy_slot, which restarts verdicts
        np.testing.assert_array_equal(
            np.asarray(bank._conv_or_default(moved)[0]),
            np.asarray(bank._conv_or_default(state)[3]),
        )


class TestServiceElasticity:
    def test_grow_backfills_queue_same_call(self):
        svc = _svc(2, max_queue=4)
        for i in range(4):
            svc.admit(f"s{i}")
        assert svc.n_active == 2 and svc.n_queued == 2
        svc.grow(4)
        assert svc.n_active == 4 and svc.n_queued == 0
        assert svc.metrics["n_grows"] == 1.0
        assert svc.metrics["n_streams"] == 4.0

    def test_shrink_compacts_first_and_rejects_overflow(self):
        svc = _svc(8)
        for i in range(4):
            svc.admit(f"s{i}")
        # strand the survivors in high slots
        svc.evict("s0")
        svc.evict("s1")
        assert max(svc.sessions.values()) >= 2
        svc.shrink(2)
        assert svc.bank.n_streams == 2
        assert sorted(svc.sessions.values()) == [0, 1]
        assert svc.metrics["n_shrinks"] == 1.0
        with pytest.raises(ValueError, match="exceed the new capacity"):
            svc.shrink(1)
        with pytest.raises(ValueError, match="use grow"):
            svc.shrink(4)

    def test_compact_moves_low_and_fixes_free_list(self):
        svc = _svc(8)
        for i in range(5):
            svc.admit(f"s{i}")
        for sid in ("s0", "s2"):
            svc.evict(sid)
        moved = svc.compact()
        assert moved > 0
        assert sorted(svc.sessions.values()) == [0, 1, 2]
        assert sorted(svc._free) == [3, 4, 5, 6, 7]
        assert svc.metrics["n_compactions"] == 1.0
        assert svc.compact() == 0  # idempotent; second pass is not counted
        assert svc.metrics["n_compactions"] == 1.0

    def test_resize_history_and_utilization(self):
        svc = _svc(2, max_queue=4)
        svc.admit("a")
        assert svc.metrics["bank_utilization"] == 0.5
        svc.grow(4, reason="unit")
        svc.shrink(2, reason="unit")
        hist = svc.lifecycle["resize_history"]
        assert [h["action"] for h in hist] == ["grow", "shrink"]
        assert hist[0]["from"] == 2 and hist[0]["to"] == 4
        assert hist[1]["reason"] == "unit"

    def test_autoscale_rejects_per_stream_hyperparams(self):
        ecfg, ocfg = _cfgs()
        from repro.core.smbgd import BankHyperparams

        bank = SeparatorBank(
            ecfg, ocfg, n_streams=2,
            hyperparams=BankHyperparams.broadcast(ocfg, 2),
        )
        with pytest.raises(ValueError, match="resizable bank"):
            SeparationService(
                bank, seed=0, autoscale=AutoscalePolicy(max_streams=4)
            )

    def test_prewarm_caches_step_per_width(self):
        svc = _svc(2)
        svc.prewarm([2, 4])
        cached = set(svc._step_cache)
        svc.grow(4)
        # the resize reused the prewarmed program — no new cache entry
        assert set(svc._step_cache) == cached


# -- the property sweep ------------------------------------------------------

QUOTAS = {"t0": 3, "t1": 3}
S_MIN, S_MAX = 2, 8


def _schedule_invariants(svc, live, gone):
    S = svc.bank.n_streams
    slots = svc.sessions
    # no sid dropped or duplicated: every live sid is in exactly one pool
    for sid in live:
        assert svc.status(sid) in ("active", "queued"), sid
    for sid in gone:
        assert svc.status(sid) == "finished", sid
    assert len(set(slots.values())) == len(slots)
    # free list consistent with the slot map and status()
    assert sorted(set(svc._free) | set(slots.values())) == list(range(S))
    assert len(svc._free) == len(set(svc._free)) == svc.n_free
    # scheduler quotas never exceeded by ACTIVE sessions
    per_tenant = {}
    for sid in slots:
        t = svc._meta[sid].tenant
        per_tenant[t] = per_tenant.get(t, 0) + 1
    for tenant, quota in QUOTAS.items():
        assert per_tenant.get(tenant, 0) <= quota, per_tenant


def _run_schedule(seed, fused):
    rng = np.random.RandomState(seed)
    elastic = SeparationService(
        _bank(S_MIN, fused=fused),
        seed=0,
        scheduler=PriorityScheduler(max_queue=S_MAX, quotas=QUOTAS),
    )
    oracle = SeparationService(_bank(S_MAX, fused=fused), seed=0)
    live, gone, next_sid = [], [], 0
    ops = rng.choice(
        ["admit", "admit", "step", "step", "step", "evict", "grow",
         "shrink", "compact"],
        size=24,
    )
    for op in ops:
        if op == "admit" and len(live) < S_MAX:
            sid = f"s{next_sid}"
            st8 = _warm(next_sid)
            elastic.admit(sid, state=st8, tenant=f"t{next_sid % 2}")
            oracle.admit(sid, state=st8)
            live.append(sid)
            next_sid += 1
        elif op == "step":
            active = sorted(elastic.sessions, key=str)
            if active:
                batches = {
                    sid: rng.randn(P, M).astype(np.float32) for sid in active
                }
                elastic.step(batches)
                oracle.step({k: v.copy() for k, v in batches.items()})
        elif op == "evict":
            active = sorted(elastic.sessions, key=str)
            if active:
                sid = active[rng.randint(len(active))]
                elastic.evict(sid)
                oracle.evict(sid)
                live.remove(sid)
                gone.append(sid)
        elif op == "grow":
            elastic.grow(min(S_MAX, elastic.bank.n_streams * 2))
        elif op == "shrink":
            target = max(
                S_MIN, elastic.bank.n_streams // 2, elastic.n_active
            )
            if target <= elastic.bank.n_streams:
                elastic.shrink(target)
        elif op == "compact":
            elastic.compact()
        _schedule_invariants(elastic, live, gone)
    return elastic, oracle


@pytest.mark.property
@pytest.mark.parametrize("fused", [False, True])
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_elastic_schedule_matches_fixed_width_oracle(fused, seed):
    """The tentpole acceptance bar: any interleaving of resize ops leaves
    every surviving session's trajectory BIT-identical (not allclose) to the
    same traffic served by a bank frozen at max width."""
    elastic, oracle = _run_schedule(seed, fused)
    for sid, slot in elastic.sessions.items():
        se = elastic.bank.slot_state(elastic.state, slot)
        so = oracle.bank.slot_state(oracle.state, oracle.sessions[sid])
        for name in ("B", "H_hat", "step"):
            np.testing.assert_array_equal(
                np.asarray(getattr(se, name)),
                np.asarray(getattr(so, name)),
                err_msg=f"{sid}.{name} diverged from the fixed-width oracle",
            )
        np.testing.assert_array_equal(
            np.asarray(elastic.bank._conv_or_default(elastic.state)[slot]),
            np.asarray(
                oracle.bank._conv_or_default(oracle.state)[
                    oracle.sessions[sid]
                ]
            ),
            err_msg=f"{sid}.conv diverged from the fixed-width oracle",
        )


@pytest.mark.property
def test_autoscaled_service_matches_oracle_under_burst():
    """The autoscaler in the run_tick loop (not manual resizes): a burst of
    admissions grows the bank, the drain shrinks it, and the sessions that
    lived through both transitions stay bit-identical to the oracle."""
    pol = AutoscalePolicy(max_streams=S_MAX, min_streams=S_MIN, cooldown_ticks=0)
    elastic = SeparationService(
        _bank(S_MIN), seed=0, autoscale=pol, max_queue=S_MAX
    )
    oracle = SeparationService(_bank(S_MAX), seed=0)
    rng = np.random.RandomState(7)
    for k in range(6):
        elastic.admit(f"s{k}", state=_warm(k))
        oracle.admit(f"s{k}", state=_warm(k))
    for _ in range(6):  # burst: autoscaler grows to cover the queue
        batches = {
            sid: rng.randn(P, M).astype(np.float32)
            for sid in sorted(elastic.sessions, key=str)
        }
        elastic.step(batches)
        oracle.step({k: v.copy() for k, v in batches.items()})
        elastic._autoscale_tick()
    assert elastic.bank.n_streams == S_MAX and elastic.n_queued == 0
    for k in range(5):  # drain: autoscaler compacts + shrinks
        elastic.evict(f"s{k}")
        oracle.evict(f"s{k}")
    for _ in range(3):
        elastic._autoscale_tick()
    assert elastic.bank.n_streams < S_MAX
    assert elastic.metrics["n_grows"] >= 1
    assert elastic.metrics["n_shrinks"] >= 1
    sid = "s5"
    se = elastic.bank.slot_state(elastic.state, elastic.sessions[sid])
    so = oracle.bank.slot_state(oracle.state, oracle.sessions[sid])
    for name in ("B", "H_hat", "step"):
        np.testing.assert_array_equal(
            np.asarray(getattr(se, name)), np.asarray(getattr(so, name))
        )
