"""Fault-injection chaos drills: inject → detect → contain → recover.

The end-to-end containment claim of the fault-tolerance layer, exercised on
BOTH execution paths (vmap oracle and fused megakernel):

  * an injected input fault (NaN/Inf burst, amplitude spike) is detected by
    the in-kernel health word within two ticks of the poisoned block being
    served,
  * the offender is rolled back to its last-known-good shadow and walks the
    escalation ladder (μ cut → quarantine → evict ``"diverged"``),
  * healthy co-tenant sessions are BIT-IDENTICAL to a fault-free run — the
    blast radius of a faulted stream is exactly that stream,
  * transient source failures (raise, stall, short read) degrade one
    session-tick instead of failing the shared launch, and
    ``ResilientSource`` retries make them invisible,
  * health state, shadows and quarantine membership survive a checkpoint
    round-trip.
"""
import tempfile
import time

import jax
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import EASIConfig, SMBGDConfig
from repro.data.pipeline import MixedSignals
from repro.data.resilience import (
    FAULT_MODES,
    FaultInjector,
    ResilientSource,
    SourceStalled,
)
from repro.data.sources import ReplaySource, SourceExhausted, SyntheticSource
from repro.serve import ConvergencePolicy, HealthPolicy, SeparationService
from repro.stream import SeparatorBank

pytestmark = pytest.mark.chaos

P = 16
HPOL = HealthPolicy(
    max_rollbacks=1, window=30, mu_cut=0.25, cut_ticks=5,
    max_quarantines=1, probation=2, probe_every=2, shadow_every=4,
)
# convergence disabled: these drills isolate the health ladder
NEVER = ConvergencePolicy(threshold=1e-12, patience=10**6, min_ticks=10**6)


def _svc(fused, S=3, **kw):
    ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=3e-3, beta=0.9, gamma=0.5)
    bank = SeparatorBank(ecfg, ocfg, n_streams=S, fused=fused, health_checks=True)
    return SeparationService(
        bank, seed=0, policy=NEVER, health_policy=HPOL, max_queue=8, **kw
    )


def _src(seed=0, faults=None):
    pipe = MixedSignals(m=4, n=2, batch=P, seed=seed)
    return FaultInjector(SyntheticSource(pipe), faults or {})


def _slot_B(svc, sid):
    return np.asarray(svc.bank.slot_state(svc.state, svc.sessions[sid]).B)


class TestFaultInjectorHarness:
    def test_fault_free_wrapper_is_bit_identical(self):
        a, b = _src(seed=3), FaultInjector(
            SyntheticSource(MixedSignals(m=4, n=2, batch=P, seed=3)), {}
        )
        for _ in range(5):
            np.testing.assert_array_equal(a.next_block(P), b.next_block(P))
        assert a.injected == {}

    def test_nan_inf_spike_truncate(self):
        src = _src(faults={0: "nan", 1: "inf", 2: ("spike", 1e3), 3: "truncate"})
        blk = src.next_block(P)
        assert np.isnan(blk[:, : P // 4]).all() and not np.isnan(blk[:, P // 2 :]).any()
        blk = src.next_block(P)
        assert np.isinf(blk[:, : P // 4]).all()
        clean = SyntheticSource(MixedSignals(m=4, n=2, batch=P, seed=0))
        for _ in range(2):
            clean.next_block(P)
        np.testing.assert_allclose(src.next_block(P), clean.next_block(P) * 1e3)
        assert src.next_block(P).shape == (4, P // 2)
        assert src.injected == {0: "nan", 1: "inf", 2: "spike", 3: "truncate"}

    def test_raise_is_transient(self):
        """The raise fires once WITHOUT consuming the block: the retry pulls
        the same block, clean."""
        src = _src(seed=5, faults={1: "raise"})
        clean = SyntheticSource(MixedSignals(m=4, n=2, batch=P, seed=5))
        np.testing.assert_array_equal(src.next_block(P), clean.next_block(P))
        with pytest.raises(RuntimeError, match="injected"):
            src.next_block(P)
        np.testing.assert_array_equal(src.next_block(P), clean.next_block(P))

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="fault mode"):
            FaultInjector(None, {0: "gamma-ray"})
        assert set(FAULT_MODES) == {
            "nan", "inf", "spike", "truncate", "raise", "stall"
        }


class TestResilientSource:
    def test_retries_then_succeeds_and_counts(self):
        src = ResilientSource(_src(seed=1, faults={0: "raise"}), max_retries=2)
        blk = src.next_block(P)
        assert blk.shape == (4, P)
        assert src.pop_retries() == 1 and src.pop_retries() == 0

    def test_budget_exhausted_reraises(self):
        class AlwaysBroken:
            def next_block(self, n):
                raise OSError("dead sensor")

        src = ResilientSource(AlwaysBroken(), max_retries=2)
        with pytest.raises(OSError, match="dead sensor"):
            src.next_block(P)
        assert src.pop_retries() == 2  # both retries burned

    def test_exhausted_passes_through_unretried(self):
        src = ResilientSource(
            ReplaySource(np.zeros((P, 4), np.float32)), max_retries=3
        )
        src.next_block(P)
        with pytest.raises(SourceExhausted):
            src.next_block(P)
        assert src.pop_retries() == 0

    def test_stall_timeout_raises_source_stalled(self):
        src = ResilientSource(
            _src(seed=2, faults={0: ("stall", 0.5), 1: ("stall", 0.5)}),
            max_retries=1,
            timeout_s=0.05,
        )
        t0 = time.monotonic()
        with pytest.raises(SourceStalled):
            src.next_block(P)
        assert time.monotonic() - t0 < 2.0  # abandoned, not awaited

    def test_delegates_cursor_protocol(self):
        inner = SyntheticSource(MixedSignals(m=4, n=2, batch=P, seed=0))
        src = ResilientSource(FaultInjector(inner, {}))
        assert src.n_channels == 4 and src.block_size == P
        src.next_block(P)
        assert src.position == P
        src.seek(0)
        assert inner.position == 0


@pytest.mark.parametrize("fused", [False, True])
class TestChaosEndToEnd:
    def test_detection_containment_and_healthy_isolation(self, fused):
        """The flagship drill: NaN burst on one session — detected within 2
        ticks, rolled back, μ cut; the healthy co-tenant's trajectory is
        bit-identical to a run where the faulty session never existed."""
        FAULT_BLOCK = 3
        chaos = _svc(fused)
        chaos.admit("healthy", source=_src(seed=1))
        chaos.admit("faulty", source=_src(seed=2, faults={FAULT_BLOCK: "nan"}))
        clean = _svc(fused)
        clean.admit("healthy", source=_src(seed=1))
        T = 10
        for _ in range(T):
            chaos.run_tick()
            clean.run_tick()
        events = [e for e in chaos.health_events if e.session_id == "faulty"]
        assert events and events[0].action == "rollback"
        # the poisoned block is served on tick FAULT_BLOCK+1; detection is ≤2
        # ticks later (in fact: the same tick, in-kernel)
        assert events[0].tick - (FAULT_BLOCK + 1) <= 2
        assert chaos.metrics["n_rollbacks"] >= 1
        # blast radius — the healthy session never felt it
        np.testing.assert_array_equal(
            _slot_B(chaos, "healthy"), _slot_B(clean, "healthy")
        )
        # containment — the faulty slot's committed state stayed finite
        assert np.isfinite(_slot_B(chaos, "faulty")).all()

    def test_escalation_to_quarantine_and_diverged(self, fused):
        """A repeat offender quarantines; one that never produces a healthy
        probe tops the ladder out and evicts with reason ``"diverged"`` —
        carrying the escalation history in the eviction record."""
        svc = _svc(fused, S=2)
        svc.admit("doomed", source=_src(seed=4, faults={i: "nan" for i in range(99)}))
        svc.admit("ok", source=_src(seed=5))
        for _ in range(40):
            svc.run_tick()
            if svc.status("doomed") == "finished":
                break
        acts = [e.action for e in svc.health_events if e.session_id == "doomed"]
        assert acts[:2] == ["rollback", "quarantine"]
        assert svc.status("doomed") == "finished"
        rec = svc.finished["doomed"]
        assert rec.reason == "diverged"
        assert rec.health is not None and rec.health.quarantines >= 1
        assert svc.metrics["n_diverged"] == 1
        assert svc.status("ok") == "active"  # co-tenant untouched

    def test_quarantine_probation_release(self, fused):
        """Two offenses quarantine; clean out-of-band probes release the
        session warm after ``probation`` healthy probes."""
        svc = _svc(fused, S=2)
        svc.admit("flappy", source=_src(seed=3, faults={2: "nan", 4: "nan"}))
        released_at = None
        for t in range(30):
            svc.run_tick()
            acts = [e.action for e in svc.health_events if e.session_id == "flappy"]
            if "release" in acts:
                released_at = t
                break
        assert released_at is not None
        assert acts == ["rollback", "quarantine", "release"]
        assert svc.status("flappy") in ("active", "queued")
        assert svc.metrics["n_quarantined"] == 0

    def test_state_corruption_hook_detected_next_tick(self, fused):
        """The bank-side corruption hook: poisoning a slot's separator state
        directly (bit-flip drill, no input fault) is caught by the next
        tick's health word and rolled back to the shadow."""
        svc = _svc(fused, S=2)
        svc.admit("victim", source=_src(seed=6))
        for _ in range(4):
            svc.run_tick()
        assert svc.metrics["n_rollbacks"] == 0
        svc.state = svc.bank.corrupt_slot(
            svc.state, svc.sessions["victim"], mode="nan"
        )
        svc.run_tick()
        events = [e for e in svc.health_events if e.session_id == "victim"]
        assert events and events[0].action == "rollback"
        assert np.isfinite(_slot_B(svc, "victim")).all()

    def test_truncated_block_degrades_one_session_tick(self, fused):
        """A short read (wrong downstream shape) is a per-session fault: the
        launch proceeds, the session skips the tick, the error is recorded."""
        svc = _svc(fused, S=2)
        svc.admit("short", source=_src(seed=7, faults={1: "truncate"}))
        svc.admit("ok", source=_src(seed=8))
        outs = [svc.run_tick() for _ in range(3)]
        assert all("ok" in out for out in outs)
        assert "short" not in outs[1] and "short" in outs[2]
        assert svc.metrics["n_degraded_ticks"] == 1
        assert "block shape" in svc.last_faults["short"]

    def test_resilient_wrapper_makes_transient_raise_invisible(self, fused):
        """FaultInjector(raise) + ResilientSource: the retry pulls the same
        block clean — the trajectory is bit-identical to a fault-free run and
        only the retry counter shows anything happened."""
        chaos = _svc(fused, S=1)
        chaos.admit(
            "u",
            source=ResilientSource(_src(seed=9, faults={2: "raise", 5: "raise"})),
        )
        clean = _svc(fused, S=1)
        clean.admit("u", source=_src(seed=9))
        for _ in range(8):
            chaos.run_tick()
            clean.run_tick()
        np.testing.assert_array_equal(_slot_B(chaos, "u"), _slot_B(clean, "u"))
        assert chaos.metrics["n_source_retries"] == 2
        assert chaos.metrics["n_degraded_ticks"] == 0

    def test_containment_state_roundtrips_checkpoint(self, fused, tmp_path):
        """Shadows, health monitors, μ-cut countdowns and the quarantine
        pool all survive save → restore; the restored service resumes the
        ladder (probation release still works)."""
        svc = _svc(fused, S=2)
        svc.admit("q", source=_src(seed=10, faults={i: "nan" for i in range(6)}))
        svc.admit("ok", source=_src(seed=11))
        for _ in range(12):
            svc.run_tick()
            if svc.status("q") == "quarantined":
                break
        assert svc.status("q") == "quarantined"
        ck = Checkpointer(tmp_path)
        life = svc.lifecycle
        svc.save(ck, step=1)
        dup = _svc(fused, S=2)
        dup.restore(ck, lifecycle=life)
        assert dup.status("q") == "quarantined"
        assert dup.status("ok") == "active"
        np.testing.assert_array_equal(
            np.asarray(svc._shadow.B), np.asarray(dup._shadow.B)
        )
        np.testing.assert_array_equal(
            np.asarray(svc._quarantined["q"].record.state.B),
            np.asarray(dup._quarantined["q"].record.state.B),
        )
        assert dup._quarantined["q"].monitor.quarantines == (
            svc._quarantined["q"].monitor.quarantines
        )
        # rebind sources (clean now) and watch probation release fire
        dup.bind_source("ok", _src(seed=11))
        q_src = _src(seed=10)
        dup.bind_source("q", q_src)
        for _ in range(12):
            dup.run_tick()
            if dup.status("q") in ("active", "queued"):
                break
        assert dup.status("q") in ("active", "queued")
