"""Latency-SLO layer (PR-8): time-to-ready tick accounting, the streaming
quantile sketch, deadline budgets with shed/gate load control, and the
record → replay harness.

The three accounting bugfixes this PR lands each get a regression test:

  * ``block_ticks=False`` used to time async *dispatch*, not completion —
    ``test_async_tick_measures_time_to_ready`` routes the bank's conv leaf
    through a sleeping ``jax.pure_callback`` and asserts the sleep shows up
    in ``last_tick_s`` even without ``block_ticks``.
  * ``samples_per_s`` used to divide by wall time since *admission* — a
    session that waited in the queue looked slow forever.  Now
    ``SessionStats`` stamps ``activated_at`` and reports ``queue_wait_s``
    separately from service-time throughput.
  * An empty ``run_tick`` (probe-only: every active feed drained/stalled)
    used to skip ``step()`` and leave no latency record at all, though the
    drift/quarantine probes it runs spend real wall-clock against any
    real-time budget.  Now empty ticks count in ``n_empty_ticks``, land in
    the latency sketch + deadline check, and ``last_probe_s`` surfaces the
    probe cost — without polluting the data-tick means or ``n_ticks``.
"""
import dataclasses
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import EASIConfig, SMBGDConfig
from repro.data.sources import (
    RecordedSource,
    Recording,
    RecordingSource,
    ReplaySource,
    SourceExhausted,
    load_recording,
    save_recording,
)
from repro.serve import (
    DeadlineMonitor,
    LatencySketch,
    SLOPolicy,
    SeparationService,
    SessionStats,
    TickTimer,
)
from repro.serve.slo import replay
from repro.stream import SeparatorBank

P = 8


def _mk_svc(S=2, P=P, fused=False, **kw):
    ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)
    return SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=S, fused=fused), seed=0, **kw
    )


def _blocks_source(n_blocks, seed=0, m=4):
    rng = np.random.default_rng(seed)
    return ReplaySource(
        rng.standard_normal((n_blocks * P, m)).astype(np.float32)
    )


class TestLatencySketch:
    def test_window_quantiles_match_numpy_exactly(self):
        rng = np.random.default_rng(1)
        xs = rng.lognormal(mean=-6.0, sigma=1.0, size=500)
        sk = LatencySketch(window=128)
        for x in xs:
            sk.add(float(x))
        tail = xs[-128:]
        for q in (0.5, 0.9, 0.99, 0.999):
            assert sk.window_quantile(q) == pytest.approx(
                float(np.quantile(tail, q)), rel=0, abs=0
            )

    def test_lifetime_quantiles_within_bin_relative_error(self):
        rng = np.random.default_rng(2)
        xs = rng.lognormal(mean=-5.0, sigma=0.8, size=4000)
        sk = LatencySketch(window=16)  # tiny window: lifetime must carry
        for x in xs:
            sk.add(float(x))
        # one log bin spans a factor of 10**(1/90); the geometric-midpoint
        # estimate is off by at most half a bin plus nearest-rank slack
        tol = 10 ** (1 / sk.bins_per_decade) - 1 + 0.01
        for q in (0.5, 0.99, 0.999):
            exact = float(np.quantile(xs, q))
            assert sk.quantile(q) == pytest.approx(exact, rel=2 * tol)

    def test_nan_skipped_and_reset(self):
        sk = LatencySketch(window=8)
        sk.add(float("nan"))
        assert sk.count == 0 and np.isnan(sk.quantile(0.5))
        sk.add(0.25)
        assert sk.count == 1 and sk.window_count == 1
        sk.reset()
        assert sk.count == 0 and np.isnan(sk.window_quantile(0.5))

    def test_out_of_range_clamps_to_edge_bins(self):
        sk = LatencySketch(window=4, lo=1e-3, hi=1e0)
        sk.add(1e-9)  # below lo
        sk.add(1e6)  # above hi
        assert sk.count == 2
        assert sk.quantile(0.0) <= 2e-3  # pinned near the lo edge
        assert sk.quantile(1.0) >= 0.5  # pinned near the hi edge

    def test_summary_keys(self):
        sk = LatencySketch()
        sk.add(0.01)
        s = sk.summary()
        assert set(s) == {
            "p50_tick_s", "p99_tick_s", "p999_tick_s",
            "p50_tick_s_life", "p99_tick_s_life", "p999_tick_s_life",
        }

    def test_validation(self):
        with pytest.raises(ValueError):
            LatencySketch(window=0)
        with pytest.raises(ValueError):
            LatencySketch(lo=1.0, hi=0.5)
        sk = LatencySketch()
        with pytest.raises(ValueError):
            sk.quantile(1.5)


class TestTickTimer:
    def test_sampled_sync_cadence(self):
        t = TickTimer(sync_every=3)
        timed = []
        for _ in range(7):
            t.start()
            _, was_timed = t.stop(sync_leaf=jnp.zeros((2,)))
            timed.append(was_timed)
        assert timed == [True, False, False, True, False, False, True]

    def test_already_synced_is_always_timed(self):
        t = TickTimer(sync_every=4)
        for _ in range(5):
            t.start()
            _, was_timed = t.stop(already_synced=True)
            assert was_timed

    def test_stop_without_start_raises(self):
        with pytest.raises(RuntimeError):
            TickTimer().stop()


class TestSLOPolicyValidation:
    def test_levers_require_budget(self):
        with pytest.raises(ValueError, match="deadline_budget_s"):
            SLOPolicy(shed=True)
        with pytest.raises(ValueError, match="deadline_budget_s"):
            SLOPolicy(gate_admissions=True)
        SLOPolicy(shed=True, gate_admissions=True, deadline_budget_s=0.1)

    @pytest.mark.parametrize(
        "kw",
        [
            dict(deadline_budget_s=0.0),
            dict(sync_every=0),
            dict(window=0),
            dict(miss_window=0),
            dict(max_miss_rate=0.0),
            dict(max_miss_rate=1.5),
            dict(shed_cooldown=0),
        ],
    )
    def test_bad_fields_raise(self, kw):
        with pytest.raises(ValueError):
            SLOPolicy(**kw)


class TestDeadlineMonitor:
    def test_window_resident_miss_count(self):
        pol = SLOPolicy(deadline_budget_s=1.0, miss_window=4)
        mon = DeadlineMonitor()
        assert mon.record(0, True, pol) == 1
        assert mon.record(1, True, pol) == 2
        assert mon.record(2, False, pol) == 2
        # tick 4: the miss at tick 0 ages out (4 - 0 >= 4), tick 1 stays
        assert mon.record(4, False, pol) == 1
        assert mon.served == 4 and mon.misses == 2


class TestTickAccounting:
    def test_metrics_is_callable_view(self):
        svc = _mk_svc()
        m = svc.metrics
        assert m() is m  # calling the view is the identity
        assert isinstance(m, dict) and set(svc.metrics()) == set(m)

    def test_async_tick_measures_time_to_ready(self):
        """Regression (bugfix a): with ``block_ticks=False`` the old clock
        stopped at dispatch.  A step whose conv leaf passes through a
        sleeping ``pure_callback`` must still show the sleep in
        ``last_tick_s`` — the timer blocks on the telemetry leaf."""
        svc = _mk_svc(S=2, block_ticks=False, policy=None)
        delay = 0.15
        orig = svc._step

        def slow_step(state, X, active):
            state, Y = orig(state, X, active)

            def _sleep(c):
                time.sleep(delay)
                return c

            conv = jax.pure_callback(
                _sleep,
                jax.ShapeDtypeStruct(state.conv.shape, state.conv.dtype),
                state.conv,
            )
            return state._replace(conv=conv), Y

        svc._step = slow_step
        svc.admit("a")
        X = {"a": jnp.zeros((P, 4))}
        svc.step(X)  # compile tick
        svc.step(X)
        assert svc.metrics["last_tick_s"] >= 0.9 * delay
        assert svc.metrics["p50_tick_s"] >= 0.9 * delay

    def test_sampled_sync_times_one_in_k(self):
        svc = _mk_svc(S=2, slo=SLOPolicy(sync_every=3))
        svc.admit("a", source=_blocks_source(9))
        for _ in range(9):
            svc.run_tick()
        m = svc.metrics
        assert m["n_ticks"] == 9
        assert m["n_timed_ticks"] == 3  # ticks 0, 3, 6
        # sampled-out ticks leave no latency record anywhere
        assert svc._sketch.count == 3

    def test_queue_wait_and_service_time_throughput(self):
        """Regression (bugfix b): queue wait must not dilute throughput."""
        t0 = 100.0
        st = SessionStats(admitted_at=t0, activated_at=t0 + 10.0)
        st.ticks, st.samples = 1, 100
        assert st.queue_wait_s() == pytest.approx(10.0)
        # throughput over SERVICE time (0.5 s), not the 10.5 s since admit
        assert st.samples_per_s(now=t0 + 10.5) == pytest.approx(200.0)
        # not-yet-activated: no queue wait reported, no throughput fiction
        st2 = SessionStats(admitted_at=t0)
        assert st2.queue_wait_s() == 0.0

    def test_queued_session_reports_queue_wait(self):
        svc = _mk_svc(S=1, max_queue=2)
        svc.admit("a", source=_blocks_source(2, seed=0))
        svc.admit("b", source=_blocks_source(2, seed=1))
        assert svc.status("b") == "queued"
        for _ in range(6):
            svc.run_tick()  # a drains -> evicted -> b backfills + drains
        stats = svc.finished["b"].stats
        assert stats.activated_at is not None
        assert stats.activated_at >= stats.admitted_at
        assert stats.queue_wait_s() > 0.0

    def test_empty_tick_counted_distinctly(self):
        """Regression (bugfix c): a probe-only tick leaves a latency record
        but does not touch the data-tick counters."""
        svc = _mk_svc(S=2)
        svc.run_tick()  # nothing admitted: empty
        m = svc.metrics
        assert m["n_empty_ticks"] == 1
        assert m["n_ticks"] == 0 and m["n_timed_ticks"] == 0
        assert np.isnan(m["last_tick_s"]) and np.isnan(m["mean_tick_s"])
        assert svc._sketch.count == 1  # ...but the sketch saw its latency

    def test_empty_tick_surfaces_probe_latency(self):
        from repro.core import smbgd as smbgd_lib
        from repro.serve import DriftMonitor, DriftPolicy, ParkedSession, SessionMeta
        from repro.serve.engine import EvictionRecord

        from repro.serve import ConvergencePolicy

        svc = _mk_svc(
            S=2,
            policy=ConvergencePolicy(threshold=1e-9, patience=10**6),
            drift_policy=DriftPolicy(mode="readmit", probe_every=1),
        )
        frozen = smbgd_lib.init_state(svc.bank.easi, jax.random.PRNGKey(0))
        svc._parked["p"] = ParkedSession(
            record=EvictionRecord(
                state=frozen, stats=SessionStats(admitted_at=0.0),
                monitor=None, reason="converged", tick=0,
            ),
            source=_blocks_source(50), monitor=DriftMonitor(),
            meta=SessionMeta(),
        )
        assert np.isnan(svc.metrics["last_probe_s"])
        svc.run_tick()
        m = svc.metrics
        assert m["n_empty_ticks"] == 1
        assert m["last_probe_s"] >= 0.0  # probe cost surfaced

    def test_empty_ticks_feed_the_deadline_check(self):
        svc = _mk_svc(S=2, slo=SLOPolicy(deadline_budget_s=1e-12))
        svc.run_tick()
        assert svc.metrics["n_deadline_misses"] == 1


class TestDeadlineBudget:
    def test_misses_counted_and_per_session(self):
        svc = _mk_svc(S=2, slo=SLOPolicy(deadline_budget_s=1e-12))
        svc.admit("a", source=_blocks_source(4))
        for _ in range(4):
            svc.run_tick()
        m = svc.metrics
        assert m["n_deadline_misses"] == 4
        assert m["deadline_miss_rate"] == 1.0
        ss = svc.session_stats("a")
        assert ss["deadline_misses"] == 4
        assert ss["deadline_misses_recent"] >= 1

    def test_generous_budget_never_misses(self):
        svc = _mk_svc(S=2, slo=SLOPolicy(deadline_budget_s=1e6))
        svc.admit("a", source=_blocks_source(3))
        for _ in range(3):
            svc.run_tick()
        assert svc.metrics["n_deadline_misses"] == 0
        assert svc.metrics["deadline_miss_rate"] == 0.0

    def test_shed_preempts_worst_missing_session(self):
        svc = _mk_svc(
            S=2,
            max_queue=2,
            slo=SLOPolicy(
                deadline_budget_s=1e-12, shed=True, max_miss_rate=0.25,
                miss_window=8, shed_cooldown=1,
            ),
        )
        svc.admit("a", source=_blocks_source(20, seed=0), priority=1.0)
        svc.admit("b", source=_blocks_source(20, seed=1), priority=0.0)
        for _ in range(6):
            svc.run_tick()
            if svc.metrics["n_shed"]:
                break
        m = svc.metrics
        assert m["n_shed"] >= 1
        # equal misses -> the LOWER-priority session is the victim
        assert svc.finished["b"].reason == "shed"
        assert svc.status("a") == "active"
        ev = [e for e in svc.slo_events if e.action == "shed"]
        assert ev and ev[0].session_id == "b" and ev[0].miss_rate > 0.25

    def test_shed_never_empties_the_bank(self):
        svc = _mk_svc(
            S=2,
            slo=SLOPolicy(
                deadline_budget_s=1e-12, shed=True, max_miss_rate=0.1,
                miss_window=4, shed_cooldown=1,
            ),
        )
        svc.admit("only", source=_blocks_source(10))
        for _ in range(5):
            svc.run_tick()
        assert svc.metrics["n_shed"] == 0  # lone session is never shed
        assert svc.status("only") == "active"

    def test_gate_holds_backfill_until_window_recovers(self):
        svc = _mk_svc(
            S=1,
            max_queue=2,
            slo=SLOPolicy(
                deadline_budget_s=1e-12, gate_admissions=True,
                max_miss_rate=0.5, miss_window=4,
            ),
        )
        svc.admit("a", source=_blocks_source(3, seed=0))
        svc.admit("b", source=_blocks_source(3, seed=1))
        assert svc.status("b") == "queued"
        for _ in range(5):
            svc.run_tick()  # a drains; every tick misses -> gate holds b
        assert svc.finished["a"].reason == "exhausted"
        assert svc.status("b") == "queued" and svc.n_free == 1
        assert any(e.action == "gate" for e in svc.slo_events)
        # direct admission is gated too: a free slot exists, yet c queues
        assert svc.admit("c") is None
        assert svc.status("c") == "queued"
        popped = svc.pop_slo_events()
        assert popped and not svc.slo_events

    def test_scheduler_context_carries_miss_rate(self):
        svc = _mk_svc(S=1, slo=SLOPolicy(deadline_budget_s=1e-12))
        svc.admit("a", source=_blocks_source(2))
        svc.run_tick()
        assert svc._sched_ctx().deadline_miss_rate == 1.0

    def test_restore_resets_slo_telemetry(self, tmp_path):
        import json

        from repro.checkpoint.checkpointer import Checkpointer

        svc = _mk_svc(S=2, slo=SLOPolicy(deadline_budget_s=1e-12), max_queue=4)
        svc.admit("a", source=_blocks_source(8))
        for _ in range(3):
            svc.run_tick()
        assert svc.metrics["n_deadline_misses"] == 3
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        snap = json.loads(json.dumps(svc.lifecycle))
        svc2 = _mk_svc(S=2, slo=SLOPolicy(deadline_budget_s=1e-12), max_queue=4)
        svc2.restore(ckpt, lifecycle=snap)
        m = svc2.metrics
        assert m["n_deadline_misses"] == 0 and m["n_timed_ticks"] == 0
        assert svc2.session_stats("a")["queue_wait_s"] == 0.0


class TestRecording:
    def test_recording_source_taps_and_delegates(self):
        inner = _blocks_source(3)
        tap = RecordingSource(inner)
        # delegation: the tap is invisible to capability probes
        assert tap.position == inner.position
        assert tap.n_channels == inner.n_channels
        b0 = tap.next_block(P)
        assert b0.shape == (4, P) and len(tap.blocks) == 1
        np.testing.assert_array_equal(tap.blocks[0], b0)
        tap.next_block(P)
        tap.next_block(P)
        with pytest.raises(SourceExhausted):
            tap.next_block(P)
        assert tap.exhausted and len(tap.blocks) == 3

    def test_recorded_source_replays_verbatim(self):
        tap = RecordingSource(_blocks_source(2))
        blocks = [tap.next_block(P), tap.next_block(P)]
        rec = RecordedSource(np.stack(tap.blocks))
        np.testing.assert_array_equal(rec.next_block(P), blocks[0])
        np.testing.assert_array_equal(rec.next_block(P), blocks[1])
        with pytest.raises(SourceExhausted):
            rec.next_block(P)
        # no seek/cursor: replay is faithful to the served-block sequence
        assert not hasattr(rec, "seek") and not hasattr(rec, "position")

    def test_recorded_source_enforces_recorded_width(self):
        tap = RecordingSource(_blocks_source(1))
        tap.next_block(P)
        rec = RecordedSource(np.stack(tap.blocks))
        with pytest.raises(ValueError, match="recorded P"):
            rec.next_block(P + 1)

    def test_save_load_round_trip(self, tmp_path):
        taps = {
            "u1": RecordingSource(_blocks_source(3, seed=0)),
            "u2": RecordingSource(_blocks_source(2, seed=1)),
        }
        for _ in range(3):
            taps["u1"].next_block(P)
        for _ in range(2):
            taps["u2"].next_block(P)
        for tap in taps.values():
            with pytest.raises(SourceExhausted):
                tap.next_block(P)
        events = [
            {"action": "admit", "sid": "u1", "tick": 0, "order": 0},
            {"action": "admit", "sid": "u2", "tick": 1, "order": 1},
            {"action": "evict", "sid": "u1", "tick": 3},
        ]
        path = tmp_path / "trace.npz"
        save_recording(path, taps, events=events, meta={"P": P, "m": 4})
        rec = load_recording(path)
        assert set(rec.sources) == {"u1", "u2"}
        assert rec.sources["u1"].n_blocks == 3
        assert rec.sources["u2"].n_blocks == 2
        assert rec.sources["u1"].exhausted
        np.testing.assert_array_equal(
            rec.sources["u1"].next_block(P), taps["u1"].blocks[0]
        )
        assert rec.events == events
        assert rec.meta == {"P": P, "m": 4}


class TestReplay:
    @pytest.mark.parametrize("fused", [False, True])
    def test_replay_is_bit_identical_to_live_run(self, fused):
        """Record a live multi-session run (staggered admits, uneven feed
        lengths), then replay the trace into a fresh service: every per-tick
        output block and the eviction order must match exactly."""
        feeds = {
            "u1": (4, 0),
            "u2": (2, 1),  # drains first
            "u3": (3, 2),  # admitted at tick 1
        }
        taps = {
            sid: RecordingSource(_blocks_source(n, seed=seed))
            for sid, (n, seed) in feeds.items()
        }
        live = _mk_svc(S=2, fused=fused, max_queue=4)
        events = []
        live.admit("u1", source=taps["u1"])
        live.admit("u2", source=taps["u2"])
        events += [
            {"action": "admit", "sid": "u1", "tick": 0, "order": 0},
            {"action": "admit", "sid": "u2", "tick": 0, "order": 1},
        ]
        live_out = [live.run_tick()]
        live.admit("u3", source=taps["u3"])
        events.append({"action": "admit", "sid": "u3", "tick": 1, "order": 2})
        while live.n_active or live.n_queued:
            live_out.append(live.run_tick())
        events += [
            {"action": "evict", "sid": sid, "tick": rec.tick}
            for sid, rec in live.finished.items()
        ]
        assert all(r.reason == "exhausted" for r in live.finished.values())

        import os
        import tempfile

        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "trace.npz")
            save_recording(path, taps, events=events, meta={"P": P})
            rec = load_recording(path)

        fresh = _mk_svc(S=2, fused=fused, max_queue=4)
        replay_out = replay(fresh, rec)
        # same eviction order, reasons, and tick stamps
        assert list(fresh.finished) == list(live.finished)
        for sid in live.finished:
            assert fresh.finished[sid].reason == "exhausted"
            assert fresh.finished[sid].tick == live.finished[sid].tick
        # bit-identical separated outputs, tick for tick
        assert len(replay_out) >= len(live_out)
        for t, out in enumerate(live_out):
            assert set(replay_out[t]) == set(out)
            for sid in out:
                np.testing.assert_array_equal(
                    np.asarray(replay_out[t][sid]), np.asarray(out[sid])
                )
        assert all(not o for o in replay_out[len(live_out):])

    def test_replay_without_events_admits_everyone_at_tick_zero(self):
        taps = {"a": RecordingSource(_blocks_source(2))}
        taps["a"].next_block(P)
        taps["a"].next_block(P)
        rec = Recording(
            sources={"a": RecordedSource(np.stack(taps["a"].blocks))},
            events=[], meta={},
        )
        svc = _mk_svc(S=2)
        out = replay(svc, rec)
        assert "a" in out[0]
        assert svc.finished["a"].reason == "exhausted"

    def test_replay_rejects_unknown_session(self):
        rec = Recording(
            sources={},
            events=[{"action": "admit", "sid": "ghost", "tick": 0}],
            meta={},
        )
        with pytest.raises(ValueError, match="unrecorded"):
            replay(_mk_svc(), rec)
