"""Drift watchdog: detection, μ boost, park/probe/re-admit, and the
end-to-end converge → drift → re-adapt → re-converge regression.

Threshold calibration (seed 0, jax CPU, μ=3e-3, P=16): the converged conv
statistic jitters around a ≈0.017 mean (EMA-0.8 never above 0.024 over 250
ticks), while an abrupt 1.2 rad mixing rotation lifts the EMA past 0.032 —
so ``ConvergencePolicy(threshold=0.025)`` converges and
``DriftPolicy(retrigger=0.03)`` separates drift from jitter with margin on
both sides.  The checked-in Amari bars ride the same measurement: ≈0.01–0.03
at convergence, so 0.15 only trips on real regressions."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EASIConfig, SMBGDConfig, amari_index, ema_update, global_system
from repro.data.pipeline import MixedSignals
from repro.data.sources import ReplaySource, SourceExhausted, SyntheticSource
from repro.serve import (
    ConvergencePolicy,
    DriftMonitor,
    DriftPolicy,
    SeparationService,
)
from repro.stream import SeparatorBank

P = 16
# calibrated against the measured conv floor — see module docstring
CONV_POLICY = ConvergencePolicy(threshold=0.025, patience=5, min_ticks=50, ema=0.9)
DRIFT_POLICY = DriftPolicy(
    retrigger=0.03, patience=2, ema=0.8, cooldown=3, boost=4.0, boost_ticks=40,
    probe_every=5,
)
# checked-in e2e bars: converged Amari ≈0.01–0.03 in calibration runs
AMARI_CONVERGED = 0.15
JUMP_TICK = 400


def _svc(mode="boost", S=2, fused=False, max_queue=4, **kw):
    ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=3e-3, beta=0.9, gamma=0.5)
    return SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=S, fused=fused),
        seed=0,
        policy=CONV_POLICY,
        drift_policy=dataclasses.replace(DRIFT_POLICY, mode=mode),
        max_queue=max_queue,
        **kw,
    )


def _jump_source(seed=0, jump_tick=JUMP_TICK):
    """Stationary mixing, then an abrupt ≈1.2 rad rotation over 5 blocks at
    ``jump_tick``, then stationary again — the distribution-shift drill."""
    pipe = MixedSignals(m=4, n=2, batch=P, seed=seed, drift_rate=1.2 / (5 * P))
    return SyntheticSource(pipe, drift_start=jump_tick, drift_stop=jump_tick + 5)


def _amari(svc, sid, src):
    B = svc.bank.slot_state(svc.state, svc.sessions[sid]).B
    return float(amari_index(global_system(B, jnp.asarray(src.true_mixing()))))


class TestDriftMonitor:
    def test_ema_matches_metrics_ema_update(self):
        pol = DriftPolicy(retrigger=0.5, patience=1, ema=0.7, cooldown=0)
        mon = DriftMonitor()
        smoothed = jnp.asarray(float("inf"))
        for x in (0.1, 0.4, 0.9, 0.2):
            mon.update(x, pol)
            smoothed = ema_update(smoothed, x, pol.ema)
            np.testing.assert_allclose(mon.stat, float(smoothed), rtol=1e-6)

    def test_cooldown_then_patience(self):
        pol = DriftPolicy(retrigger=0.1, patience=2, cooldown=2)
        mon = DriftMonitor()
        # above threshold from the start — cooldown must absorb the first 2
        assert mon.update(0.5, pol) is False  # cooldown 1
        assert mon.update(0.5, pol) is False  # cooldown 2
        assert mon.update(0.5, pol) is False  # patience 1
        assert mon.update(0.5, pol) is True  # patience 2 → fire
        # a dip resets the consecutive counter
        mon2 = DriftMonitor(seen=10)
        assert mon2.update(0.5, pol) is False
        assert mon2.update(0.01, pol) is False
        assert mon2.update(0.5, pol) is False
        assert mon2.update(0.5, pol) is True

    def test_policy_validation(self):
        for kw in (
            dict(mode="explode"),
            dict(patience=0),
            dict(ema=1.0),
            dict(retrigger=0.0),
            dict(boost=0.0),
            dict(probe_every=0),
            dict(probe_batch=-1),
        ):
            with pytest.raises(ValueError):
                DriftPolicy(**kw)

    def test_drift_policy_requires_convergence_policy(self):
        ecfg = EASIConfig(n_components=2, n_features=4)
        with pytest.raises(ValueError, match="ConvergencePolicy"):
            SeparationService(
                SeparatorBank(ecfg, SMBGDConfig(batch_size=P), n_streams=1),
                drift_policy=DriftPolicy(),
            )


class TestBoostLifecycle:
    def test_converged_session_stays_hot_and_served(self):
        svc = _svc("boost")
        svc.admit("u", source=_jump_source())
        for _ in range(80):
            svc.run_tick()
        assert svc.status("u") == "converged"  # hot, not evicted
        assert svc.metrics["n_hot"] == 1 and svc.metrics["n_evicted"] == 0
        ticks_before = svc.session_stats("u")["ticks"]
        svc.run_tick()
        assert svc.session_stats("u")["ticks"] == ticks_before + 1  # still fed

    def test_drift_fires_boost_and_reconverges(self):
        events = []
        svc = _svc("boost", on_drift=lambda sid, ev: events.append((sid, ev)))
        src = _jump_source()
        svc.admit("u", source=src)
        for _ in range(JUMP_TICK - 1):
            svc.run_tick()
        assert svc.status("u") == "converged"
        pi_pre = _amari(svc, "u", src)
        assert pi_pre < AMARI_CONVERGED
        fired_at = None
        for t in range(JUMP_TICK - 1, JUMP_TICK + 500):
            svc.run_tick()
            if events and fired_at is None:
                fired_at = t
                slot = svc.sessions["u"]
                assert svc._boost_scale[slot] == DRIFT_POLICY.boost  # μ boosted
                assert svc.status("u") == "active"  # re-earning convergence
        assert fired_at is not None and fired_at < JUMP_TICK + 40
        (sid, ev), = events[:1]
        assert sid == "u" and ev.action == "boost" and ev.stat > DRIFT_POLICY.retrigger
        # boost expired and the session re-converged on the NEW mixing
        assert svc.status("u") == "converged"
        assert svc._boost_scale[svc.sessions["u"]] == 1.0
        assert _amari(svc, "u", src) < AMARI_CONVERGED
        assert svc.metrics["n_drift_events"] == len(events) == 1

    def test_hot_session_preempted_by_new_admission(self):
        svc = _svc("boost", S=1)
        svc.admit("u", source=_jump_source())
        for _ in range(80):
            svc.run_tick()
        assert svc.status("u") == "converged"
        slot = svc.admit("newcomer")
        assert slot is not None  # hot session preempted, not queued
        assert svc.status("u") == "finished"
        assert svc.finished["u"].reason == "preempted"

    def test_capacity_pressure_beats_warmth(self):
        """With sessions queued, a converging session evicts instead of going
        hot — warmth never starves the queue."""
        svc = _svc("boost", S=1, max_queue=2)
        svc.admit("u", source=_jump_source())
        svc.admit("waiting")
        for _ in range(80):
            svc.run_tick()
            if svc.status("u") == "finished":
                break
        assert svc.status("u") == "finished"
        assert svc.finished["u"].reason == "converged"
        assert svc.status("waiting") == "active"

    @pytest.mark.parametrize("fused", [False, True])
    def test_boost_changes_trajectory_no_retrace(self, fused):
        """The μ boost must actually reach the kernel: after a forced boost,
        the boosted service's state diverges from an unboosted clone within
        one tick (per-stream hyperparam rows as traced operands)."""
        svc_a = _svc("boost", fused=fused)
        svc_b = _svc("boost", fused=fused)
        src_a, src_b = _jump_source(), _jump_source()
        svc_a.admit("u", source=src_a)
        svc_b.admit("u", source=src_b)
        for _ in range(10):
            svc_a.run_tick()
            svc_b.run_tick()
        # force a boost on A only (white-box: what _fire_boost applies)
        slot = svc_a.sessions["u"]
        svc_a._boost_scale[slot] = 4.0
        svc_a._boost_left["u"] = 5
        svc_a.run_tick()
        svc_b.run_tick()
        Ba = np.asarray(svc_a.bank.slot_state(svc_a.state, slot).B)
        Bb = np.asarray(svc_b.bank.slot_state(svc_b.state, svc_b.sessions["u"]).B)
        assert not np.allclose(Ba, Bb)


class TestWatchdogEdgeCases:
    """Regression coverage for the review findings: boost cleanup on
    re-convergence, preemption eligibility, and backpressure during
    re-admission."""

    def test_boost_cleared_when_reconverging_hot(self):
        """A session that re-converges to HOT while its boost is still
        counting down must return to base μ — the boost must not ride the
        hot state (or lifecycle snapshots) forever."""
        svc = _svc("boost")
        svc.drift_policy = dataclasses.replace(
            DRIFT_POLICY, boost=1.2, boost_ticks=10_000
        )
        src = _jump_source()
        svc.admit("u", source=src)
        for _ in range(10):
            svc.run_tick()
        slot = svc.sessions["u"]
        # white-box: engage a mild boost that cannot expire by countdown
        svc._boost_scale[slot] = 1.2
        svc._boost_left["u"] = 10_000
        svc._monitors["u"] = type(svc._monitors["u"])()
        for _ in range(120):
            svc.run_tick()
            if svc.status("u") == "converged":
                break
        assert svc.status("u") == "converged"
        assert "u" not in svc._boost_left
        assert svc._boost_scale[slot] == 1.0
        assert svc.lifecycle["boost"] == {}

    def test_gated_admission_does_not_preempt_hot(self):
        """A quota-gated admission cannot take the slot, so it must not cost
        a hot session its warmth (it queues; the separator stays warm)."""
        from repro.serve import PriorityScheduler

        ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
        ocfg = SMBGDConfig(batch_size=P, mu=3e-3, beta=0.9, gamma=0.5)
        svc = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=1),
            seed=0,
            policy=CONV_POLICY,
            drift_policy=DRIFT_POLICY,
            scheduler=PriorityScheduler(max_queue=2, quotas={"acme": 0}),
        )
        svc.admit("warm", source=_jump_source())
        for _ in range(80):
            svc.run_tick()
        assert svc.status("warm") == "converged"  # hot
        assert svc.admit("gated", tenant="acme") is None  # queued, not placed
        assert svc.status("warm") == "converged"  # still warm: no preemption
        assert svc.n_free == 0
        # an eligible admission DOES preempt (warmth yields to usable work)
        assert svc.admit("eligible") is not None
        assert svc.finished["warm"].reason == "preempted"

    def test_probe_seeks_to_live_edge_of_finite_source(self):
        """Near the end of a finite feed the probe clamps its skip to the
        last full block — it must measure the present, not a window from
        (probe_every−1) ticks ago."""
        from repro.core import smbgd as smbgd_lib
        from repro.serve import DriftMonitor, ParkedSession, SessionMeta
        from repro.serve.engine import EvictionRecord, SessionStats

        svc = _svc("readmit")
        X = np.zeros((40, 4), np.float32)  # 2.5 blocks of P=16
        src = ReplaySource(X)
        frozen = smbgd_lib.init_state(svc.bank.easi, jax.random.PRNGKey(0))
        svc._parked["p"] = ParkedSession(
            record=EvictionRecord(
                state=frozen, stats=SessionStats(admitted_at=0.0),
                monitor=None, reason="converged", tick=0,
            ),
            source=src, monitor=DriftMonitor(), meta=SessionMeta(),
        )
        for _ in range(DRIFT_POLICY.probe_every):
            svc._probe_parked()
        # skip would be 64 > 40−16: clamped to 24, probed [24:40] — the edge
        assert src.position == 40

    def test_readmit_backs_off_under_contention(self):
        """A drifted parked session whose re-admission would only QUEUE stays
        parked instead (a queued warm-start would be un-snapshotable pending
        state) and re-admits warm once a slot actually frees."""
        svc = _svc("readmit", S=1, max_queue=2)
        src = _jump_source()
        svc.admit("u", source=src)
        for _ in range(80):
            svc.run_tick()
        assert svc.status("u") == "parked"
        svc.admit("blocker")  # holds the only slot; no source → never served
        for _ in range(JUMP_TICK):
            svc.run_tick()
        # drift long since visible to the probes, but no slot to take
        assert svc.status("u") == "parked"
        assert svc.n_queued == 0 and not svc.drift_events
        svc.evict("blocker")
        for _ in range(3 * DRIFT_POLICY.probe_every):
            svc.run_tick()
        assert svc.status("u") == "active"  # warm re-admission went through
        assert [e.action for e in svc.drift_events] == ["readmit"]
        assert int(svc.bank.slot_state(svc.state, svc.sessions["u"]).step) > 0


class TestReadmitLifecycle:
    def test_converged_session_parks_with_its_source(self):
        svc = _svc("readmit")
        svc.admit("u", source=_jump_source())
        for _ in range(80):
            svc.run_tick()
        assert svc.status("u") == "parked"
        assert svc.metrics["n_parked"] == 1
        assert svc.n_free == 2  # the slot was really freed
        assert "u" not in svc.finished  # parked ≠ finished

    def test_probe_detects_drift_and_readmits_warm(self):
        events = []
        svc = _svc("readmit", on_drift=lambda sid, ev: events.append(ev))
        src = _jump_source()
        svc.admit("u", source=src)
        parked_state = None
        readmit_at = None
        for t in range(JUMP_TICK + 120):
            svc.run_tick()
            if svc.status("u") == "parked" and parked_state is None:
                parked_state = svc.parked["u"].record.state
            if svc.status("u") == "active" and readmit_at is None and parked_state is not None:
                readmit_at = t
                # warm start: the slot carries the frozen separator onward,
                # step counter included (no γ re-gate)
                st = svc.bank.slot_state(svc.state, svc.sessions["u"])
                assert int(st.step) > 0
        assert parked_state is not None
        assert readmit_at is not None and readmit_at >= JUMP_TICK
        assert [e.action for e in events] == ["readmit"]
        # probes ran at service time: the source skipped ahead while parked
        assert src.position >= JUMP_TICK * P

    def test_full_cycle_reconverges_and_reparks(self):
        # Amari confirmation (against the source's LIVE mixing — no
        # set_mixing call) vetoes parking until the session truly separates,
        # so the re-parked separator is genuinely re-converged
        svc = _svc("readmit")
        svc.policy = dataclasses.replace(CONV_POLICY, amari_threshold=0.1)
        src = _jump_source()
        svc.admit("u", source=src)
        for _ in range(JUMP_TICK + 400):
            svc.run_tick()
        # drift → warm re-admission → re-convergence → parked again
        assert svc.status("u") == "parked"
        assert svc.metrics["n_drift_events"] == 1
        B = svc.parked["u"].record.state.B
        pi = float(amari_index(global_system(B, jnp.asarray(src.true_mixing()))))
        assert pi < AMARI_CONVERGED

    def test_exhausted_parked_source_finishes(self):
        svc = _svc("readmit")
        # enough for convergence (~55 ticks) plus a few probes, then dry
        X = np.asarray(
            jax.random.normal(jax.random.PRNGKey(0), (70 * P, 4)), np.float32
        )
        svc.admit("u", source=ReplaySource(X))
        for _ in range(200):
            svc.run_tick()
            if svc.status("u") == "finished":
                break
        assert svc.status("u") == "finished"
        # the bugfix: a probe-time drain is an EVICTION with the honest
        # reason, not a silently-finished "converged" record
        assert svc.finished["u"].reason == "exhausted"

    @pytest.mark.parametrize("probe_batch", [0, 4])
    def test_probe_exhaustion_never_escapes_run_tick(self, probe_batch):
        """Regression (both probe engines): SourceExhausted raised by a
        parked source mid-probe must turn into an eviction with reason
        "exhausted" inside run_tick — on_evict observes it, n_evicted counts
        it, and the exception never reaches the caller."""
        from repro.serve import DriftMonitor, ParkedSession, SessionMeta
        from repro.serve.engine import EvictionRecord, SessionStats

        events = []
        svc = _svc("readmit", on_evict=lambda sid, rec: events.append((sid, rec.reason)))
        svc.drift_policy = dataclasses.replace(
            DRIFT_POLICY, mode="readmit", probe_every=1, probe_batch=probe_batch
        )
        from repro.core import smbgd as smbgd_lib

        frozen = smbgd_lib.init_state(svc.bank.easi, jax.random.PRNGKey(0))
        svc._parked["dry"] = ParkedSession(
            record=EvictionRecord(
                state=frozen, stats=SessionStats(admitted_at=0.0),
                monitor=None, reason="converged", tick=0,
            ),
            source=ReplaySource(np.zeros((P - 1, 4), np.float32)),  # < one block
            monitor=DriftMonitor(), meta=SessionMeta(),
        )
        evicted_before = svc.metrics["n_evicted"]
        svc.run_tick()  # must not raise
        assert svc.status("dry") == "finished"
        assert svc.finished["dry"].reason == "exhausted"
        assert events == [("dry", "exhausted")]
        assert svc.metrics["n_evicted"] == evicted_before + 1

    def test_manual_evict_unparks(self):
        svc = _svc("readmit")
        svc.admit("u", source=_jump_source())
        for _ in range(80):
            svc.run_tick()
        assert svc.status("u") == "parked"
        final = svc.evict("u")
        assert final.B.shape == (2, 4)
        assert svc.status("u") == "finished"
        with pytest.raises(ValueError, match="parked"):
            # (a fresh park, then admitting the parked id is refused)
            svc2 = _svc("readmit")
            svc2.admit("u", source=_jump_source())
            for _ in range(80):
                svc2.run_tick()
            svc2.admit("u")


class TestRunTickIngestion:
    """The pull loop itself (independent of drift)."""

    def test_pull_matches_push(self):
        """run_tick over a bound source == step() fed the same blocks."""
        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)
        pull = SeparationService(SeparatorBank(ecfg, ocfg, n_streams=2), seed=0)
        push = SeparationService(SeparatorBank(ecfg, ocfg, n_streams=2), seed=0)
        pipe = MixedSignals(m=4, n=2, batch=P, seed=3)
        pull.admit("u", source=SyntheticSource(pipe))
        push.admit("u")
        feed = SyntheticSource(pipe)
        for _ in range(5):
            o_pull = pull.run_tick()
            o_push = push.step({"u": feed.next_block(P).T})
            np.testing.assert_allclose(
                np.asarray(o_pull["u"]), np.asarray(o_push["u"]), rtol=1e-6
            )

    def test_sourceless_sessions_skipped(self):
        svc = _svc("boost")
        svc.admit("manual")  # no source: push-mode session
        assert svc.run_tick() == {}
        assert svc.session_stats("manual")["ticks"] == 0

    def test_exhausted_source_evicts_with_reason(self):
        svc = _svc("boost")
        X = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (3 * P, 4)))
        svc.admit("u", source=ReplaySource(X))
        for _ in range(4):
            svc.run_tick()
        assert svc.status("u") == "finished"
        assert svc.finished["u"].reason == "exhausted"
        assert svc.finished["u"].stats.ticks == 3

    def test_wrong_channel_count_degrades_not_raises(self):
        """A misshapen block is a per-session fault, not a launch failure:
        the session skips the tick (degraded) and the error is surfaced in
        ``last_faults`` — other sessions keep being served."""
        svc = _svc("boost")
        svc.admit("u", source=ReplaySource(np.zeros((64, 3), np.float32)))
        svc.admit("ok", source=_jump_source(seed=1))
        out = svc.run_tick()
        assert "u" not in out and "ok" in out
        assert svc.metrics["n_degraded_ticks"] == 1
        assert "block shape" in svc.last_faults["u"]
        assert svc.session_stats("u")["ticks"] == 0

    def test_bind_source_after_admit(self):
        svc = _svc("boost")
        svc.admit("u")
        svc.bind_source("u", ReplaySource(np.zeros((P, 4), np.float32)))
        out = svc.run_tick()
        assert out["u"].shape == (P, 2)
        with pytest.raises(KeyError):
            svc.bind_source("ghost", ReplaySource(np.zeros((P, 4), np.float32)))


@pytest.mark.parametrize("fused", [False, True])
def test_e2e_drift_regression(fused):
    """The acceptance path, on both the vmap bank and the megakernel: a
    session served via run_tick converges under a stationary mixing, the
    mixing jumps, the watchdog flags it (DriftEvent), the μ boost re-adapts
    it, and it re-converges — final Amari within the checked-in threshold."""
    svc = _svc("boost", fused=fused)
    src = _jump_source()
    svc.admit("u", source=src)
    seen_converged = seen_drift = False
    for _ in range(JUMP_TICK + 500):
        svc.run_tick()
        seen_converged = seen_converged or svc.status("u") == "converged"
        seen_drift = seen_drift or bool(svc.drift_events)
    assert seen_converged, "never converged pre-drift"
    assert seen_drift, "watchdog never fired"
    assert svc.drift_events[0].action == "boost"
    assert svc.status("u") == "converged", "did not re-converge after drift"
    pi = _amari(svc, "u", src)
    assert pi < AMARI_CONVERGED, f"stale separator after drift: Amari {pi:.4f}"
