"""Batched out-of-band drift probing.

The batched probe engine stacks the frozen states of all due parked sessions
into a transient probe bank and computes every virtual conv statistic in one
no-commit launch (O(parked / probe_batch) dispatches).  The PR-4 sequential
loop survives behind ``DriftPolicy(probe_batch=0)`` as the oracle, and the
differential property sweep here proves the two engines produce identical
virtual conv statistics, DriftEvents and readmit decisions across random
ragged park populations on both the vmap and megakernel bank paths.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EASIConfig, SMBGDConfig
from repro.core import smbgd as smbgd_lib
from repro.data.sources import ReplaySource
from repro.serve import (
    ConvergencePolicy,
    DriftMonitor,
    DriftPolicy,
    ParkedSession,
    SeparationService,
    SessionMeta,
)
from repro.serve.engine import EvictionRecord, SessionStats
from repro.stream import SeparatorBank
from _hypothesis_compat import given, settings, st

P = 8


def _park(svc, sid, state, source, order=0):
    """White-box park injection: the probe engines only read ParkedSession
    fields, so parking directly (instead of converging a served session)
    keeps the sweep fast without changing what is under test."""
    svc._parked[sid] = ParkedSession(
        record=EvictionRecord(
            state=state,
            stats=SessionStats(admitted_at=0.0),
            monitor=None,
            reason="converged",
            tick=0,
        ),
        source=source,
        monitor=DriftMonitor(),
        meta=SessionMeta(order=order),
    )


def _mk_svc(
    m, n, fused, probe_batch, retrigger, S=3, seed=0, probe_every=2,
    probe_phases=1,
):
    ecfg = EASIConfig(n_components=n, n_features=m, mu=2e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)
    return SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=S, fused=fused),
        seed=seed,
        policy=ConvergencePolicy(threshold=0.025),
        drift_policy=DriftPolicy(
            mode="readmit",
            retrigger=retrigger,
            patience=1,
            ema=0.6,
            cooldown=1,
            probe_every=probe_every,
            probe_batch=probe_batch,
            probe_phases=probe_phases,
        ),
        max_queue=2,
    )


def _populate(svc, k, data_seed):
    """Park ``k`` sessions with deterministic frozen states and looping
    replay feeds — identical across services built with the same seed."""
    m = svc.bank.easi.n_features
    keys = jax.random.split(jax.random.PRNGKey(data_seed), max(k, 2))
    for i in range(k):
        st_i = smbgd_lib.init_state(svc.bank.easi, keys[i])._replace(
            step=jnp.asarray(i % 3, jnp.int32)
        )
        rng = np.random.default_rng(1000 * data_seed + i)
        X = rng.standard_normal((32 * P, m)).astype(np.float32)
        _park(svc, f"p{i}", st_i, ReplaySource(X, loop=True), order=i)


class TestBankProbeMode:
    """The no-commit probe step itself (stream/bank.py + the megakernel's
    freeze-only fast path)."""

    @pytest.mark.parametrize("fused", [False, True])
    def test_probe_matches_step_conv(self, fused):
        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)
        bank = SeparatorBank(ecfg, ocfg, n_streams=4, fused=fused)
        state = bank.init(jax.random.PRNGKey(0))
        X = jax.random.normal(jax.random.PRNGKey(1), (4, P, 4))
        stepped, _ = bank.step(state, X)
        conv, health, _mom = bank.probe(state, X)
        np.testing.assert_allclose(
            np.asarray(conv), np.asarray(stepped.conv), rtol=1e-5, atol=1e-6
        )
        np.testing.assert_array_equal(np.asarray(health), np.zeros((4,), np.int32))

    @pytest.mark.parametrize("fused", [False, True])
    def test_probe_never_mutates_and_masks_inactive(self, fused):
        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)
        bank = SeparatorBank(ecfg, ocfg, n_streams=4, fused=fused)
        state = bank.init(jax.random.PRNGKey(0))
        before = jax.tree.map(np.asarray, state._asdict())
        X = jax.random.normal(jax.random.PRNGKey(1), (4, P, 4))
        conv, _health, _mom = bank.probe(
            state, X, active=jnp.asarray([1, 0, 1, 0], jnp.int32)
        )
        conv = np.asarray(conv)
        # inactive lanes carry the previous statistic (+inf = never measured)
        assert np.isfinite(conv[0]) and np.isfinite(conv[2])
        assert np.isinf(conv[1]) and np.isinf(conv[3])
        for k, v in state._asdict().items():
            np.testing.assert_array_equal(np.asarray(v), before[k])

    def test_unstack_states_inverts_stack(self):
        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)
        bank = SeparatorBank(ecfg, ocfg, n_streams=3, fused=True)
        state = bank.init(jax.random.PRNGKey(0))  # padded layout
        subs = bank.unstack_states(state)
        assert len(subs) == 3 and subs[0].B.shape == (2, 4)  # logical shapes
        restacked = bank.pad_state(SeparatorBank.stack_states(subs))
        np.testing.assert_array_equal(
            np.asarray(restacked.B), np.asarray(state.B)
        )
        np.testing.assert_array_equal(
            np.asarray(restacked.H_hat), np.asarray(state.H_hat)
        )
        np.testing.assert_array_equal(
            np.asarray(restacked.step), np.asarray(state.step)
        )


class TestProbeEngine:
    """The serving layer's batched due-batch assembly."""

    def test_launch_economics(self):
        """10 parked sessions, probe_batch=4 → 3 launches (the O(parked /
        batch) contract) vs 10 sequential dispatches."""
        bat = _mk_svc(4, 2, False, probe_batch=4, retrigger=1e9, probe_every=1)
        seq = _mk_svc(4, 2, False, probe_batch=0, retrigger=1e9, probe_every=1)
        for svc in (bat, seq):
            _populate(svc, 10, data_seed=3)
            svc.run_tick()
        assert bat.metrics["n_probes"] == seq.metrics["n_probes"] == 10
        assert seq.metrics["n_probe_launches"] == 10
        assert bat.metrics["n_probe_launches"] == math.ceil(10 / 4)

    def test_ragged_chunks_share_pow2_programs(self):
        """Ragged due batches land on power-of-two probe-bank widths, so the
        width cache stays logarithmic in probe_batch."""
        svc = _mk_svc(4, 2, False, probe_batch=8, retrigger=1e9, probe_every=1)
        _populate(svc, 11, data_seed=5)  # chunks of 8 + 3 → widths 8 and 4
        svc.run_tick()
        assert sorted(svc._probe_banks) == [4, 8]
        assert svc.metrics["n_probe_launches"] == 2
        # shrinking population reuses cached widths — no new programs
        for sid in [f"p{i}" for i in range(6)]:
            svc.evict(sid)
        svc.run_tick()
        assert sorted(svc._probe_banks) == [4, 8]

    def test_probe_exhaustion_evicts_with_reason(self):
        """Satellite bugfix: a parked source draining during a probe must
        evict the session with reason "exhausted" inside run_tick — never
        escape it, never mislabel the record as "converged"."""
        records = []
        svc = _mk_svc(4, 2, False, probe_batch=4, retrigger=1e9, probe_every=1)
        svc.on_evict = lambda sid, rec: records.append((sid, rec.reason))
        frozen = smbgd_lib.init_state(svc.bank.easi, jax.random.PRNGKey(0))
        # fewer than one block left: the very first probe pull drains it
        _park(svc, "dry", frozen, ReplaySource(np.zeros((P - 1, 4), np.float32)))
        _populate(svc, 2, data_seed=9)  # healthy neighbours keep probing
        svc.run_tick()  # must not raise
        assert svc.status("dry") == "finished"
        assert svc.finished["dry"].reason == "exhausted"
        assert records == [("dry", "exhausted")]
        assert svc.metrics["n_parked"] == 2  # neighbours unaffected
        assert svc.metrics["n_probes"] == 2  # drained session never probed


def _run_pair(k, m, n, fused, fire, probe_batch, ticks=6, probe_phases=1):
    retrigger = 1e-9 if fire else 1e9
    seq = _mk_svc(
        m, n, fused, probe_batch=0, retrigger=retrigger,
        probe_phases=probe_phases,
    )
    bat = _mk_svc(
        m, n, fused, probe_batch=probe_batch, retrigger=retrigger,
        probe_phases=probe_phases,
    )
    for svc in (seq, bat):
        _populate(svc, k, data_seed=k * 13 + m + 3 * n)
    for _ in range(ticks):
        seq.run_tick()
        bat.run_tick()
    return seq, bat


@pytest.mark.property
class TestDifferentialProbe:
    """Batched probe ≡ PR-4 sequential probe, across random ragged park
    populations (1..S+7 parked), mixed (m, n) shapes (exercising the padded
    probe-bank geometry) and both bank execution paths."""

    @given(
        k=st.integers(1, 10),
        shape=st.sampled_from([(4, 2), (5, 3), (6, 2)]),
        fused=st.sampled_from([False, True]),
        fire=st.sampled_from([True, False]),
        probe_batch=st.sampled_from([1, 3, 4, 8]),
    )
    @settings(max_examples=15, deadline=None)
    def test_batched_matches_sequential(self, k, shape, fused, fire, probe_batch):
        m, n = shape
        seq, bat = _run_pair(k, m, n, fused, fire, probe_batch)
        sids = [f"p{i}" for i in range(k)]
        # identical readmit decisions: same lifecycle states, same slots
        for sid in sids:
            assert seq.status(sid) == bat.status(sid), sid
        assert seq.sessions == bat.sessions
        assert set(seq.parked) == set(bat.parked)
        # identical DriftEvents (who fired, what happened, where they landed)
        ev_s = [(e.session_id, e.action, e.slot, e.tick) for e in seq.drift_events]
        ev_b = [(e.session_id, e.action, e.slot, e.tick) for e in bat.drift_events]
        assert ev_s == ev_b
        for es, eb in zip(seq.drift_events, bat.drift_events):
            np.testing.assert_allclose(es.stat, eb.stat, rtol=1e-4, atol=1e-6)
        # identical virtual conv statistics folded into the monitors
        for sid, ps in seq.parked.items():
            mb = bat.parked[sid].monitor
            assert ps.monitor.seen == mb.seen
            assert ps.monitor.above == mb.above
            np.testing.assert_allclose(
                ps.monitor.stat, mb.stat, rtol=1e-4, atol=1e-6
            )
        # probes advanced every source to the same service time
        for sid, ps in seq.parked.items():
            if bat.parked[sid].source is not None:
                assert ps.source.position == bat.parked[sid].source.position
        # the whole point: fewer launches, same probes (probe_batch=1 chunks
        # one session per launch — no win, but still the batched code path)
        assert seq.metrics["n_probes"] == bat.metrics["n_probes"]
        if k > probe_batch > 1:
            assert (
                bat.metrics["n_probe_launches"]
                < seq.metrics["n_probe_launches"]
            )

    @given(
        k=st.integers(1, 7),
        fire=st.sampled_from([True, False]),
    )
    @settings(max_examples=8, deadline=None)
    def test_differential_with_served_traffic(self, k, fire):
        """The equivalence holds with live sessions sharing run_tick: served
        traffic, parked probes and readmissions interleave identically."""
        retrigger = 1e-9 if fire else 1e9
        seq = _mk_svc(4, 2, False, probe_batch=0, retrigger=retrigger)
        bat = _mk_svc(4, 2, False, probe_batch=2, retrigger=retrigger)
        for svc in (seq, bat):
            _populate(svc, k, data_seed=17 * k)
            rng = np.random.default_rng(99)
            X = rng.standard_normal((64 * P, 4)).astype(np.float32)
            svc.admit("live", source=ReplaySource(X, loop=True))
        for _ in range(6):
            o_s = seq.run_tick()
            o_b = bat.run_tick()
            assert set(o_s) == set(o_b)
            for sid in o_s:
                np.testing.assert_allclose(
                    np.asarray(o_s[sid]), np.asarray(o_b[sid]), rtol=1e-5,
                    atol=1e-6,
                )
        assert seq.sessions == bat.sessions
        for sid in [f"p{i}" for i in range(k)] + ["live"]:
            assert seq.status(sid) == bat.status(sid)


class TestStaggeredProbe:
    """``DriftPolicy.probe_phases``: hash-staggered parked probing.  Each
    parked session keeps a fixed ``probe_every * probe_phases`` probe period;
    only which run_tick serves it changes."""

    def test_phase_hash_stable_partition(self):
        """The bucket hash is deterministic, in range, and identical across
        services (it must survive checkpoint/restore and process restarts —
        that is why it is crc32, not the salted builtin ``hash``)."""
        sids = [f"p{i}" for i in range(20)] + [("tuple", 3), 42]
        for phases in (1, 2, 3, 5):
            buckets = [SeparationService._probe_phase(s, phases) for s in sids]
            assert all(0 <= b < phases for b in buckets)
            assert buckets == [
                SeparationService._probe_phase(s, phases) for s in sids
            ]
        # a real spread: 20 sids over 3 buckets should not all collide
        assert len({SeparationService._probe_phase(s, 3) for s in sids}) > 1

    def test_phases_one_matches_default_policy(self):
        """``probe_phases=1`` is bit-for-bit today's everyone-at-once sweep
        (the field defaults to 1, so legacy policies are unchanged)."""
        explicit = _mk_svc(4, 2, False, probe_batch=0, retrigger=1e9,
                           probe_phases=1)
        legacy = SeparationService(
            SeparatorBank(
                EASIConfig(n_components=2, n_features=4, mu=2e-3),
                SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5),
                n_streams=3,
            ),
            seed=0,
            policy=ConvergencePolicy(threshold=0.025),
            drift_policy=DriftPolicy(
                mode="readmit", retrigger=1e9, patience=1, ema=0.6,
                cooldown=1, probe_every=2, probe_batch=0,
            ),
            max_queue=2,
        )
        for svc in (explicit, legacy):
            _populate(svc, 5, data_seed=7)
        for _ in range(6):
            explicit.run_tick()
            legacy.run_tick()
        assert explicit.metrics["n_probes"] == legacy.metrics["n_probes"]
        for sid, ps in explicit.parked.items():
            lp = legacy.parked[sid]
            assert ps.monitor.seen == lp.monitor.seen
            np.testing.assert_allclose(ps.monitor.stat, lp.monitor.stat)
            assert ps.source.position == lp.source.position

    def test_full_cycle_probes_every_session_once(self):
        """Over one full cycle (probe_every × probe_phases run_ticks) every
        parked session is probed exactly once — no sid starved, none doubled."""
        svc = _mk_svc(4, 2, False, probe_batch=0, retrigger=1e9,
                      probe_every=2, probe_phases=3)
        k = 9
        _populate(svc, k, data_seed=11)
        for cycle in (1, 2):
            for _ in range(2 * 3):
                svc.run_tick()
            for sid, ps in svc.parked.items():
                assert ps.monitor.seen == cycle, sid
            assert svc.metrics["n_probes"] == cycle * k

    def test_staggered_equals_slow_sweep_after_full_cycles(self):
        """A (probe_every=2, probe_phases=3) schedule gives each session the
        IDENTICAL per-session probe trajectory as a legacy (probe_every=6)
        sweep — same blocks pulled (the seek skips the whole 6-tick gap),
        same virtual conv stats, same cursor — only the serving tick differs."""
        slow = _mk_svc(4, 2, False, probe_batch=0, retrigger=1e9,
                       probe_every=6, probe_phases=1)
        stag = _mk_svc(4, 2, False, probe_batch=0, retrigger=1e9,
                       probe_every=2, probe_phases=3)
        for svc in (slow, stag):
            _populate(svc, 7, data_seed=23)
        for _ in range(12):  # two full cycles of either schedule
            slow.run_tick()
            stag.run_tick()
        assert slow.metrics["n_probes"] == stag.metrics["n_probes"]
        for sid, ps in slow.parked.items():
            sp = stag.parked[sid]
            assert ps.monitor.seen == sp.monitor.seen == 2
            np.testing.assert_allclose(
                ps.monitor.stat, sp.monitor.stat, rtol=1e-5, atol=1e-7
            )
            assert ps.source.position == sp.source.position

    @pytest.mark.property
    @given(
        k=st.integers(1, 10),
        probe_phases=st.sampled_from([2, 3]),
        probe_batch=st.sampled_from([2, 4]),
        fire=st.sampled_from([True, False]),
    )
    @settings(max_examples=10, deadline=None)
    def test_batched_matches_sequential_staggered(
        self, k, probe_phases, probe_batch, fire
    ):
        """The batched ≡ sequential differential contract survives
        staggering: both engines see the same due bucket per probe tick."""
        seq, bat = _run_pair(
            k, 4, 2, False, fire, probe_batch, ticks=2 * 3 * 2,
            probe_phases=probe_phases,
        )
        for sid in [f"p{i}" for i in range(k)]:
            assert seq.status(sid) == bat.status(sid), sid
        assert seq.sessions == bat.sessions
        ev_s = [(e.session_id, e.action, e.slot, e.tick) for e in seq.drift_events]
        ev_b = [(e.session_id, e.action, e.slot, e.tick) for e in bat.drift_events]
        assert ev_s == ev_b
        for sid, ps in seq.parked.items():
            mb = bat.parked[sid].monitor
            assert ps.monitor.seen == mb.seen
            np.testing.assert_allclose(
                ps.monitor.stat, mb.stat, rtol=1e-4, atol=1e-6
            )
            if bat.parked[sid].source is not None:
                assert ps.source.position == bat.parked[sid].source.position
        assert seq.metrics["n_probes"] == bat.metrics["n_probes"]
