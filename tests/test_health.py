"""In-kernel health telemetry: word semantics, commit refusal, shadows.

The fault-containment contract at the kernel/bank layers: every step reports
a per-stream int32 health word (non-finite B′/Ĥ′/Y bits + the blow-up flag)
computed as one more in-register reduction beside ``conv``; a bad word means
the kernel REFUSED the commit (the slot keeps its pre-tick state, exactly
like an active-mask freeze); the fused megakernel, the vmap path and the
naive ref oracle agree bit-for-bit on the verdicts.  On top of that sit the
service's shadow-snapshot helpers (``update_shadow`` / ``restore_slot`` /
``copy_slot``) and the NaN-saturating monitor recurrences the escalation
ladder consumes.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EASIConfig, SMBGDConfig, ema_update
from repro.kernels.easi_gradient import ops as easi_ops
from repro.kernels.easi_gradient.ref import health_word_ref, smbgd_step_bank_ref
from repro.serve import ConvergencePolicy, DriftPolicy, HealthMonitor, HealthPolicy
from repro.serve.drift import DriftMonitor
from repro.serve.engine import ConvergenceMonitor
from repro.stream import SeparatorBank
from repro.stream.bank import BankState

P = 16


def _cfgs(P=P, n=2, m=4, mu=2e-3):
    return (
        EASIConfig(n_components=n, n_features=m, mu=mu),
        SMBGDConfig(batch_size=P, mu=mu, beta=0.9, gamma=0.5),
    )


def _bank(fused, S=4, health_checks=True, **kw):
    ecfg, ocfg = _cfgs()
    return SeparatorBank(
        ecfg, ocfg, n_streams=S, fused=fused, health_checks=health_checks, **kw
    )


def _poisoned_batch(bank, key, S=4, nan_stream=1, inf_stream=2):
    """(S, P, m) batch with a NaN burst in one stream, an Inf in another."""
    X = np.array(
        jax.random.normal(key, (S, P, bank.easi.n_features)), dtype=np.float32
    )
    X[nan_stream, : P // 2] = np.nan
    X[inf_stream, 0, 0] = np.inf
    return jnp.asarray(X)


class TestHealthWord:
    def test_describe_health(self):
        assert easi_ops.describe_health(easi_ops.HEALTH_OK) == "ok"
        s = easi_ops.describe_health(
            easi_ops.HEALTH_NONFINITE_B | easi_ops.HEALTH_BLOWUP
        )
        assert "nonfinite-B" in s and "blowup" in s

    def test_health_word_ref_bits(self):
        ok = np.zeros((2, 2))
        bad = np.array([[np.nan, 0.0], [0.0, 0.0]])
        assert health_word_ref(ok, ok, ok, 0.1, 100.0) == easi_ops.HEALTH_OK
        assert health_word_ref(bad, ok, ok, 0.1, 100.0) == easi_ops.HEALTH_NONFINITE_B
        assert health_word_ref(ok, bad, ok, 0.1, 100.0) == easi_ops.HEALTH_NONFINITE_H
        assert health_word_ref(ok, ok, bad, 0.1, 100.0) == easi_ops.HEALTH_NONFINITE_Y
        assert health_word_ref(ok, ok, ok, 200.0, 100.0) == easi_ops.HEALTH_BLOWUP
        # NaN delta counts as blow-up (~(δ <= bound) semantics)
        assert health_word_ref(ok, ok, ok, float("nan"), 100.0) & easi_ops.HEALTH_BLOWUP

    @pytest.mark.parametrize("fused", [False, True])
    def test_poisoned_streams_flagged_and_frozen(self, fused):
        """NaN/Inf input streams report a bad word AND keep pre-tick state;
        clean neighbours commit normally."""
        bank = _bank(fused)
        state = bank.init(jax.random.PRNGKey(0))
        X = _poisoned_batch(bank, jax.random.PRNGKey(1))
        new_state, _ = bank.step(state, X)
        health = np.asarray(new_state.health)
        assert health[0] == 0 and health[3] == 0
        assert health[1] != 0 and health[2] != 0
        B_old, B_new = np.asarray(state.B), np.asarray(new_state.B)
        step_old, step_new = np.asarray(state.step), np.asarray(new_state.step)
        for s in range(4):
            committed = not np.array_equal(B_new[s], B_old[s])
            assert committed == (health[s] == 0), s
            assert (step_new[s] == step_old[s] + 1) == (health[s] == 0), s

    def test_fused_vmap_and_ref_words_agree(self):
        key = jax.random.PRNGKey(7)
        banks = {f: _bank(f) for f in (False, True)}
        st0 = banks[False].init(key)
        X = _poisoned_batch(banks[False], jax.random.fold_in(key, 1))
        words = {}
        for f, bank in banks.items():
            state = bank.pad_state(st0) if f else st0
            new_state, _ = bank.step(state, X)
            words[f] = np.asarray(new_state.health)
        np.testing.assert_array_equal(words[False], words[True])

    def test_kernel_health_matches_ref_oracle(self):
        """ops.smbgd_step_bank health output vs ref.py on poisoned input."""
        S, n, m = 4, 2, 4
        lay = easi_ops.bank_layout(n, m, P)
        key = jax.random.PRNGKey(3)
        Xl = np.array(jax.random.normal(key, (S, P, m)), np.float32)
        Xl[1, :4] = np.nan
        X = jnp.zeros((S, lay.P_pad, lay.m_pad)).at[:, :P, :m].set(Xl)
        B = jnp.zeros((S, lay.n_pad, lay.m_pad)).at[:, :n, :m].set(
            jax.random.normal(jax.random.fold_in(key, 1), (S, n, m)) * 0.3
        )
        H = jnp.zeros((S, lay.n_pad, lay.n_pad))
        W = jnp.full((S, lay.P_pad), 0.0).at[:, :P].set(1.0 / P)
        step = jnp.ones((S,), jnp.int32)
        gamma_hat = jnp.full((S,), 0.4)
        active = jnp.asarray([1, 1, 1, 0], jnp.int32)  # stream 3 frozen
        out_k = easi_ops.smbgd_step_bank(
            X, W, B, H, step, gamma_hat, active, block_p=lay.block_p
        )
        out_r = smbgd_step_bank_ref(X, W, B, H, step, gamma_hat, active)
        np.testing.assert_array_equal(np.asarray(out_k[5]), np.asarray(out_r[5]))
        h = np.asarray(out_k[5])
        assert h[1] != 0 and h[0] == 0
        assert h[3] == 0  # frozen streams take no verdict

    @pytest.mark.parametrize("fused", [False, True])
    def test_probe_reports_virtual_health(self, fused):
        """The no-commit probe returns the word a step WOULD produce."""
        bank = _bank(fused)
        state = bank.init(jax.random.PRNGKey(0))
        X = _poisoned_batch(bank, jax.random.PRNGKey(1))
        _conv, health, _mom = bank.probe(state, X)
        stepped, _ = bank.step(state, X)
        np.testing.assert_array_equal(
            np.asarray(health), np.asarray(stepped.health)
        )

    @pytest.mark.parametrize("fused", [False, True])
    def test_health_checks_off_restores_legacy_commit(self, fused):
        """health_checks=False: zero overhead, zero words, and a poisoned
        stream COMMITS its (non-finite) update — the pre-PR behavior."""
        bank = _bank(fused, health_checks=False)
        state = bank.init(jax.random.PRNGKey(0))
        X = _poisoned_batch(bank, jax.random.PRNGKey(1))
        new_state, _ = bank.step(state, X)
        assert np.all(np.asarray(new_state.health) == 0)
        assert not np.all(np.isfinite(np.asarray(new_state.B)[1]))

    def test_blowup_bound_override(self):
        """A tiny blow-up bound flags ordinary finite updates."""
        bank = _bank(True, blowup=1e-12)
        state = bank.init(jax.random.PRNGKey(0))
        X = jax.random.normal(jax.random.PRNGKey(1), (4, P, 4))
        new_state, _ = bank.step(state, X)
        health = np.asarray(new_state.health)
        assert np.all(health & easi_ops.HEALTH_BLOWUP)


class TestShadowHelpers:
    def test_update_shadow_masks_per_stream(self):
        bank = _bank(True)
        key = jax.random.PRNGKey(0)
        shadow = bank.init(key)
        state, _ = bank.step(shadow, jax.random.normal(key, (4, P, 4)))
        mask = jnp.asarray([1, 0, 1, 0], jnp.int32)
        out = bank.update_shadow(shadow, state, mask)
        for s in range(4):
            want = state if s % 2 == 0 else shadow
            np.testing.assert_array_equal(
                np.asarray(out.B[s]), np.asarray(want.B[s])
            )
            assert int(out.step[s]) == int(want.step[s])

    def test_restore_slot_rolls_back_one_stream(self):
        bank = _bank(True)
        key = jax.random.PRNGKey(0)
        shadow = bank.init(key)
        state, _ = bank.step(shadow, jax.random.normal(key, (4, P, 4)))
        out = bank.restore_slot(state, shadow, 2)
        np.testing.assert_array_equal(np.asarray(out.B[2]), np.asarray(shadow.B[2]))
        np.testing.assert_array_equal(np.asarray(out.B[0]), np.asarray(state.B[0]))
        assert int(np.asarray(out.health)[2]) == 0

    def test_copy_slot_reseeds_shadow(self):
        bank = _bank(True)
        key = jax.random.PRNGKey(0)
        dst = bank.init(key)
        src, _ = bank.step(dst, jax.random.normal(key, (4, P, 4)))
        out = bank.copy_slot(dst, src, 1)
        np.testing.assert_array_equal(np.asarray(out.B[1]), np.asarray(src.B[1]))
        np.testing.assert_array_equal(np.asarray(out.B[0]), np.asarray(dst.B[0]))

    def test_corrupt_slot_modes(self):
        bank = _bank(True)
        state = bank.init(jax.random.PRNGKey(0))
        assert not np.isfinite(np.asarray(bank.corrupt_slot(state, 0, "nan").B)[0, 0, 0])
        assert np.isinf(np.asarray(bank.corrupt_slot(state, 0, "inf").B)[0, 0, 0])
        big = bank.corrupt_slot(state, 1, "scale", scale=1e30)
        assert np.max(np.abs(np.asarray(big.B)[1])) >= 1e20
        with pytest.raises(ValueError, match="mode"):
            bank.corrupt_slot(state, 0, "zap")


class TestHealthPolicyAndMonitor:
    def test_policy_validation(self):
        with pytest.raises(ValueError):
            HealthPolicy(max_rollbacks=-1)
        with pytest.raises(ValueError):
            HealthPolicy(mu_cut=0.0)
        with pytest.raises(ValueError):
            HealthPolicy(probation=0)
        with pytest.raises(ValueError):
            HealthPolicy(probe_every=0)

    def test_offense_window_escalation(self):
        pol = HealthPolicy(max_rollbacks=2, window=10)
        mon = HealthMonitor()
        assert mon.record_offense(1, 1, pol) is False
        assert mon.record_offense(2, 1, pol) is False
        assert mon.record_offense(3, 1, pol) is True  # 3rd within window
        # offenses outside the sliding window age out
        mon2 = HealthMonitor()
        assert mon2.record_offense(1, 1, pol) is False
        assert mon2.record_offense(2, 1, pol) is False
        assert mon2.record_offense(50, 1, pol) is False  # 1, 2 pruned

    def test_policy_requires_health_checks(self):
        from repro.serve import SeparationService

        with pytest.raises(ValueError, match="health_checks"):
            SeparationService(
                _bank(True, health_checks=False),
                policy=ConvergencePolicy(),
                health_policy=HealthPolicy(),
            )


class TestNaNSaturatingMonitors:
    """Satellite: a faulted tick's NaN statistic must never poison the
    host-side monitor recurrences — skip the sample, count the skip."""

    def test_ema_update_skips_nan_value(self):
        s = ema_update(jnp.asarray(0.5), jnp.asarray(float("nan")), 0.9)
        assert float(s) == 0.5
        # +inf value keeps the legacy blend/replace semantics
        s = ema_update(jnp.asarray(float("inf")), jnp.asarray(0.3), 0.9)
        assert float(s) == pytest.approx(0.3)

    def test_convergence_monitor_skips_nan(self):
        pol = ConvergencePolicy(threshold=0.5, patience=2, min_ticks=0, ema=0.5)
        mon = ConvergenceMonitor()
        mon.update(0.1, pol)
        before = (mon.stat, mon.below, mon.ticks)
        mon.update(float("nan"), pol)
        assert (mon.stat, mon.below, mon.ticks) == before
        assert mon.skipped == 1
        mon.update(0.1, pol)  # streak resumes where it left off
        assert mon.below == 2

    def test_drift_monitor_skips_nan(self):
        pol = DriftPolicy(retrigger=0.1, patience=2, cooldown=0)
        mon = DriftMonitor()
        assert mon.update(0.5, pol) is False
        assert mon.update(float("nan"), pol) is False
        assert mon.skipped == 1 and mon.above == 1  # streak preserved
        assert mon.update(0.5, pol) is True

    def test_monitor_parity_with_ema_update_under_nan(self):
        """ConvergenceMonitor's host recurrence stays pinned to the in-graph
        ema_update even across NaN samples."""
        pol = ConvergencePolicy(threshold=0.5, patience=10**6, min_ticks=0, ema=0.7)
        mon = ConvergenceMonitor()
        smoothed = jnp.asarray(float("inf"))
        for x in (0.4, float("nan"), 0.2, float("nan"), 0.9):
            mon.update(x, pol)
            smoothed = ema_update(smoothed, x, pol.ema)
            if math.isfinite(float(smoothed)):
                np.testing.assert_allclose(mon.stat, float(smoothed), rtol=1e-6)


class TestBankStateHealthField:
    def test_state_roundtrips_health_leaf(self):
        bank = _bank(True)
        state = bank.init(jax.random.PRNGKey(0))
        state, _ = bank.step(
            state, jax.random.normal(jax.random.PRNGKey(1), (4, P, 4))
        )
        d = state._asdict()
        assert "health" in d
        rt = BankState(**d)
        np.testing.assert_array_equal(
            np.asarray(rt.health), np.asarray(state.health)
        )

    def test_epoch_carries_health(self):
        bank = _bank(True)
        state, _ = bank.epoch(
            bank.init(jax.random.PRNGKey(0)),
            jax.random.normal(jax.random.PRNGKey(1), (4, 4 * P, 4)),
        )
        assert np.asarray(state.health).shape == (4,)
