"""Trainer: loss goes down, resume-from-checkpoint continuity, NaN guard."""
import dataclasses
import json
import math
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import make_lm_pipeline
from repro.optim.smbgd import smbgd
from repro.optim.optimizers import adamw
from repro.train.trainer import Trainer, TrainerConfig


def _setup(tmp_path, arch="smollm-135m", **tkw):
    cfg = dataclasses.replace(get_config(arch).reduced(), n_layers=2)
    pipe = make_lm_pipeline(cfg, seq_len=32, global_batch=8, seed=0)
    tcfg = TrainerConfig(
        ckpt_dir=str(tmp_path / "ckpt"),
        ckpt_every=5,
        log_every=2,
        metrics_path=str(tmp_path / "metrics.jsonl"),
        **tkw,
    )
    tx = smbgd(learning_rate=0.05, gamma=0.8)
    return cfg, pipe, tcfg, tx


class TestTraining:
    def test_loss_decreases(self, tmp_path):
        cfg, pipe, tcfg, tx = _setup(tmp_path)
        tr = Trainer(cfg, tx, tcfg)
        _, _, losses = tr.fit(jax.random.PRNGKey(0), pipe, n_steps=30)
        assert len(losses) == 30
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3

    def test_metrics_logged(self, tmp_path):
        cfg, pipe, tcfg, tx = _setup(tmp_path)
        tr = Trainer(cfg, tx, tcfg)
        tr.fit(jax.random.PRNGKey(0), pipe, n_steps=11)
        lines = [json.loads(l) for l in open(tmp_path / "metrics.jsonl")]
        assert any("loss" in l for l in lines)

    def test_resume_continues_stream(self, tmp_path):
        """Kill after 12 steps, restart: the second run must resume from the
        checkpoint step and end near the uninterrupted run."""
        cfg, pipe, tcfg, tx = _setup(tmp_path)
        tr1 = Trainer(cfg, tx, tcfg)
        p_full, _, losses_full = tr1.fit(jax.random.PRNGKey(0), pipe, n_steps=20)

        tcfg2 = dataclasses.replace(tcfg, ckpt_dir=str(tmp_path / "ckpt2"))
        tr2 = Trainer(cfg, tx, tcfg2)
        tr2.fit(jax.random.PRNGKey(0), pipe, n_steps=12)
        tr3 = Trainer(cfg, tx, tcfg2)
        p_resumed, _, losses_tail = tr3.fit(jax.random.PRNGKey(0), pipe, n_steps=20)
        # resumed run processed only the remaining steps
        assert len(losses_tail) < 12
        # end state close to the uninterrupted run (same data stream; small
        # drift from the few re-executed steps after the 10-step checkpoint)
        l_full = losses_full[-1]
        l_res = losses_tail[-1]
        assert abs(l_full - l_res) < 0.35 * max(abs(l_full), 1.0)

    def test_microbatched_smbgd_runs(self, tmp_path):
        cfg, pipe, tcfg, tx = _setup(tmp_path, microbatches=4, smbgd_beta=0.9)
        tr = Trainer(cfg, tx, tcfg)
        _, _, losses = tr.fit(jax.random.PRNGKey(0), pipe, n_steps=8)
        assert all(math.isfinite(l) for l in losses)


class TestNaNGuard:
    def test_nan_guard_restores(self, tmp_path):
        cfg, pipe, tcfg, tx = _setup(tmp_path)
        tr = Trainer(cfg, tx, tcfg)
        params, opt_state, _ = tr.init_state(jax.random.PRNGKey(0))
        tr.ckpt.save(4, (params, opt_state))

        calls = {"n": 0}
        real_step = tr.step_fn

        def poisoned(params, opt_state, batch):
            calls["n"] += 1
            p, o, l = real_step(params, opt_state, batch)
            if calls["n"] == 3:
                return p, o, jnp.float32(float("nan"))
            return p, o, l

        tr.step_fn = poisoned
        _, _, losses = tr.fit(jax.random.PRNGKey(0), pipe, n_steps=10)
        assert all(math.isfinite(l) for l in losses)
        # resumes at ckpt step 4 → reruns steps 5..9: 3 calls + 5 rerun = 8
        assert calls["n"] == 8
