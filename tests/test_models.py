"""Per-architecture smoke tests (REDUCED configs — the assignment's (f)):
one forward/train step on CPU, assert output shapes + no NaNs; plus the
parallel-vs-recurrent serving consistency that pins down KV-cache/SSM-state
correctness for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import ARCH_IDS, all_lm_configs, get_config
from repro.models import model as M

LM_ARCHS = [a for a in ARCH_IDS if a != "easi-ica"]


def _batch(cfg, key, B=2, T=32):
    if cfg.n_codebooks:
        return {"tokens": jax.random.randint(key, (B, T, cfg.n_codebooks), 0, cfg.vocab_size)}
    if cfg.vision_tokens:
        return {
            "tokens": jax.random.randint(key, (B, T - cfg.vision_tokens), 0, cfg.vocab_size),
            "vision_embeds": jax.random.normal(key, (B, cfg.vision_tokens, cfg.d_model)),
        }
    return {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch).reduced()
        key = jax.random.PRNGKey(0)
        params = M.init_params(key, cfg)
        batch = _batch(cfg, key)
        logits, aux = M.forward(params, batch, cfg)
        B, T = 2, 32
        if cfg.n_codebooks:
            assert logits.shape == (B, T, cfg.n_codebooks, cfg.vocab_size)
        else:
            assert logits.shape == (B, T, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_train_step_finite_and_learns_direction(self, arch):
        """One SGD step must reduce loss on the same batch (sane gradients)."""
        cfg = get_config(arch).reduced()
        key = jax.random.PRNGKey(1)
        params = M.init_params(key, cfg)
        batch = _batch(cfg, key)

        def loss(p):
            return M.loss_fn(p, batch, cfg)[0]

        l0, g = jax.value_and_grad(loss)(params)
        assert bool(jnp.isfinite(l0))
        gnorm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
        assert np.isfinite(gnorm) and gnorm > 0
        p1 = jax.tree.map(lambda p, gi: p - 0.3 * gi, params, g)
        l1 = loss(p1)
        assert float(l1) < float(l0), f"{arch}: {float(l0)} -> {float(l1)}"

    def test_input_specs_cover_all_shapes(self, arch):
        cfg = get_config(arch)
        for s in SHAPES_BY_NAME.values():
            specs = M.input_specs(cfg, s)
            assert "tokens" in specs
            B = s.global_batch
            assert specs["tokens"].shape[0] == B


@pytest.mark.parametrize(
    "arch", ["minitron-8b", "gemma2-27b", "xlstm-1.3b", "zamba2-2.7b", "musicgen-large"]
)
def test_parallel_vs_recurrent_consistency(arch):
    """Token-by-token decode must reproduce the parallel forward exactly —
    validates KV caches, ring buffers, SSM/mLSTM/sLSTM streaming states."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, T = 2, 16
    batch = _batch(cfg, key, B=B, T=T)
    toks = batch["tokens"]
    logits_par, _ = M.forward(params, batch, cfg)
    st = M.init_serve_state(cfg, B, T)
    outs = []
    for t in range(T):
        lg, st = M.decode_step(params, st, {"tokens": toks[:, t : t + 1]}, cfg)
        outs.append(lg[:, 0])
    logits_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_par), np.asarray(logits_seq), rtol=1e-3, atol=2e-4
    )


def test_moe_parallel_vs_recurrent_no_drops():
    """MoE equality holds exactly when expert capacity is not exceeded."""
    cfg = dataclasses.replace(get_config("arctic-480b").reduced(), capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, T = 2, 16
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    logits_par, _ = M.forward(params, {"tokens": toks}, cfg)
    st = M.init_serve_state(cfg, B, T)
    outs = []
    for t in range(T):
        lg, st = M.decode_step(params, st, {"tokens": toks[:, t : t + 1]}, cfg)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(
        np.asarray(logits_par), np.asarray(jnp.stack(outs, axis=1)), rtol=1e-3, atol=2e-4
    )


def test_moe_load_balance_aux_positive():
    cfg = get_config("kimi-k2-1t-a32b").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    _, aux = M.forward(params, batch, cfg)
    assert float(aux) > 0.5  # ≈1 at uniform routing, per Switch normalization


def test_scan_vs_unrolled_forward_equal():
    """cfg.scan_layers=False (dry-run body reconstruction path) must be
    numerically identical to the scanned stack."""
    cfg = get_config("minitron-8b").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = _batch(cfg, key)
    l1, _ = M.forward(params, batch, cfg)
    l2, _ = M.forward(params, batch, dataclasses.replace(cfg, scan_layers=False))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-5)


def test_gemma2_softcap_bounds_logits():
    cfg = get_config("gemma2-27b").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, _ = M.forward(params, batch, cfg)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_sliding_window_restricts_context():
    """A token beyond the window must not influence a gemma2 local layer."""
    cfg = dataclasses.replace(
        get_config("gemma2-27b").reduced(), n_layers=2, sliding_window=8
    )
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    T = 32
    toks = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    # Perturb token 0; positions ≥ window in a 2-layer net (1 local + 1 global)
    # still see it through the global layer — so compare against a model with
    # BOTH layers local instead.
    cfg_local = dataclasses.replace(cfg, alt_local_global=False, sliding_window=8)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    l1, _ = M.forward(params, {"tokens": toks}, cfg_local)
    l2, _ = M.forward(params, {"tokens": toks2}, cfg_local)
    # windows are [t-8, t]: positions > 2*8 cannot be reached in 2 hops
    tail = slice(2 * 8 + 1, None)
    np.testing.assert_allclose(
        np.asarray(l1[0, tail]), np.asarray(l2[0, tail]), atol=1e-5
    )
    assert float(jnp.max(jnp.abs(l1[0, 0] - l2[0, 0]))) > 1e-4  # sanity: head moved


def test_mlstm_chunkwise_equals_parallel():
    """Chunkwise mLSTM (the §Perf variant / official xLSTM formulation) must
    equal the quadratic parallel form for any chunk size."""
    from repro.models.xlstm import _mlstm_chunkwise, _mlstm_parallel

    key = jax.random.PRNGKey(0)
    B, H, T, dqk, dv = 2, 3, 64, 8, 16
    q = jax.random.normal(key, (B, H, T, dqk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, T, dqk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, T, dv))
    log_i = jax.random.normal(jax.random.fold_in(key, 3), (B, H, T))
    log_f = jax.nn.log_sigmoid(
        jax.random.normal(jax.random.fold_in(key, 4), (B, H, T)) + 3.0
    )
    h_par = _mlstm_parallel(q, k, v, log_i, log_f)
    for L in (8, 16, 64):
        h_chk = _mlstm_chunkwise(q, k, v, log_i, log_f, L)
        np.testing.assert_allclose(
            np.asarray(h_par), np.asarray(h_chk), rtol=2e-4, atol=2e-4
        )
    # unrolled (dry-run counting path) == scanned
    h_u = _mlstm_chunkwise(q, k, v, log_i, log_f, 16, unroll=True)
    np.testing.assert_allclose(
        np.asarray(h_u),
        np.asarray(_mlstm_chunkwise(q, k, v, log_i, log_f, 16)),
        rtol=1e-5, atol=1e-5,
    )
    # gradients finite through the chunk recurrence
    g = jax.grad(lambda q: float(0) + jnp.sum(_mlstm_chunkwise(q, k, v, log_i, log_f, 16) ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(g)))
