"""PR-6 memory system: dtype policy, prefetch, VMEM-derived block_s, and the
persisted autotune cache.

The differential contracts:

  * bf16 storage vs the f32 oracle — same trajectory within checked-in
    Amari/conv tolerances across ragged shapes and all nonlinearities
    (accumulation is f32 either way; only the stored B/Ĥ quantize),
  * prefetch=True vs prefetch=False — bit-identical on the interpret path
    (the DMA pipeline reorders copies, never arithmetic),
  * the default block_s derives from the layout's actual VMEM residency
    (no hardcoded caps; compiled backends fail loudly when one stream
    can't fit),
  * geometry knobs resolve from the autotune cache; dtype_policy never does.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import metrics as metrics_lib
from repro.core.easi import EASIConfig
from repro.core.nonlinearities import NONLINEARITIES
from repro.core.smbgd import SMBGDConfig
from repro.kernels.easi_gradient import ops as easi_ops
from repro.stream import SeparatorBank
from repro.stream import autotune as autotune_lib
from repro.stream.bank import BankState

# Checked-in bf16-vs-f32 tolerances (empirical worst over the sweep below at
# 20 ticks: conv ≈ 5e-4, Amari ≈ 4.6e-2 — an order of margin on conv, ~2x on
# Amari, which is still well under the ≈0.5 scale of an unseparated system)
BF16_CONV_TOL = 5e-3
BF16_AMARI_TOL = 1e-1


def _cfgs(P=8, n=2, m=4, nonlinearity="cubic", mu=1e-3):
    return (
        EASIConfig(n_components=n, n_features=m, mu=mu, nonlinearity=nonlinearity),
        SMBGDConfig(batch_size=P, mu=mu, beta=0.9, gamma=0.5),
    )


def _mixed_batches(key, S, K, P, m, n):
    """K ticks of (S, P, m) mixtures of a fixed random (m, n) mixing —
    unit-norm columns keep every shape/nonlinearity combo in EASI's stable
    region (an un-normalized mixing diverges BOTH dtypes at some seeds,
    which tests nothing about precision)."""
    A = jax.random.normal(jax.random.fold_in(key, 7), (m, n))
    A = A / jnp.linalg.norm(A, axis=0, keepdims=True)
    src = jax.random.normal(jax.random.fold_in(key, 8), (S, K, P, n))
    return A, jnp.einsum("skpn,mn->skpm", src, A)


class TestBf16VsF32Oracle:
    @pytest.mark.property
    @settings(max_examples=12, deadline=None)
    @given(
        shape=st.sampled_from([(8, 2, 4), (13, 3, 5), (32, 4, 6), (5, 2, 7)]),
        nonlinearity=st.sampled_from(sorted(NONLINEARITIES)),
        seed=st.integers(min_value=0, max_value=3),
    )
    def test_trajectory_within_tolerance(self, shape, nonlinearity, seed):
        """20-tick bf16 bank vs the f32 oracle from the same init: per-stream
        conv statistics and Amari indices agree within checked-in tolerance
        across ragged (padded) shapes and every nonlinearity."""
        P, n, m = shape
        ecfg, ocfg = _cfgs(P=P, n=n, m=m, nonlinearity=nonlinearity)
        S, K = 3, 20
        key = jax.random.PRNGKey(seed)
        A, X = _mixed_batches(key, S, K, P, m, n)
        f32 = SeparatorBank(ecfg, ocfg, S, fused=True, autotune=False)
        bf16 = SeparatorBank(
            ecfg, ocfg, S, fused=True, dtype_policy="bf16", autotune=False
        )
        st_f = f32.init(key)
        st_b = bf16.pad_state(f32.unpad_state(st_f))
        assert st_b.B.dtype == jnp.bfloat16
        for k in range(K):
            st_f, _ = f32.step(st_f, X[:, k])
            st_b, _ = bf16.step(st_b, X[:, k])
        assert st_b.B.dtype == jnp.bfloat16  # storage dtype survives stepping
        assert st_b.conv.dtype == jnp.float32  # statistic stays f32
        assert float(jnp.abs(st_f.conv - st_b.conv).max()) <= BF16_CONV_TOL
        am_f = f32.performance_index(st_f, A)
        am_b = bf16.performance_index(st_b, A)
        assert float(jnp.abs(am_f - am_b).max()) <= BF16_AMARI_TOL

    def test_nonfused_paths_follow_policy(self):
        """The vmap fallbacks honor the policy too: bf16 storage, f32 compute
        (upcast/downcast at the same boundaries the kernel uses)."""
        ecfg, ocfg = _cfgs()
        S = 4
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, 8, 4))
        for kwargs in ({}, {"use_pallas": True}):
            bank = SeparatorBank(
                ecfg, ocfg, S, dtype_policy="bf16", autotune=False, **kwargs
            )
            st0 = bank.init(key)
            assert st0.B.dtype == jnp.bfloat16
            st1, Y = bank.step(st0, X)
            assert st1.B.dtype == jnp.bfloat16
            assert st1.H_hat.dtype == jnp.bfloat16
            # f32 compute: Y comes from the upcast B, not bf16 math
            assert Y.dtype == jnp.float32

    def test_probe_matches_between_policies(self):
        """The no-commit probe statistic agrees across storage dtypes within
        the conv tolerance (frozen parked separators are probed at whatever
        dtype they were parked in)."""
        ecfg, ocfg = _cfgs()
        S = 4
        key = jax.random.PRNGKey(2)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, 8, 4))
        f32 = SeparatorBank(ecfg, ocfg, S, fused=True, autotune=False)
        bf16 = SeparatorBank(
            ecfg, ocfg, S, fused=True, dtype_policy="bf16", autotune=False
        )
        st = f32.init(key)
        st, _ = f32.step(st, X)  # step once so the probe sees a real state
        conv_f, health_f, _mom_f = f32.probe(st, X)
        conv_b, health_b, _mom_b = bf16.probe(bf16.pad_state(f32.unpad_state(st)), X)
        assert float(jnp.abs(conv_f - conv_b).max()) <= BF16_CONV_TOL
        # a healthy state probes healthy at either storage dtype
        assert not health_f.any() and not health_b.any()

    def test_slot_boundary_casts(self):
        """Logical interchange stays at the config compute dtype: slot_state /
        unstack_states upcast, set_slot / pad_state cast back in, and a
        frozen slot round-trips bf16 → f32 → bf16 exactly."""
        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(
            ecfg, ocfg, 3, fused=True, dtype_policy="bf16", autotune=False
        )
        key = jax.random.PRNGKey(3)
        st, _ = bank.step(
            bank.init(key),
            jax.random.normal(jax.random.fold_in(key, 1), (3, 8, 4)),
        )
        sub = bank.slot_state(st, 0)
        assert sub.B.dtype == jnp.float32  # logical boundary is f32
        back = bank.set_slot(st, 0, sub)
        np.testing.assert_array_equal(np.asarray(back.B[0]), np.asarray(st.B[0]))
        subs = bank.unstack_states(st)
        assert all(s.B.dtype == jnp.float32 for s in subs)
        stacked = bank.pad_state(SeparatorBank.stack_states(subs))
        assert stacked.B.dtype == jnp.bfloat16

    def test_persistent_bytes_reduction_meets_bar(self):
        """The acceptance number: bf16 storage cuts persistent HBM bytes per
        session ≥ 1.5x vs f32 at the benchmark shape."""
        lay_f32 = easi_ops.bank_layout(2, 4, 32)
        lay_bf16 = easi_ops.bank_layout(2, 4, 32, dtype_policy="bf16")
        reduction = (
            lay_f32.persistent_bytes_per_session
            / lay_bf16.persistent_bytes_per_session
        )
        assert reduction >= 1.5
        # and the tick-traffic estimate shrinks too (X/Y/W bytes are shared)
        assert (
            lay_bf16.tick_hbm_bytes_per_stream < lay_f32.tick_hbm_bytes_per_stream
        )


class TestPrefetchBitIdentity:
    @pytest.mark.parametrize("policy", [None, "bf16"])
    def test_step_bit_identical(self, policy):
        """prefetch=True reorders the X DMA, never arithmetic: every output
        of the megakernel step is bit-identical to the sync path."""
        ecfg, ocfg = _cfgs(P=13, n=3, m=5)
        S = 4
        key = jax.random.PRNGKey(4)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, 13, 5))
        mk = lambda pf: SeparatorBank(
            ecfg, ocfg, S, fused=True, dtype_policy=policy,
            prefetch=pf, autotune=False,
        )
        sync, pre = mk(False), mk(True)
        st0 = sync.init(key)
        st_s, Y_s = sync.step(st0, X)
        st_p, Y_p = pre.step(st0, X)
        for a, b in zip(st_s, st_p):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(Y_s), np.asarray(Y_p))

    @pytest.mark.parametrize("policy", [None, "bf16"])
    def test_probe_bit_identical(self, policy):
        ecfg, ocfg = _cfgs(P=13, n=3, m=5)
        S = 4
        key = jax.random.PRNGKey(5)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, 13, 5))
        mk = lambda pf: SeparatorBank(
            ecfg, ocfg, S, fused=True, dtype_policy=policy,
            prefetch=pf, autotune=False,
        )
        sync, pre = mk(False), mk(True)
        st0 = sync.init(key)
        st0, _ = sync.step(st0, X)
        active = jnp.asarray([1, 0, 1, 1], jnp.int32)  # mask crosses blocks
        conv_s, health_s, _mom_s = sync.probe(st0, X, active=active)
        conv_p, health_p, _mom_p = pre.probe(st0, X, active=active)
        np.testing.assert_array_equal(np.asarray(conv_s), np.asarray(conv_p))
        np.testing.assert_array_equal(np.asarray(health_s), np.asarray(health_p))

    def test_prefetch_crosses_stream_block_boundaries(self):
        """block_s < S forces the pipeline's global tile counter across
        stream-block boundaries — the warmup/steady-state handoff the DMA
        slots must survive."""
        ecfg, ocfg = _cfgs(P=32)
        S = 6
        key = jax.random.PRNGKey(6)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, 32, 4))
        mk = lambda pf: SeparatorBank(
            ecfg, ocfg, S, fused=True, block_p=8, block_s=2,
            prefetch=pf, autotune=False,
        )
        st0 = mk(False).init(key)
        st_s, Y_s = mk(False).step(st0, X)
        st_p, Y_p = mk(True).step(st0, X)
        np.testing.assert_array_equal(np.asarray(st_s.B), np.asarray(st_p.B))
        np.testing.assert_array_equal(np.asarray(Y_s), np.asarray(Y_p))


class TestVmemDerivedBlockS:
    def test_default_is_budget_derived(self, monkeypatch):
        """block_s = largest divisor of S with residency x block_s ≤ budget."""
        lay = easi_ops.bank_layout(2, 4, 32)
        resident = lay.vmem_resident_bytes_per_stream()
        monkeypatch.setenv(easi_ops._VMEM_BUDGET_ENV, str(3 * resident))
        # cap 3 → largest divisor of 8 that is ≤ 3 is 2
        assert easi_ops.default_block_s(8, lay, interpret=True) == 2
        monkeypatch.setenv(easi_ops._VMEM_BUDGET_ENV, str(64 * resident))
        assert easi_ops.default_block_s(8, lay, interpret=True) == 8

    def test_prefetch_residency_costs_block_s(self, monkeypatch):
        """The double buffer's second X slot counts against the budget: at a
        budget sized to exactly fit the sync residency, prefetch shrinks the
        derived block_s."""
        lay = easi_ops.bank_layout(2, 4, 32)
        sync = lay.vmem_resident_bytes_per_stream(prefetch=False)
        pre = lay.vmem_resident_bytes_per_stream(prefetch=True)
        assert pre > sync
        monkeypatch.setenv(easi_ops._VMEM_BUDGET_ENV, str(4 * sync))
        bs_sync = easi_ops.default_block_s(8, lay, interpret=True)
        bs_pre = easi_ops.default_block_s(8, lay, prefetch=True, interpret=True)
        assert bs_pre < bs_sync == 4

    def test_compiled_raises_when_one_stream_cannot_fit(self, monkeypatch):
        """No silent VMEM blowups on real hardware: a shape whose single
        stream exceeds the budget fails loudly on compiled backends and
        clamps to 1 on the interpreter (host memory, nothing to blow)."""
        lay = easi_ops.bank_layout(2, 4, 32)
        monkeypatch.setenv(easi_ops._VMEM_BUDGET_ENV, "64")
        with pytest.raises(ValueError, match="exceeds the VMEM budget"):
            easi_ops.default_block_s(8, lay, interpret=False)
        assert easi_ops.default_block_s(8, lay, interpret=True) == 1

    def test_large_shape_shrinks_block_s(self):
        """A big (m, n) shape derives a smaller block_s than a toy shape
        under the same budget — the hardcoded-cap bug this replaces."""
        small = easi_ops.bank_layout(2, 4, 32)
        big = easi_ops.bank_layout(64, 256, 256)
        assert (
            big.vmem_resident_bytes_per_stream()
            > small.vmem_resident_bytes_per_stream()
        )
        bs_small = easi_ops._default_block_s(
            64, resident_bytes=small.vmem_resident_bytes_per_stream(),
            interpret=False,
        )
        bs_big = easi_ops._default_block_s(
            64, resident_bytes=big.vmem_resident_bytes_per_stream(),
            interpret=False,
        )
        assert bs_big < bs_small


class TestAutotuneCache:
    def _seed_cache(self, monkeypatch, tmp_path, entry, S=4, P=8, m=4, n=2):
        path = tmp_path / "autotune.json"
        monkeypatch.setenv(autotune_lib.CACHE_ENV, str(path))
        autotune_lib.store(S, P, m, n, entry)
        return path

    def test_store_lookup_roundtrip(self, monkeypatch, tmp_path):
        entry = {"block_p": 8, "block_s": 2, "prefetch": True}
        self._seed_cache(monkeypatch, tmp_path, entry)
        assert autotune_lib.lookup(4, 8, 4, 2) == entry
        assert autotune_lib.lookup(5, 8, 4, 2) is None  # different shape key
        # different backend tag: the interpret entry must not leak
        assert autotune_lib.lookup(4, 8, 4, 2, interpret=False) is None

    def test_bank_resolves_geometry_from_cache(self, monkeypatch, tmp_path):
        self._seed_cache(
            monkeypatch, tmp_path,
            {"block_p": 8, "block_s": 2, "prefetch": True, "dtype_policy": "bf16"},
        )
        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, 4, fused=True)
        assert (bank.block_p, bank.block_s, bank.prefetch) == (8, 2, True)
        # dtype_policy is recorded but NEVER auto-applied
        assert bank.dtype_policy is None
        assert bank.storage_dtype == jnp.float32

    def test_explicit_knobs_and_opt_out_win(self, monkeypatch, tmp_path):
        self._seed_cache(
            monkeypatch, tmp_path, {"block_p": 8, "block_s": 2, "prefetch": True}
        )
        ecfg, ocfg = _cfgs()
        explicit = SeparatorBank(ecfg, ocfg, 4, fused=True, block_p=16)
        assert explicit.block_p == 16  # explicit beats cached
        assert explicit.block_s == 2  # unset knobs still fill in
        opt_out = SeparatorBank(ecfg, ocfg, 4, fused=True, autotune=False)
        assert (opt_out.block_p, opt_out.block_s, opt_out.prefetch) == (
            None, None, None,
        )

    def test_non_dividing_cached_block_s_skipped(self, monkeypatch, tmp_path):
        self._seed_cache(
            monkeypatch, tmp_path,
            {"block_p": 8, "block_s": 3, "prefetch": False},
        )
        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, 4, fused=True)  # 4 % 3 != 0
        assert bank.block_s is None
        assert bank.block_p == 8

    def test_corrupt_cache_never_breaks_construction(self, monkeypatch, tmp_path):
        path = tmp_path / "autotune.json"
        path.write_text("{not json")
        monkeypatch.setenv(autotune_lib.CACHE_ENV, str(path))
        assert autotune_lib.load_cache() == {}
        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, 4, fused=True)
        assert bank.block_p is None  # fell back to derived defaults
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 4))
        st, _ = bank.step(bank.init(key), X)  # and still steps fine

    def test_cached_geometry_is_numerically_invariant(
        self, monkeypatch, tmp_path
    ):
        """Adopting tuned geometry must never change results: a cache-tuned
        bank matches the default-geometry bank bit for bit."""
        ecfg, ocfg = _cfgs()
        key = jax.random.PRNGKey(1)
        X = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 4))
        default = SeparatorBank(ecfg, ocfg, 4, fused=True, autotune=False)
        st0 = default.init(key)
        st_d, Y_d = default.step(st0, X)
        self._seed_cache(
            monkeypatch, tmp_path, {"block_p": 8, "block_s": 2, "prefetch": True}
        )
        tuned = SeparatorBank(ecfg, ocfg, 4, fused=True)
        st_t, Y_t = tuned.step(st0, X)
        for a, b in zip(st_d, st_t):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(Y_d), np.asarray(Y_t))

    def test_resize_re_resolves_at_new_width_key(self, monkeypatch, tmp_path):
        """``with_streams`` re-runs autotune resolution keyed on the NEW
        (S, P, m, n, backend): each width adopts its own tuned geometry."""
        self._seed_cache(
            monkeypatch, tmp_path,
            {"block_p": 8, "block_s": 2, "prefetch": False}, S=4,
        )
        autotune_lib.store(8, 8, 4, 2, {
            "block_p": 4, "block_s": 4, "prefetch": True,
        })
        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, 4, fused=True)
        assert (bank.block_p, bank.block_s, bank.prefetch) == (8, 2, False)
        wide = bank.with_streams(8)
        assert (wide.block_p, wide.block_s, wide.prefetch) == (4, 4, True)
        # and back: the original width's entry re-adopts, not the wide one's
        back = wide.with_streams(4)
        assert (back.block_p, back.block_s, back.prefetch) == (8, 2, False)

    def test_resize_keeps_explicit_knobs_winning(self, monkeypatch, tmp_path):
        self._seed_cache(
            monkeypatch, tmp_path,
            {"block_p": 8, "block_s": 2, "prefetch": False}, S=4,
        )
        autotune_lib.store(8, 8, 4, 2, {
            "block_p": 4, "block_s": 4, "prefetch": True,
        })
        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, 4, fused=True, block_p=16)
        assert bank.block_p == 16 and bank.block_s == 2
        wide = bank.with_streams(8)
        # the hand-set knob survives the resize; unset knobs re-resolve
        assert wide.block_p == 16
        assert (wide.block_s, wide.prefetch) == (4, True)
        # opt-out stays opted out at every width
        opt_out = SeparatorBank(ecfg, ocfg, 4, fused=True, autotune=False)
        wide_out = opt_out.with_streams(8)
        assert (wide_out.block_p, wide_out.block_s, wide_out.prefetch) == (
            None, None, None,
        )

    def test_resize_with_missing_or_corrupt_cache_falls_back(
        self, monkeypatch, tmp_path
    ):
        """No entry at the new width (or a corrupt cache file) degrades to
        the VMEM-budget derived defaults — and the resized bank still steps."""
        self._seed_cache(
            monkeypatch, tmp_path,
            {"block_p": 8, "block_s": 2, "prefetch": True}, S=4,
        )
        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, 4, fused=True)
        assert bank.block_p == 8
        wide = bank.with_streams(8)  # no S=8 entry seeded
        assert (wide.block_p, wide.block_s, wide.prefetch) == (
            None, None, None,
        )
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(jax.random.fold_in(key, 1), (8, 8, 4))
        wide.step(wide.init(key), X)  # budget-derived geometry serves
        # corrupt cache mid-flight: the resize itself must not raise
        (tmp_path / "autotune.json").write_text("{not json")
        narrow = wide.with_streams(2)
        assert (narrow.block_p, narrow.block_s, narrow.prefetch) == (
            None, None, None,
        )

    def test_checked_in_cache_parses_and_keys_well_formed(self):
        """The committed AUTOTUNE.json artifact stays loadable and every
        entry carries the geometry schema the resolver reads."""
        cache = json.loads(autotune_lib._DEFAULT_PATH.read_text())
        assert cache  # the repo ships tuned entries
        for key, entry in cache.items():
            assert "backend=" in key and "S=" in key
            for field in autotune_lib.GEOMETRY_KEYS:
                assert field in entry, (key, field)
