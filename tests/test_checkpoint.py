"""Checkpointing: atomicity, GC, resume, reshard-on-load (elastic restart)."""
import dataclasses
import json
import os
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "step_scale": jnp.float32(0.5),
    }


class TestRoundtrip:
    def test_save_restore_exact(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=2)
        t = _tree()
        ckpt.save(7, t)
        restored, step = ckpt.restore(jax.tree.map(jnp.zeros_like, t))
        assert step == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        t = _tree()
        ckpt.save_async(3, t)
        ckpt.wait()
        assert ckpt.latest_step() == 3

    def test_latest_picks_newest_complete(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=10)
        ckpt.save(1, _tree())
        ckpt.save(5, _tree(1))
        # a torn write (tmp dir) must be invisible
        (tmp_path / "step_000000000009.tmp").mkdir()
        # an incomplete dir without manifest must be invisible
        (tmp_path / "step_000000000008").mkdir()
        assert ckpt.latest_step() == 5

    def test_gc_keeps_n(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ckpt.save(s, _tree(s))
        assert ckpt.all_steps() == [3, 4]

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        ckpt.save(0, _tree())
        bad = {"layers": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((16,))}, "step_scale": jnp.float32(0)}
        with pytest.raises(ValueError):
            ckpt.restore(bad)


class TestServiceLifecycleRoundtrip:
    """Queue + convergence-policy state across a checkpoint boundary: the
    arrays ride the Checkpointer, the host-side lifecycle snapshot rides
    alongside (JSON-able), and a restored service resumes the SAME lifecycle
    trajectory — monitors, queue order and all."""

    def _svc(self, **kw):
        from repro.core import EASIConfig, SMBGDConfig
        from repro.serve.engine import ConvergencePolicy, SeparationService
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=2),
            seed=0,
            policy=ConvergencePolicy(threshold=10.0, patience=3, min_ticks=4),
            max_queue=4,
            **kw,
        )

    def test_queue_and_policy_state_roundtrip(self, tmp_path):
        svc = self._svc()
        for sid in ("a", "b", "q1", "q2"):
            svc.admit(sid)
        X = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
        for k in range(2):  # part-way to convergence: monitors mid-flight
            svc.step({"a": X, "b": X})
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=3)
        snap = json.loads(json.dumps(svc.lifecycle))  # must survive JSON

        svc2 = self._svc()
        got = svc2.restore(ckpt, lifecycle=snap)
        assert got == 3
        assert svc2.sessions == svc.sessions
        assert svc2.queued == ("q1", "q2")
        assert svc2.session_stats("a")["conv_below"] == 2
        # the restored service reaches convergence on the same tick as the
        # original, evicting + backfilling identically
        for k in range(2):
            o1 = svc.step({"a": X, "b": X})
            o2 = svc2.step({"a": X, "b": X})
            for sid in o1:
                np.testing.assert_array_equal(np.asarray(o1[sid]), np.asarray(o2[sid]))
        for s in (svc, svc2):
            assert s.status("a") == "finished" and s.status("q1") == "active"
        np.testing.assert_allclose(
            np.asarray(svc.finished["a"].state.B),
            np.asarray(svc2.finished["a"].state.B),
            rtol=1e-6, atol=1e-7,
        )

    def test_restore_rejects_queue_session_overlap(self, tmp_path):
        svc = self._svc()
        svc.admit("a")
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=0)
        svc2 = self._svc()
        with pytest.raises(ValueError, match="overlap"):
            svc2.restore(
                ckpt, lifecycle={"sessions": {"a": 0}, "queue": ["a"]}
            )
        with pytest.raises(ValueError, match="overlap"):
            svc2.restore(
                ckpt, lifecycle={"sessions": {}, "queue": ["q", "q"]}
            )

    def test_bank_conv_statistic_roundtrips(self, tmp_path):
        """BankState.conv is a first-class leaf: exact across save/restore."""
        svc = self._svc()
        svc.admit("a")
        svc.step({"a": jax.random.normal(jax.random.PRNGKey(1), (8, 4))})
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        svc2 = self._svc()
        svc2.restore(ckpt, lifecycle=svc.lifecycle)
        np.testing.assert_array_equal(
            np.asarray(svc.state.conv), np.asarray(svc2.state.conv)
        )
        assert np.all(np.isfinite(np.asarray(svc2.state.conv)[:1]))


class TestDriftLifecycleRoundtrip:
    """Scheduler + drift-watchdog state across a checkpoint boundary, taken
    MID-DRIFT: hot monitors, boost countdowns, per-slot μ multipliers,
    scheduling metadata and source cursors all resume, and the restored
    service replays the original's exact trajectory."""

    def _svc(self):
        from repro.core import EASIConfig, SMBGDConfig
        from repro.serve import (
            ConvergencePolicy,
            DriftPolicy,
            PriorityScheduler,
            SeparationService,
        )
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
        ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=0.5)
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=1),
            seed=0,
            policy=ConvergencePolicy(
                threshold=0.025, patience=5, min_ticks=50, ema=0.9
            ),
            drift_policy=DriftPolicy(
                retrigger=0.03, patience=2, ema=0.8, cooldown=3,
                mode="boost", boost=4.0, boost_ticks=60,
            ),
            # tenant "suspended" has quota 0: its sessions ride the queue
            # (through the checkpoint) without ever contending for the slot
            scheduler=PriorityScheduler(max_queue=4, quotas={"suspended": 0}),
        )

    def _source(self):
        from repro.data.pipeline import MixedSignals
        from repro.data.sources import SyntheticSource

        pipe = MixedSignals(m=4, n=2, batch=16, seed=0, drift_rate=1.2 / 80)
        return SyntheticSource(pipe, drift_start=80, drift_stop=85)

    def test_mid_drift_roundtrip_resumes_exact_trajectory(self, tmp_path):
        svc = self._svc()
        src = svc_src = self._source()
        svc.admit("u", source=src, tenant="acme", priority=5.0)
        # rides the queue through the ckpt (quota-gated, so "u" stays hot)
        svc.admit("waiting", tenant="suspended", priority=1.0)
        # serve through convergence → hot → drift fires → μ boost engaged
        for _ in range(95):
            svc.run_tick()
        assert svc.drift_events and "u" in svc._boost_left  # mid-re-adaptation
        boost_left_at_save = dict(svc._boost_left)
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=7)
        snap = json.loads(json.dumps(svc.lifecycle))  # must survive JSON

        svc2 = self._svc()
        got = svc2.restore(ckpt, lifecycle=snap)
        assert got == 7
        # scheduler state: queue order AND metadata resumed
        assert svc2.queued == ("waiting",)
        assert svc2.scheduler.meta_of("waiting").priority == 1.0
        # watchdog state: boost countdown + μ row resumed exactly
        assert svc2._boost_left == boost_left_at_save
        np.testing.assert_array_equal(svc2._mu_scale, svc._mu_scale)
        # source re-binds and seeks to the recorded cursor
        src2 = self._source()
        svc2.bind_source("u", src2)
        assert src2.position == svc_src.position
        # both services now walk the identical trajectory (boost expiry and
        # re-convergence included)
        for _ in range(120):
            o1, o2 = svc.run_tick(), svc2.run_tick()
            for sid in o1:
                np.testing.assert_allclose(
                    np.asarray(o1[sid]), np.asarray(o2[sid]), rtol=1e-6, atol=1e-7
                )
        assert svc.status("u") == svc2.status("u") == "converged"
        assert svc2._boost_left == svc._boost_left == {}
        np.testing.assert_array_equal(svc2._mu_scale, svc._mu_scale)

    def test_hot_monitor_roundtrips(self, tmp_path):
        svc = self._svc()
        svc.admit("u", source=self._source())
        for _ in range(70):
            svc.run_tick()
        assert svc.status("u") == "converged"  # hot under drift watch
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        snap = json.loads(json.dumps(svc.lifecycle))
        assert snap["hot"]["u"]["seen"] > 0

        svc2 = self._svc()
        svc2.restore(ckpt, lifecycle=snap)
        assert svc2.status("u") == "converged"
        assert dataclasses.asdict(svc2._hot["u"]) == snap["hot"]["u"]

    def test_restore_rejects_bad_mu_scale(self, tmp_path):
        svc = self._svc()
        svc.admit("u")
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=0)
        svc2 = self._svc()
        with pytest.raises(ValueError, match="mu_scale"):
            svc2.restore(
                ckpt,
                lifecycle={"sessions": {"u": 0}, "mu_scale": [1.0, 1.0, 1.0]},
            )

    def test_restore_rejects_drift_state_without_drift_policy(self, tmp_path):
        """A snapshot carrying hot/boost/μ state must not restore into a
        service that cannot run it (it would crash or silently drift from
        the original trajectory)."""
        from repro.core import EASIConfig, SMBGDConfig
        from repro.serve import ConvergencePolicy, SeparationService
        from repro.stream import SeparatorBank

        svc = self._svc()
        svc.admit("u", source=self._source())
        for _ in range(95):  # through convergence → hot → boost engaged
            svc.run_tick()
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=2)
        snap = json.loads(json.dumps(svc.lifecycle))
        assert snap["boost"] or snap["hot"]  # the snapshot carries drift state

        ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
        ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=0.5)
        plain = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=1),
            seed=0,
            policy=ConvergencePolicy(threshold=0.025, patience=5, min_ticks=50),
        )
        with pytest.raises(ValueError, match="drift"):
            plain.restore(ckpt, lifecycle=snap)
        # dropping the watch state restores fine (arrays are still valid)
        snap2 = dict(snap, hot={}, boost={}, mu_scale=None)
        plain.restore(ckpt, lifecycle=snap2)
        assert plain.sessions == svc.sessions


class TestElasticRestore:
    def test_reshard_on_load(self, tmp_path):
        """Checkpoints are topology-independent: restore with explicit
        shardings places leaves onto the (new) mesh — 1-device CPU here, the
        512→256 path exercised by the dry-run meshes."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ckpt = Checkpointer(tmp_path)
        t = _tree()
        ckpt.save(2, t)
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        restored, step = ckpt.restore(t, shardings=sh)
        assert step == 2
        for leaf in jax.tree.leaves(restored):
            assert leaf.sharding == NamedSharding(mesh, P())

    def test_restore_specific_step(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=10)
        ckpt.save(1, _tree(1))
        ckpt.save(2, _tree(2))
        r1, s1 = ckpt.restore(_tree(), step=1)
        np.testing.assert_array_equal(
            np.asarray(r1["layers"]["w"]), np.asarray(_tree(1)["layers"]["w"])
        )
