"""Checkpointing: atomicity, GC, resume, reshard-on-load (elastic restart)."""
import json
import os
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "step_scale": jnp.float32(0.5),
    }


class TestRoundtrip:
    def test_save_restore_exact(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=2)
        t = _tree()
        ckpt.save(7, t)
        restored, step = ckpt.restore(jax.tree.map(jnp.zeros_like, t))
        assert step == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        t = _tree()
        ckpt.save_async(3, t)
        ckpt.wait()
        assert ckpt.latest_step() == 3

    def test_latest_picks_newest_complete(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=10)
        ckpt.save(1, _tree())
        ckpt.save(5, _tree(1))
        # a torn write (tmp dir) must be invisible
        (tmp_path / "step_000000000009.tmp").mkdir()
        # an incomplete dir without manifest must be invisible
        (tmp_path / "step_000000000008").mkdir()
        assert ckpt.latest_step() == 5

    def test_gc_keeps_n(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ckpt.save(s, _tree(s))
        assert ckpt.all_steps() == [3, 4]

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        ckpt.save(0, _tree())
        bad = {"layers": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((16,))}, "step_scale": jnp.float32(0)}
        with pytest.raises(ValueError):
            ckpt.restore(bad)


class TestElasticRestore:
    def test_reshard_on_load(self, tmp_path):
        """Checkpoints are topology-independent: restore with explicit
        shardings places leaves onto the (new) mesh — 1-device CPU here, the
        512→256 path exercised by the dry-run meshes."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ckpt = Checkpointer(tmp_path)
        t = _tree()
        ckpt.save(2, t)
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        restored, step = ckpt.restore(t, shardings=sh)
        assert step == 2
        for leaf in jax.tree.leaves(restored):
            assert leaf.sharding == NamedSharding(mesh, P())

    def test_restore_specific_step(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=10)
        ckpt.save(1, _tree(1))
        ckpt.save(2, _tree(2))
        r1, s1 = ckpt.restore(_tree(), step=1)
        np.testing.assert_array_equal(
            np.asarray(r1["layers"]["w"]), np.asarray(_tree(1)["layers"]["w"])
        )
